"""Batched serving demo across architecture families: dense GQA (llama),
MQA (gemma), MLA+MoE (deepseek), recurrent (xlstm), hybrid (hymba).

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.configs import get_smoke_config
from repro.launch.serve import serve

for arch in ["llama2-1b", "gemma-2b", "deepseek-v3-671b", "xlstm-1.3b",
             "hymba-1.5b"]:
    cfg = get_smoke_config(arch)
    r = serve(cfg, batch=4, prompt_len=16, gen=8)
    print(f"{arch:18s} prefill {1000*r['prefill_s']:7.1f} ms | "
          f"decode {r['decode_tok_per_s']:8.1f} tok/s | "
          f"sample {r['tokens'][0][:5].tolist() if r['tokens'] is not None else '-'}")
