"""Shape-bucketed serving with a true rolled autoregressive decode loop.

A serving worker sees wildly shape-diverse traffic — a prompt of 24 tokens
and one of 900 should not pay the same worst-case memory plan, and a
T-step decode should not pay T traced step graphs.  This demo:

1. compiles a prefill-style step once with symbolic ``(b, s)`` and
   ``buckets=``, so the schedule/remat/arena pipeline specializes per
   sequence-length bucket;
2. warms the buckets the worker expects, then drives mixed-length
   requests through ``BucketBatcher`` — same-bucket requests dispatch
   together, and a memory budget holds back buckets whose *guaranteed*
   arena bound does not fit;
3. compiles the decode loop **rolled**: one ``scan`` with a *symbolic*
   trip count becomes a single ``Loop`` instruction over a lowered body
   sub-program — plan size, compile time and the steady-state arena are
   all independent of how many tokens each request generates, and the
   trip count buckets like any other declared dim;
4. runs the classic multi-architecture decode smoke loop.

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import optimize, scan, symbolic_dim, symbolic_dims
from repro.launch.serve import BucketBatcher, serve

# -- 1. one trace, per-bucket specialization ----------------------------------

B, S = symbolic_dims("b, s")
D, F = 64, 256


def prefill_step(w, x):
    """Attention-flavoured prefill block: activations scale with b*s*s."""
    h = jax.nn.gelu(x @ w["wi"])
    scores = jax.nn.softmax(h @ jnp.swapaxes(h, -1, -2) / np.sqrt(F))
    ctx = scores @ h
    return jnp.tanh(ctx @ w["wo"]).sum(axis=-1)


w_specs = {"wi": jax.ShapeDtypeStruct((D, F), jnp.float32),
           "wo": jax.ShapeDtypeStruct((F, D), jnp.float32)}
x_spec = jax.ShapeDtypeStruct((B, S, D), jnp.float32)

fn = optimize(prefill_step, w_specs, x_spec,
              dynamic_dims={"b": (1, 8), "s": (16, 1024)},
              buckets={"s": [64, 256]})       # s: [16,64] [65,256] [257,1024]

table = fn.specialization_table
print(f"bucket space: {table.space!r}")
print(f"whole-range guaranteed arena: {fn.arena_bound_bytes/2**20:.1f} MiB")

# -- 2. warmup + bucket-aware batching ----------------------------------------

fn.warmup([{"b": 4, "s": 32}, {"b": 4, "s": 128}])   # expected traffic
budget = 48 << 20                                     # this worker's HBM slice
batcher = BucketBatcher(fn, memory_budget=budget)

rng = np.random.RandomState(0)
w = {"wi": jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
     "wo": jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)}
for s in [24, 900, 48, 200, 60, 128, 980]:            # mixed-length arrivals
    x = jnp.asarray(rng.randn(2, s, D), jnp.float32)
    batcher.submit({"b": 2, "s": s}, payload=x)

for group in batcher.drain():
    bound = group.arena_bound_bytes
    n_inst = group.n_instructions                  # lowered Program length
    print(f"dispatch {len(group)} reqs in bucket {group.label:24s} "
          f"(arena <= {bound/2**20:5.1f} MiB, "
          f"program={n_inst if n_inst is not None else '?'} instrs)")
    for x in group.payloads:
        fn(w, x)
st = fn.last_report.stats
print(f"held over budget: {batcher.pending()} reqs "
      f"{list(batcher.pending_by_bucket())}")
print(f"dispatch stats: hits={st.bucket_hits} "
      f"specializations={st.specialize_count} "
      f"last dispatch={st.last_dispatch_ns/1e3:.0f} us\n")

# -- 3. the decode loop itself, rolled ----------------------------------------

T = symbolic_dim("t")                               # symbolic trip count
VOCAB = 128


def decode_loop(w, h0, pos):
    """T greedy decode steps as ONE rolled loop: carry = hidden state,
    per-step output = the sampled token ids."""
    def cell(h, p):
        h = jnp.tanh(h @ w["wh"] + p)               # state update
        logits = h @ w["wv"]                        # readout
        return h, jnp.argmax(logits, axis=-1)       # (carry, token)
    h_final, tokens = scan(cell, jnp.tanh(h0), pos)
    return h_final, tokens


dw_specs = {"wh": jax.ShapeDtypeStruct((D, D), jnp.float32),
            "wv": jax.ShapeDtypeStruct((D, VOCAB), jnp.float32)}
dec = optimize(decode_loop, dw_specs,
               jax.ShapeDtypeStruct((4, D), jnp.float32),   # prefill state
               jax.ShapeDtypeStruct((T, D), jnp.float32),   # per-step posemb
               dynamic_dims={"t": (1, 512)},
               buckets={"t": [16, 64]})    # gen-length buckets, SPMD-stable

dw = {"wh": jnp.asarray(rng.randn(D, D) * 0.2, jnp.float32),
      "wv": jnp.asarray(rng.randn(D, VOCAB) * 0.2, jnp.float32)}
h0 = jnp.asarray(rng.randn(4, D) * 0.2, jnp.float32)

counts = None
for gen in [8, 17, 100, 300]:                       # ONE plan, any gen length
    pos = jnp.asarray(rng.randn(gen, D) * 0.1, jnp.float32)
    _, tokens = dec(dw, h0, pos)
    st = dec.last_report.stats
    counts = dec.program.counts()
    print(f"rolled decode gen={gen:4d}: tokens[:6]={tokens[:6, 0].tolist()} "
          f"peak={st.device_peak/1024:.1f}KiB arena={st.arena_bytes} "
          f"program={sum(counts.values())} instrs (Loop={counts['Loop']})")
print("plan is O(body), not O(T*body): "
      f"{sum(counts.values())} instructions serve every gen length\n")

# -- 4. the multi-architecture decode smoke loop ------------------------------

for arch in ["llama2-1b", "gemma-2b", "deepseek-v3-671b", "xlstm-1.3b",
             "hymba-1.5b"]:
    cfg = get_smoke_config(arch)
    r = serve(cfg, batch=4, prompt_len=16, gen=8)
    print(f"{arch:18s} prefill {1000*r['prefill_s']:7.1f} ms | "
          f"decode {r['decode_tok_per_s']:8.1f} tok/s | "
          f"sample {r['tokens'][0][:5].tolist() if r['tokens'] is not None else '-'}")
