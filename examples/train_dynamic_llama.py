"""End-to-end driver: fine-tune the (reduced) Llama on a CodeAlpaca-like
variable-length stream with the BladeDISC++ dynamic-shape path, under a
memory cap, with checkpointing — the paper's §3 workload end to end.

    PYTHONPATH=src python examples/train_dynamic_llama.py
"""
import tempfile

from repro.configs import get_smoke_config
from repro.launch.train import train

cfg = get_smoke_config("llama2-1b")
with tempfile.TemporaryDirectory() as d:
    # establish the free-run peak, then train under a 75% cap
    probe = train(cfg, steps=5, batch_size=6, mode="dynamic", log_every=2)
    cap = int(probe["peak_bytes"] * 0.75)
    stats = train(cfg, steps=120, batch_size=6, mode="dynamic",
                  memory_limit=cap, ckpt_dir=d, ckpt_every=40, log_every=20)
print(f"tokens/s       : {stats['tokens_per_s']:.0f}")
print(f"loss           : {stats['losses'][0]:.3f} -> {stats['losses'][-1]:.3f}")
print(f"peak bytes     : {stats['peak_bytes']/2**20:.1f} MiB (cap {cap/2**20:.1f})")
print(f"recompilations : {stats['recompilations']} (dynamic shapes, one trace)")
