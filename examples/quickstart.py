"""Quickstart: the BladeDISC++ pipeline on a dynamic-shape MLP train step.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimize, symbolic_dims
from repro.core.executor.memory import MemoryLimitExceeded

# 1. Declare symbolic dims: batch and sequence vary at runtime.
B, S = symbolic_dims("b, s")

LAYERS, D, F = 6, 64, 512


def loss_fn(ws, x):
    h = x
    for w1, w2 in ws:
        h = h + jax.nn.gelu(h @ w1) @ w2
    return (h ** 2).mean()


def train_step(ws, x):
    loss, grads = jax.value_and_grad(loss_fn)(ws, x)
    return loss, jax.tree.map(lambda w, g: w - 1e-3 * g, ws, grads)


# 2. Optimize once: symbolic trace -> op scheduling (§2.2) -> remat plan (§2.3).
w_specs = [(jax.ShapeDtypeStruct((D, F), jnp.float32),
            jax.ShapeDtypeStruct((F, D), jnp.float32)) for _ in range(LAYERS)]
opt = optimize(train_step, w_specs, jax.ShapeDtypeStruct((B, S, D), jnp.float32))
r = opt.report
print(f"compiled once: {len(opt.plan.order)} ops, "
      f"{r.schedule.symbolic_decisions} symbolic scheduling decisions, "
      f"{r.n_candidates} remat candidates ({r.n_recomputable} recomputable)")

# 3. Run ANY shape with the same plan — no retracing, no padding.
rng = np.random.RandomState(0)
ws = [(jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
       jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)) for _ in range(LAYERS)]
for (b, s) in [(2, 17), (5, 128), (3, 61)]:
    x = jnp.asarray(rng.randn(b, s, D), jnp.float32)
    loss, _ = opt(ws, x)
    peak = opt.last_report.stats.device_peak
    print(f"shape ({b:2d},{s:4d}): loss={float(loss):8.4f} peak={peak/2**20:6.2f} MiB")

# 4. Cap memory: the runtime evicts + rematerializes; numerics unchanged.
x = jnp.asarray(rng.randn(6, 256, D), jnp.float32)
loss_free, _ = opt(ws, x)
peak = opt.last_report.stats.device_peak
print(f"free-run peak at (6,256): {peak/2**20:.2f} MiB")
for frac in (0.8, 0.6, 0.45):
    capped = opt.with_memory_limit(int(peak * frac))
    try:
        loss_c, _ = capped(ws, x)
    except MemoryLimitExceeded:
        print(f"  {100*frac:3.0f}% cap: infeasible (single-op floor reached)")
        break
    st = capped.last_report.stats
    assert abs(float(loss_c) - float(loss_free)) < 1e-5
    print(f"  {100*frac:3.0f}% cap: peak={st.device_peak/2**20:6.2f} MiB  "
          f"evictions={st.evictions:3d} recomputes={st.recomputes:3d} "
          f"offloads={st.offloads:2d}  (numerics unchanged)")

# 5. Bounded dynamic shapes: declare dim ranges to resolve more scheduling
#    decisions symbolically and get a compile-time worst-case peak guarantee
#    (what a static-allocation backend would size its arena with).
opt_b = optimize(train_step, w_specs,
                 jax.ShapeDtypeStruct((B, S, D), jnp.float32),
                 dynamic_dims={"b": (1, 8), "s": "<=256"})
frac = opt_b.report.schedule.decision_symbolic_fraction
print(f"declared 1<=b<=8, s<=256: guaranteed peak <= "
      f"{opt_b.guaranteed_peak_bytes/2**20:.2f} MiB, "
      f"{100*frac:.1f}% of scheduling decisions symbolic")
x = jnp.asarray(rng.randn(8, 256, D), jnp.float32)
opt_b(ws, x)
assert opt_b.last_report.stats.device_peak <= opt_b.guaranteed_peak_bytes

# 6. Memory planner (memory_plan="arena", on by default): compile-time
#    buffer reuse over symbolic liveness.  Every run draws from a planned
#    arena — never bigger than the free-run peak — and with bounded dims
#    the arena size itself has a compile-time guarantee.
st = opt_b.last_report.stats
print(f"memory planner: arena={st.arena_bytes/2**20:.2f} MiB "
      f"(<= peak {st.device_peak/2**20:.2f} MiB) across {st.slots} slots, "
      f"reuse_ratio={st.reuse_ratio:.2f}, "
      f"guaranteed arena <= {opt_b.arena_bound_bytes/2**20:.2f} MiB")
assert st.arena_bytes <= st.device_peak
assert st.arena_bytes <= opt_b.arena_bound_bytes
