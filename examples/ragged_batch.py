"""Ragged batch serving: value-dependent bounded dims end to end.

A packed-sequence workload: requests arrive as a padded token batch plus
a validity mask, the model runs its expensive FFN only on the *valid*
rows.  How many rows are valid is decided by the input **values** — no
declared range can know it at compile time.  ``masked_select`` introduces
a fresh bounded dim ``b <= s``: the planner reserves its slots at the cap
(the only sound compile-time answer), and at runtime a ``BindDim`` step
publishes the measured extent so every later fit, free, and peak uses the
tight size.  Dispatch buckets on the *declared* dims; the bounded dim is
measured per call inside whichever bucket serves it.

    PYTHONPATH=src python examples/ragged_batch.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimize, symbolic_dim
from repro.kernels import masked_select

S = symbolic_dim("s")         # padded batch rows (declared, bucketed)
D, F = 32, 128

# 1. The serve step: select valid rows, run the wide FFN only on them.


def serve_step(x, mask, w1, w2):
    rows, n_valid = masked_select(x, mask)       # (b, D), b <= s: bounded
    h = jax.nn.gelu(rows @ w1)                   # (b, F): propagated
    y = h @ w2                                   # (b, D)
    return jnp.sum(y, axis=0), n_valid


specs = (jax.ShapeDtypeStruct((S, D), jnp.float32),
         jax.ShapeDtypeStruct((S,), jnp.bool_),
         jax.ShapeDtypeStruct((D, F), jnp.float32),
         jax.ShapeDtypeStruct((F, D), jnp.float32))

# 2. Compile once, bucketed on the declared dim.  The bounded dim never
#    appears in dynamic_dims — the input decides it, per call.
fn = optimize(serve_step, *specs, dynamic_dims={"s": (1, 512)},
              buckets="geometric")
g = fn.plan.graph
(bname, cap), = g.bound_dims.items()
print(f"traced: bounded dim {bname} <= {cap} "
      f"(reserve {fn.arena_bound_bytes / 2**10:.0f} KiB at the cap)")

# 3. Serve a ragged request stream: same padded size, wildly different
#    occupancy.  The measured extent is visible in MemoryStats, and the
#    peak tracks it — not the pad.
rng = np.random.RandomState(0)
w1 = jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32)
w2 = jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)

print(f"{'rows':>5} {'valid':>5} {'bucket':>7} {'measured':>9} "
      f"{'peak KiB':>9} {'arena KiB':>10}")
for s_rows, occ in [(48, 1.0), (48, 0.25), (300, 0.6), (300, 0.02),
                    (300, 0.0)]:
    x = jnp.asarray(rng.randn(s_rows, D), jnp.float32)
    mask = jnp.arange(s_rows) < int(round(s_rows * occ))
    out, n_valid = fn(x, mask, w1, w2)
    st = fn.last_report.stats
    measured = st.measured_dims[bname]
    assert measured == int(n_valid) == int(round(s_rows * occ))
    assert st.arena_bytes <= fn.arena_bound_bytes
    print(f"{s_rows:5d} {int(n_valid):5d} {str(fn.last_bucket):>7} "
          f"{measured:9d} {st.device_peak / 2**10:9.1f} "
          f"{st.arena_bytes / 2**10:10.1f}")

# 4. The tight accounting is the whole point: an almost-empty batch peaks
#    far below a full one of the same padded size.
peaks = {}
x = jnp.asarray(rng.randn(300, D), jnp.float32)
for occ in (1.0, 0.02):
    fn(x, jnp.arange(300) < int(300 * occ), w1, w2)
    peaks[occ] = fn.last_report.stats.device_peak
print(f"padded 300 rows: full-occupancy peak {peaks[1.0] / 2**10:.0f} KiB, "
      f"2%-occupancy peak {peaks[0.02] / 2**10:.0f} KiB "
      f"({peaks[0.02] / peaks[1.0]:.2f}x)")
assert peaks[0.02] < peaks[1.0]

# 5. And the plan stays honest: the replayed timeline at the measured env
#    audits clean against the compile-time liveness plan.
diff = fn.memory_timeline(fn.last_report.env)
assert diff.ok, diff.summary()
print(f"plan-vs-actual at the measured env: ok "
      f"({len(diff.actual.points)} instruction points, "
      f"0 unexplained allocations)")
