"""Fault-tolerance demo: kill a serving worker mid-stream, restore from
its checkpoint, and resume with bit-identical outputs.

A worker serves a deterministic stream of training requests through the
hardened ``BucketBatcher`` loop (structured ``RequestFailed`` outcomes,
no crash-on-failure), checkpointing its state every few requests via the
atomic :class:`~repro.checkpoint.Checkpointer`.  We then "lose" the
worker mid-serve, bring up a fresh one from the latest checkpoint, and
replay the remainder of the stream: the combined loss sequence and the
final parameters match an uninterrupted run bitwise — exact-once resume.

The coda restores the same checkpoint onto a different (shrunken) device
mesh: checkpoints store full logical arrays, so they re-shard onto any
topology, which is what makes the restart *elastic*.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import optimize, symbolic_dims
from repro.core.resilience import ResilienceConfig
from repro.launch.serve import BucketBatcher

B, S = symbolic_dims("b, s")
V, D, F = 300, 32, 64


def loss_fn(params, tokens, labels):
    emb = params["emb"][tokens]
    h = jax.nn.gelu(emb @ params["w1"])
    logits = (h @ params["w2"]) @ params["emb"].T
    logp = jax.nn.log_softmax(logits)
    oh = jax.nn.one_hot(labels, logits.shape[-1])
    return -(oh * logp).sum() / (1.0 * tokens.shape[0] * tokens.shape[1])


def train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    return loss, jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)


def init_params(seed=0):
    rng = np.random.RandomState(seed)
    return {"emb": jnp.asarray(rng.randn(V, D), jnp.float32),
            "w1": jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
            "w2": jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)}


def tokens_of(b, s, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)


def request_stream(n=10):
    """Deterministic shape-diverse request stream: (b, s, data seed)."""
    shapes = [(2, 24), (3, 48), (2, 16), (4, 40)]
    return [shapes[i % len(shapes)] + (i,) for i in range(n)]


def make_worker():
    """A fresh serving worker — what a restarted process would build.

    ``kernel_select=False`` keeps the compiled pipeline fully
    deterministic across restarts (measured selection could legitimately
    pick a different variant on the new host)."""
    return optimize(train_step,
                    {"emb": jax.ShapeDtypeStruct((V, D), jnp.float32),
                     "w1": jax.ShapeDtypeStruct((D, F), jnp.float32),
                     "w2": jax.ShapeDtypeStruct((F, D), jnp.float32)},
                    jax.ShapeDtypeStruct((B, S), jnp.int32),
                    jax.ShapeDtypeStruct((B, S), jnp.int32),
                    dynamic_dims={"b": (1, 8), "s": (8, 256)},
                    buckets={"s": [32, 256]},
                    kernel_select=False,
                    resilience=ResilienceConfig())


class WorkerKilled(RuntimeError):
    """The simulated mid-serve crash."""


def serve(requests, ck, params, *, start=0, ckpt_every=3, kill_at=None):
    """Serve ``requests[start:]`` on a fresh worker, checkpointing state
    + cursor every ``ckpt_every`` requests.  ``kill_at`` crashes the
    worker before that request is processed (the demo's fault).

    Returns ``(params, losses)`` where ``losses`` is ``[(request index,
    loss), ...]`` — each request is processed exactly once.
    """
    fn = make_worker()
    bat = BucketBatcher(fn)
    losses = []
    for i in range(start, len(requests)):
        if kill_at is not None and i == kill_at:
            raise WorkerKilled(f"worker lost before request {i}")
        b, s, seed = requests[i]
        bat.submit({"b": b, "s": s},
                   payload=(params, tokens_of(b, s, seed),
                            tokens_of(b, s, seed + 1)))
        [outcome] = bat.process()
        if not outcome["ok"]:              # structured, not a crash
            print(f"request {i} failed structurally: {outcome['error']}")
            continue
        loss, params = outcome["value"]
        losses.append((i, np.asarray(loss)))
        if (i + 1) % ckpt_every == 0:
            ck.save(i + 1, {"params": params}, extra={"cursor": i + 1})
    return params, losses


def resume(requests, ck, **kw):
    """Restore the latest checkpoint and serve the rest of the stream."""
    cursor, state, extra = ck.restore()
    assert extra["cursor"] == cursor
    return serve(requests, ck, state["params"], start=cursor, **kw)


def main():
    requests = request_stream(10)
    with tempfile.TemporaryDirectory() as ref_dir, \
            tempfile.TemporaryDirectory() as d:
        # uninterrupted reference
        ref_params, ref_losses = serve(requests, Checkpointer(ref_dir),
                                       init_params())
        # the same stream, crashed at request 7 (checkpoint landed at 6)
        ck = Checkpointer(d)
        try:
            serve(requests, ck, init_params(), kill_at=7)
        except WorkerKilled as e:
            print(f"crash: {e}")
        res_params, res_losses = resume(requests, ck)
        print(f"restored at cursor {ck.latest_step()}, replayed "
              f"{len(res_losses)} requests")

        # exact-once, bit-exact: the resumed tail matches the reference
        tail = dict(ref_losses)
        assert all(np.array_equal(tail[i], l) for i, l in res_losses)
        assert all(np.array_equal(a, b) for a, b in
                   zip(jax.tree.leaves(ref_params),
                       jax.tree.leaves(res_params)))
        print("resumed outputs match the uninterrupted run bitwise: True")

        # elastic restore onto a different mesh: checkpoints store full
        # logical arrays, so they re-shard onto any device topology
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.sharding import ShardingRules
        step, state, _ = ck.restore()
        mesh = make_debug_mesh(1, 1)       # the "new" (shrunken) cluster
        rules = ShardingRules(mesh)
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            state["params"])
        shardings = rules.named(rules.params_pspecs(shapes))
        resharded = jax.tree.map(jax.device_put, state["params"], shardings)
        assert all(isinstance(x, jax.Array)
                   for x in jax.tree.leaves(resharded))
        print(f"elastic restore at step {step}: params resharded onto "
              f"{mesh.devices.size}-device mesh OK")


if __name__ == "__main__":
    main()
