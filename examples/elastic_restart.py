"""Fault-tolerance demo: train, 'lose' the job, resume bit-exact from the
checkpoint — then restore the same checkpoint onto a different mesh
(elastic re-sharding), as a 1000-node cluster would after losing hosts.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.train import train

cfg = get_smoke_config("llama2-1b")
with tempfile.TemporaryDirectory() as d:
    # uninterrupted reference
    ref = train(cfg, steps=10, batch_size=4, log_every=100)
    # crash after 5 steps (checkpoint taken), resume to 10
    train(cfg, steps=5, batch_size=4, ckpt_dir=d, ckpt_every=5, log_every=100)
    resumed = train(cfg, steps=10, batch_size=4, ckpt_dir=d, ckpt_every=5,
                    log_every=100)
    exact = np.allclose(ref["losses"][5:], resumed["losses"], rtol=1e-5)
    print(f"resume losses match uninterrupted run: {exact}")

    # elastic restore onto a different mesh: checkpoints store full logical
    # arrays, so they re-shard onto any device topology
    ck = Checkpointer(d)
    step, state, extra = ck.restore()
    mesh = make_debug_mesh(1, 1)  # the "new" (shrunken) cluster
    rules = ShardingRules(mesh)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          state["params"])
    shardings = rules.named(rules.params_pspecs(shapes))
    resharded = jax.tree.map(jax.device_put, state["params"], shardings)
    assert all(isinstance(x, jax.Array) for x in jax.tree.leaves(resharded))
    print(f"elastic restore at step {step}: params resharded onto "
          f"{mesh.devices.size}-device mesh OK")
