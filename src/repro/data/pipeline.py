"""Variable-length data pipeline (the paper's dynamic-shape workload).

Synthesizes a CodeAlpaca-20K-like length distribution (samples of ~100–3000
characters ≈ 25–750 tokens, log-uniform) with a fixed seed.  Two batching
modes reproduce the paper's comparison:

  * ``dynamic``  — fixed sample count per batch, sequences packed to the
    batch max length WITHOUT padding buckets: every iteration has a
    different (B, S) — the dynamic-shape regime.
  * ``bucketed`` — static-shape regime: lengths padded up to the nearest
    power of two (largest bucket = dataset max, as in the paper §3).

The pipeline is deterministic and resumable: ``state()`` / ``restore()``
give the exact cursor for checkpoint-restart.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    batch_size: int = 14
    min_tokens: int = 25
    max_tokens: int = 750
    n_samples: int = 20_000
    seed: int = 0
    mode: str = "dynamic"          # dynamic | bucketed
    pad_id: int = 0
    align: int = 8                 # dynamic mode: round max-len up (tile-friendly)


class DataPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        # log-uniform lengths ~ chars 100..3000 mapped to tokens
        u = rng.uniform(np.log(cfg.min_tokens), np.log(cfg.max_tokens),
                        size=cfg.n_samples)
        self._lengths = np.exp(u).astype(np.int64)
        self._order = rng.permutation(cfg.n_samples)
        self._cursor = 0
        self._epoch = 0
        self._rng_tokens = np.random.RandomState(cfg.seed + 1)

    # -- resumable state ---------------------------------------------------------
    def state(self) -> Dict:
        return {"cursor": int(self._cursor), "epoch": int(self._epoch),
                "seed": self.cfg.seed}

    def restore(self, state: Dict) -> None:
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self._cursor = state["cursor"]
        self._epoch = state["epoch"]

    # -- batching -------------------------------------------------------------------
    @staticmethod
    def bucket_len(n: int) -> int:
        p = 1
        while p < n:
            p <<= 1
        return p

    def _next_indices(self) -> np.ndarray:
        b = self.cfg.batch_size
        if self._cursor + b > len(self._order):
            self._epoch += 1
            rng = np.random.RandomState(self.cfg.seed + 7 + self._epoch)
            self._order = rng.permutation(self.cfg.n_samples)
            self._cursor = 0
        idx = self._order[self._cursor:self._cursor + b]
        self._cursor += b
        return idx

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        idx = self._next_indices()
        lens = self._lengths[idx]
        maxlen = int(lens.max())
        if cfg.mode == "bucketed":
            maxlen = self.bucket_len(maxlen)
        else:
            a = cfg.align
            maxlen = -(-maxlen // a) * a
        toks = np.full((cfg.batch_size, maxlen), cfg.pad_id, np.int32)
        mask = np.zeros((cfg.batch_size, maxlen), np.float32)
        for r, (i, L) in enumerate(zip(idx, lens)):
            rs = np.random.RandomState(int(self.cfg.seed + 13 + i))
            toks[r, :L] = rs.randint(1, cfg.vocab, size=int(L))
            mask[r, :L] = 1.0
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = cfg.pad_id
        lmask = mask.copy()
        lmask[:, -1] = 0.0
        return {"tokens": toks, "labels": labels, "mask": lmask,
                "lengths": lens.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # -- stats (used by benchmarks) ----------------------------------------------
    def padding_waste(self, n_batches: int = 200) -> Tuple[float, float]:
        """Returns (dynamic_waste, bucketed_waste) as padded-token fractions."""
        saved = self.state()
        dyn = buck = total_d = total_b = 0
        for _ in range(n_batches):
            idx = self._next_indices()
            lens = self._lengths[idx]
            m = int(lens.max())
            a = self.cfg.align
            md = -(-m // a) * a
            mb = self.bucket_len(m)
            dyn += md * len(lens) - lens.sum()
            total_d += md * len(lens)
            buck += mb * len(lens) - lens.sum()
            total_b += mb * len(lens)
        self.restore(saved)
        return dyn / total_d, buck / total_b
