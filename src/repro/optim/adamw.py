"""AdamW with decoupled weight decay and global-norm clipping (pure JAX)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # bf16 option for trillion-class configs


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_state(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, state: AdamWState,
                  cfg: AdamWConfig = AdamWConfig()) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - cfg.lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.state_dtype),
                v_new.astype(cfg.state_dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
