from .adamw import AdamWConfig, AdamWState, apply_updates, global_norm, init_state

__all__ = ["AdamWConfig", "AdamWState", "apply_updates", "global_norm", "init_state"]
