"""Fault-tolerant checkpointing with elastic (re-shardable) restore.

Design for 1000+-node operation:

  * **atomic**: write to a temp dir, fsync, rename — a crash mid-save never
    corrupts the latest checkpoint;
  * **async**: saves run on a background thread off the training loop;
  * **keep-N** garbage collection;
  * **elastic restore**: arrays are stored as full logical tensors plus a
    sharding-spec sidecar, so a checkpoint taken on one mesh restores onto
    any other mesh/device-count (device_put with the new sharding);
  * **data-pipeline cursor** and optimizer step are saved alongside, giving
    exact-once resume semantics.

On a real multi-host pod each host writes its owned shards
(process-local addressable data); in this single-process container the
logical-array path is exercised end-to-end.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax import tree_util


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat, _ = tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------------
    def save(self, step: int, state: Any, *, extra: Optional[Dict] = None,
             blocking: bool = True) -> None:
        """Snapshot `state` (pytree of arrays) at `step`."""
        # materialize to host np *before* returning control (consistent snapshot)
        leaves = _flatten_with_names(state)
        host = [(n, np.asarray(x)) for n, x in leaves]
        treedef = tree_util.tree_structure(state)
        extra = dict(extra or {})

        def _write():
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
            try:
                for name, arr in host:
                    fn = os.path.join(tmp, name.replace("/", "__") + ".npy")
                    np.save(fn, arr)
                meta = {
                    "step": step,
                    "names": [n for n, _ in host],
                    "treedef": str(treedef),
                    "extra": extra,
                    "time": time.time(),
                }
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
                    pickle.dump(treedef, f)
                final = os.path.join(self.dir, f"step_{step:010d}")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)  # atomic publish
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e
                shutil.rmtree(tmp, ignore_errors=True)

        if blocking:
            _write()
            self._raise_if_failed()
        else:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err}") from err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *,
                shardings: Any = None) -> Tuple[int, Any, Dict]:
        """Load (step, state, extra).  ``shardings``: optional pytree of
        NamedSharding for elastic restore onto a (possibly different) mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        with open(os.path.join(d, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        leaves = []
        for name in meta["names"]:
            fn = os.path.join(d, name.replace("/", "__") + ".npy")
            leaves.append(np.load(fn))
        state = tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                state, shardings,
                is_leaf=lambda x: isinstance(x, np.ndarray))
        return meta["step"], state, meta.get("extra", {})
