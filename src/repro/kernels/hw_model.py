"""The hardware model: one set of machine constants for every consumer.

``benchmarks/roofline.py`` (arch-level roofline terms) and
``kernels/variants.py`` (the per-kernel analytical cost model) must agree
on what the machine can do — peak FLOP rate, HBM bandwidth, VMEM
capacity, MXU/VPU geometry — or a kernel the cost model calls
compute-bound would look memory-bound in the roofline table.  Both import
from here; nothing else in the repo hard-codes a TFLOP/s.

The defaults describe a TPU v5e-class chip (the target the Pallas
kernels are tiled for):

* one MXU of 128x128 ALUs — matmul operands want every contracting /
  non-contracting tile dimension at (a multiple of) 128;
* a VPU of (8, 128) lanes for elementwise work;
* ~16 MiB of VMEM per core, shared by every in-flight block and the
  pipeline's double buffers — the cost model's *validity* constraint;
* per-``pallas_call`` launch overhead, the constant that makes the
  reference implementation win for degenerate shapes.

Values are per chip.  ``HardwareModel`` is a frozen dataclass so a test
(or a different deployment target) can carry its own instance; module
attributes ``PEAK_FLOPS`` / ``HBM_BW`` / ``LINK_BW`` keep the names the
roofline benchmark has always exported.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class HardwareModel:
    """Per-chip machine constants consumed by cost model + roofline."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 MXU FLOP/s
    vpu_flops: float = 12.3e12       # f32 elementwise FLOP/s (8x128 VPU)
    hbm_bw: float = 819e9            # HBM bytes/s
    link_bw: float = 50e9            # ICI bytes/s per link
    vmem_bytes: int = 16 * 2**20     # usable VMEM per core
    mxu_dim: int = 128               # systolic array edge
    vpu_sublanes: int = 8            # VREG is (8, 128)
    vpu_lanes: int = 128
    # fixed cost of entering a pallas_call (grid setup, prologue DMAs);
    # the reference implementation instead pays one fused-XLA dispatch
    kernel_launch_s: float = 2e-6
    xla_dispatch_s: float = 5e-7
    # per-grid-step sequencing overhead (scalar core bookkeeping + DMA
    # issue between steps that the pipeline cannot fully hide)
    grid_step_s: float = 5e-9

    def with_vmem(self, vmem_bytes: int) -> "HardwareModel":
        """The same chip with a different VMEM budget (tests/property
        checks shrink it to watch the valid variant set contract)."""
        return replace(self, vmem_bytes=vmem_bytes)


DEFAULT_HW = HardwareModel()

# legacy module-level names (roofline's original constants)
PEAK_FLOPS = DEFAULT_HW.peak_flops
HBM_BW = DEFAULT_HW.hbm_bw
LINK_BW = DEFAULT_HW.link_bw


def mxu_efficiency(hw: HardwareModel, *tile_dims: int) -> float:
    """Fraction of MXU peak a matmul with these tile dims can sustain.

    Each dimension below the systolic edge wastes the proportional slice
    of the array (a 64-wide operand occupies half the 128 columns); full
    multiples are free.  Dims are clamped to [1, mxu_dim] before the
    ratio, so 256 is as good as 128 — alignment, not size, is what pays.
    """
    eff = 1.0
    for d in tile_dims:
        d = max(1, min(int(d), hw.mxu_dim))
        eff *= d / hw.mxu_dim
    return max(eff, 1e-6)
