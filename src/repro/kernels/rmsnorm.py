"""Pallas TPU fused RMSNorm kernel.

One grid step normalizes a (BLOCK_ROWS × d) tile held in VMEM: the
mean-of-squares reduction runs in fp32 on the VPU, the scale multiply fuses
into the same pass — one HBM read + one write per element (unfused JAX does
~3 passes).  d is padded to a lane multiple (128) by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float, d_valid: int):
    x = x_ref[...].astype(jnp.float32)             # (R, D)
    d = x.shape[-1]
    if d_valid != d:  # zero-padded tail: exclude from the mean
        lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(lane < d_valid, x, 0.0)
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d_valid
    y = x * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + s_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    d_pad = -(-d // 128) * 128
    if d_pad != d:
        xf = jnp.pad(xf, [(0, 0), (0, d_pad - d)])
        scale_p = jnp.pad(scale, (0, d_pad - d))
    else:
        scale_p = scale
    block_rows = min(block_rows, n)
    n_pad = -(-n // block_rows) * block_rows
    if n_pad != n:
        xf = jnp.pad(xf, [(0, n_pad - n), (0, 0)])

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d_valid=d),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((d_pad,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_pad), x.dtype),
        interpret=interpret,
    )(xf, scale_p)
    return out[:n, :d].reshape(orig_shape)
