"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        softmax_scale: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, S, hd); k/v: (B, Hkv, T, hd).  Dense softmax attention."""
    b, hq, s, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, hkv, g, s, hd).astype(jnp.float32) * scale
    sc = jnp.einsum("bhgsd,bhtd->bhgst", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, s, hd).astype(q.dtype)


def reference_rmsnorm(x: jax.Array, scale: jax.Array,
                      eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)
