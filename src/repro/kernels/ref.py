"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        softmax_scale: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, S, hd); k/v: (B, Hkv, T, hd).  Dense softmax attention."""
    b, hq, s, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, hkv, g, s, hd).astype(jnp.float32) * scale
    sc = jnp.einsum("bhgsd,bhtd->bhgst", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, s, hd).astype(q.dtype)


def reference_rmsnorm(x: jax.Array, scale: jax.Array,
                      eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Padded-to-bound oracles for the value-dependent ops (``kernels.ops``):
# every output keeps the *input's* static shape (the bound), the valid
# prefix holds the result, the tail is zeros, and an i32 count scalar
# reports the measured extent.  Pure jnp, fixed shapes — usable both as
# the eager impl of the primitives and as the allclose ground truth.
# ---------------------------------------------------------------------------


def _keep_prefix(x: jax.Array, count: jax.Array) -> jax.Array:
    """Zero out rows at index >= count (rows = leading axis)."""
    n = x.shape[0]
    keep = jnp.arange(n) < count
    keep = keep.reshape((n,) + (1,) * (x.ndim - 1))
    return jnp.where(keep, x, jnp.zeros_like(x))


def reference_nonzero_pad(x: jax.Array):
    """Indices of nonzero entries of 1-D ``x``, zero-padded to len(x)."""
    n = x.shape[0]
    nz = x != 0
    idx = jnp.nonzero(nz, size=n, fill_value=0)[0].astype(jnp.int32)
    return idx, jnp.sum(nz).astype(jnp.int32)


def reference_masked_select(x: jax.Array, mask: jax.Array):
    """Rows of ``x`` where 1-D ``mask`` holds, compacted to the front."""
    count = jnp.sum(mask).astype(jnp.int32)
    perm = jnp.argsort(~mask)          # stable: kept rows keep their order
    return _keep_prefix(x[perm], count), count


def reference_topk_dynamic(x: jax.Array, k: jax.Array):
    """Largest ``k`` values of 1-D ``x`` (k data-dependent), descending."""
    count = jnp.clip(k.astype(jnp.int32), 0, x.shape[0])
    return _keep_prefix(jnp.sort(x)[::-1], count), count


def reference_unique_bounded(x: jax.Array):
    """Sorted distinct values of 1-D ``x``, zero-padded to len(x)."""
    s = jnp.sort(x)
    isnew = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), s[1:] != s[:-1]])
    count = jnp.sum(isnew).astype(jnp.int32)
    return _keep_prefix(s[jnp.argsort(~isnew)], count), count
