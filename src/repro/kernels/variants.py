"""Kernel-variant registry + the hardware-aware analytical cost model.

Buckets specialize *memory plans*; this module is what lets them
specialize *kernels* too.  Every selectable primitive registers a table
of variants — Pallas block configurations at several sizes and pipeline
depths, plus the dense reference implementation — and a cost function
that prices one variant at one concrete shape from the
:class:`~repro.kernels.hw_model.HardwareModel` constants:

* **MXU / VPU time** — FLOPs over the sustained rate, discounted by
  :func:`~repro.kernels.hw_model.mxu_efficiency` for tiles below the
  128-wide systolic edge;
* **HBM time** — bytes moved, including padding copies and the
  K/V-revisit traffic that shrinks as blocks grow;
* **fixed overhead** — per-``pallas_call`` launch vs per-XLA-dispatch
  cost, the term that makes the reference implementation win degenerate
  shapes (Vortex's sample-free, hierarchized strategy space: prune by
  hardware constraints, rank analytically, never autotune on-device);
* **VMEM footprint** — the *validity* constraint: a variant whose
  double-buffered working set cannot fit VMEM at any in-range shape is
  never selected for that range.

Selection happens per compiled plan (:func:`select_kernels`): a kernel
node's dims are bounded by the plan's ``ShapeGraph`` intervals — a
bucket's narrowed ranges, or the whole declared range for the fallback
plan — the cost model scores every valid variant at the range's lo /
geometric-mid / hi corners, and the cheapest total wins.  Validity is
judged at the range's *upper* corner (footprints are monotone in every
dim), so the whole-range fallback can never adopt a variant that some
in-range shape would overflow; an unbounded dim that a Pallas footprint
depends on simply rules the Pallas variants out, leaving the always-valid
reference implementation.

The winning variant's parameter overrides are baked into the lowered
``Compute`` instruction at lowering time — the VM hot path never
branches on shape — and the scores surface as ``kernel-select`` entries
in the :class:`~repro.core.obs.DecisionLog`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, \
    Tuple

from .hw_model import DEFAULT_HW, HardwareModel, mxu_efficiency

# dims the cost model probes when a range has no upper bound: a heuristic
# *pricing* point only — validity never relies on it (unbounded Pallas
# footprints are simply invalid)
_UNBOUNDED_PROBE = 4096


# ---------------------------------------------------------------------------
# variant + cost containers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelVariant:
    """One selectable configuration of a kernel primitive.

    ``block`` holds the primitive's block-size parameters as sorted
    name/value pairs (hashable); ``pipeline_depth`` is the multiple-
    buffering factor the cost model charges VMEM for (Pallas TPU double
    buffers in/out blocks by default — depth 1 models the serial
    fallback that halves the footprint when depth 2 cannot fit)."""

    name: str
    impl: str                                   # 'pallas' | 'ref'
    block: Tuple[Tuple[str, int], ...] = ()
    pipeline_depth: int = 2

    def overrides(self) -> Dict[str, Any]:
        """The node-param overrides that realize this variant."""
        return {"impl": self.impl, "pipeline_depth": self.pipeline_depth,
                **dict(self.block)}

    def block_of(self, name: str, default: int = 0) -> int:
        return dict(self.block).get(name, default)


@dataclass(frozen=True)
class VariantCost:
    """One variant priced at one concrete shape."""

    time_s: float
    flops: float
    hbm_bytes: float
    vmem_bytes: int          # working-set footprint (0 for HBM-resident ref)
    util: float              # sustained fraction of the unit's peak


@dataclass
class KernelSelection:
    """The outcome of selecting one kernel node over one shape range."""

    node_id: int
    prim_name: str
    variant: KernelVariant
    default: KernelVariant
    scores: Dict[str, float]                 # variant name -> summed time_s
    bounds: Dict[str, Tuple[int, Optional[int]]]  # dim label -> (lo, hi)
    probes: List[Dict[str, int]] = field(default_factory=list)
    invalid: Tuple[str, ...] = ()            # variants VMEM ruled out
    measured: bool = False                   # True after a measured re-select

    @property
    def is_default(self) -> bool:
        return self.variant.name == self.default.name

    @property
    def model_speedup(self) -> float:
        """Predicted default-time / selected-time over the probe corners."""
        sel = self.scores.get(self.variant.name, 0.0)
        def_ = self.scores.get(self.default.name, sel)
        return def_ / sel if sel > 0 else 1.0

    def describe_bounds(self) -> str:
        parts = []
        for name, (lo, hi) in self.bounds.items():
            parts.append(f"{name}∈[{lo},{'∞' if hi is None else hi}]")
        return " ".join(parts)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# prim name -> (variants, default, cost_fn, shape_fn)
#   cost_fn(variant, shapes, itemsize, params, hw) -> VariantCost
#   shape_fn(node_dims) -> the dim-label map the cost model prices
_REGISTRY: Dict[str, Dict[str, Any]] = {}


def register_kernel(prim_name: str, variants: Sequence[KernelVariant],
                    default: KernelVariant,
                    cost_fn: Callable[..., VariantCost]) -> None:
    if default.name not in {v.name for v in variants}:
        raise ValueError(f"default variant {default.name!r} not in the "
                         f"{prim_name} registry")
    _REGISTRY[prim_name] = dict(variants=tuple(variants), default=default,
                                cost=cost_fn)


def variants_for(prim_name: str) -> Tuple[KernelVariant, ...]:
    return _REGISTRY[prim_name]["variants"]


def default_variant(prim_name: str) -> KernelVariant:
    return _REGISTRY[prim_name]["default"]


def is_selectable(prim_name: str) -> bool:
    return prim_name in _REGISTRY


def registered_kernels() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


# ---------------------------------------------------------------------------
# tile / footprint helpers
# ---------------------------------------------------------------------------


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _tile_bytes(rows: int, cols: int, itemsize: int,
                hw: HardwareModel) -> int:
    """VMEM bytes of one (rows, cols) tile after min-tile padding.

    The second-minor dim pads to the sublane count, the minor dim to the
    128-lane width — a (block_q, 1) f32 accumulator still occupies
    (block_q, 128) lanes of VMEM."""
    return (_ceil_to(max(rows, 1), hw.vpu_sublanes)
            * _ceil_to(max(cols, 1), hw.vpu_lanes) * itemsize)


def flash_vmem_bytes(variant: KernelVariant, s_hi: Optional[int],
                     t_hi: Optional[int], hd: Optional[int], itemsize: int,
                     hw: HardwareModel) -> Optional[int]:
    """Worst-case VMEM working set of a flash-attention variant.

    Block dims self-bound (``min(block, s)`` never exceeds the block), so
    unbounded s/t stay sound; an unbounded head dim cannot be bounded at
    all — ``None`` (treated as invalid for Pallas)."""
    if variant.impl == "ref":
        return 0
    if hd is None:
        return None
    bq = variant.block_of("block_q", 128)
    bkv = variant.block_of("block_kv", 128)
    if s_hi is not None:
        bq = min(bq, max(s_hi, 1))
    if t_hi is not None:
        bkv = min(bkv, max(t_hi, 1))
    io = (_tile_bytes(bq, hd, itemsize, hw)          # Q block
          + 2 * _tile_bytes(bkv, hd, itemsize, hw)   # K + V blocks
          + _tile_bytes(bq, hd, itemsize, hw))       # O block
    scratch = (2 * _tile_bytes(bq, 1, 4, hw)         # m, l (f32)
               + _tile_bytes(bq, hd, 4, hw))         # acc (f32)
    return variant.pipeline_depth * io + scratch


def rmsnorm_vmem_bytes(variant: KernelVariant, n_hi: Optional[int],
                       d: Optional[int], itemsize: int,
                       hw: HardwareModel) -> Optional[int]:
    if variant.impl == "ref":
        return 0
    if d is None:
        return None
    br = variant.block_of("block_rows", 256)
    if n_hi is not None:
        br = min(br, max(n_hi, 1))
    d_pad = _ceil_to(d, hw.vpu_lanes)
    io = 2 * _tile_bytes(br, d_pad, itemsize, hw)    # x + out blocks
    scratch = (_tile_bytes(1, d_pad, itemsize, hw)   # scale row
               + _tile_bytes(br, d_pad, 4, hw))      # f32 working copy
    return variant.pipeline_depth * io + scratch


def variant_vmem_bytes(prim_name: str, variant: KernelVariant,
                       hi_shape: Mapping[str, Optional[int]], itemsize: int,
                       hw: HardwareModel = DEFAULT_HW) -> Optional[int]:
    """Worst-case footprint over a range's upper corner (``None`` dims =
    unbounded).  The validity predicate is ``footprint <= hw.vmem_bytes``
    with ``None`` meaning unboundable → invalid."""
    if prim_name == "flash_attention":
        return flash_vmem_bytes(variant, hi_shape.get("s"), hi_shape.get("t"),
                                hi_shape.get("hd"), itemsize, hw)
    if prim_name == "rmsnorm":
        return rmsnorm_vmem_bytes(variant, hi_shape.get("n"),
                                  hi_shape.get("d"), itemsize, hw)
    raise KeyError(prim_name)


def variant_valid(prim_name: str, variant: KernelVariant,
                  hi_shape: Mapping[str, Optional[int]], itemsize: int,
                  hw: HardwareModel = DEFAULT_HW) -> bool:
    vm = variant_vmem_bytes(prim_name, variant, hi_shape, itemsize, hw)
    return vm is not None and vm <= hw.vmem_bytes


# ---------------------------------------------------------------------------
# cost models
# ---------------------------------------------------------------------------


def _causal_block_pairs(nq: int, nk: int, bq: int, bkv: int) -> int:
    """Blocks the causal kernel actually runs: for q block ``qi`` only kv
    blocks at or below the diagonal contribute (the kernel's ``pl.when``
    skip), ≈ half the grid for square shapes."""
    total = 0
    for qi in range(nq):
        total += min(nk, (qi * bq + bq - 1) // bkv + 1)
    return total


def flash_cost(variant: KernelVariant, shape: Mapping[str, int],
               itemsize: int, params: Mapping[str, Any],
               hw: HardwareModel = DEFAULT_HW) -> VariantCost:
    """Price one flash-attention variant at one concrete shape."""
    b, hq = shape["b"], shape["hq"]
    s, t, hd = shape["s"], shape["t"], shape["hd"]
    causal = bool(params.get("causal", True))
    if variant.impl == "ref":
        # dense: full S×T scores, no causal block skipping; the score
        # matrix round-trips HBM only once it outgrows VMEM — below that
        # it stays on-chip and the dense path is pure fixed-cost
        flops_mxu = b * hq * 4.0 * s * t * hd
        flops_vpu = b * hq * 8.0 * s * t
        eff = mxu_efficiency(hw, hd, t)
        scores_b = b * hq * s * t * 4
        hbm = ((b * hq * 2 * s * hd + 2 * b * hq * t * hd) * itemsize
               + (3 * scores_b if scores_b > hw.vmem_bytes else 0))
        compute_s = flops_mxu / (hw.peak_flops * eff) + flops_vpu / hw.vpu_flops
        time = max(compute_s, hbm / hw.hbm_bw) + 3 * hw.xla_dispatch_s
        util = compute_s / time if time > 0 else 0.0
        return VariantCost(time, flops_mxu + flops_vpu, hbm, 0, util)

    bq = min(variant.block_of("block_q", 128), s)
    bkv = min(variant.block_of("block_kv", 128), t)
    s_pad, t_pad = _ceil_to(s, bq), _ceil_to(t, bkv)
    nq, nk = s_pad // bq, t_pad // bkv
    pairs = _causal_block_pairs(nq, nk, bq, bkv) if causal else nq * nk
    flops_mxu = b * hq * pairs * 4.0 * bq * bkv * hd
    flops_vpu = b * hq * pairs * 6.0 * bq * bkv
    eff = mxu_efficiency(hw, hd, bkv)
    # Q/O stream once; K/V tiles re-stream once per visiting q block —
    # the revisit traffic is what larger q blocks buy down
    hbm = (2 * b * hq * s_pad * hd + b * hq * pairs * 2 * bkv * hd) * itemsize
    compute_s = flops_mxu / (hw.peak_flops * eff) + flops_vpu / hw.vpu_flops
    grid = b * hq * nq * nk
    time = max(compute_s, hbm / hw.hbm_bw) \
        + hw.kernel_launch_s + grid * hw.grid_step_s
    util = compute_s / time if time > 0 else 0.0
    vm = flash_vmem_bytes(variant, s, t, hd, itemsize, hw) or 0
    return VariantCost(time, flops_mxu + flops_vpu, hbm, vm, util)


def rmsnorm_cost(variant: KernelVariant, shape: Mapping[str, int],
                 itemsize: int, params: Mapping[str, Any],
                 hw: HardwareModel = DEFAULT_HW) -> VariantCost:
    """Price one rmsnorm variant at one concrete shape (n rows × d)."""
    n, d = shape["n"], shape["d"]
    if variant.impl == "ref":
        # unfused jnp: ~3 passes over the (n, d) activation, no padding
        flops = 4.0 * n * d
        hbm = 6 * n * d * itemsize
        compute_s = flops / hw.vpu_flops
        time = max(compute_s, hbm / hw.hbm_bw) + 3 * hw.xla_dispatch_s
        return VariantCost(time, flops, hbm, 0,
                           compute_s / time if time > 0 else 0.0)

    br = min(variant.block_of("block_rows", 256), n)
    d_pad = _ceil_to(d, hw.vpu_lanes)
    n_pad = _ceil_to(n, br)
    flops = 4.0 * n_pad * d_pad
    # fused kernel: one read + one write per (padded) element — plus the
    # wrapper's pad/unpad copies whenever d or n is not tile-aligned,
    # the traffic that makes tiny-d Pallas strictly worse than ref
    hbm = 2 * n_pad * d_pad * itemsize
    if d_pad != d or n_pad != n:
        hbm += (n * d + n_pad * d_pad) * itemsize      # pad copy
        hbm += (n_pad * d_pad + n * d) * itemsize      # unpad slice
    compute_s = flops / hw.vpu_flops
    grid = n_pad // br
    time = max(compute_s, hbm / hw.hbm_bw) \
        + hw.kernel_launch_s + grid * hw.grid_step_s
    vm = rmsnorm_vmem_bytes(variant, n, d, itemsize, hw) or 0
    return VariantCost(time, flops, hbm, vm,
                       compute_s / time if time > 0 else 0.0)


# ---------------------------------------------------------------------------
# the built-in variant tables
# ---------------------------------------------------------------------------


def _fa_variant(bq: int, bkv: int, depth: int = 2) -> KernelVariant:
    suffix = "" if depth == 2 else f"_d{depth}"
    return KernelVariant(name=f"pallas_{bq}x{bkv}{suffix}", impl="pallas",
                         block=(("block_kv", bkv), ("block_q", bq)),
                         pipeline_depth=depth)


FLASH_DEFAULT = _fa_variant(128, 128)
FLASH_VARIANTS: Tuple[KernelVariant, ...] = (
    FLASH_DEFAULT,
    _fa_variant(256, 256),
    _fa_variant(512, 256),
    _fa_variant(64, 64),
    _fa_variant(128, 128, depth=1),     # halved buffering for fat head dims
    KernelVariant(name="ref_dense", impl="ref"),
)


def _rn_variant(rows: int, depth: int = 2) -> KernelVariant:
    suffix = "" if depth == 2 else f"_d{depth}"
    return KernelVariant(name=f"pallas_r{rows}{suffix}", impl="pallas",
                         block=(("block_rows", rows),), pipeline_depth=depth)


RMSNORM_DEFAULT = _rn_variant(256)
RMSNORM_VARIANTS: Tuple[KernelVariant, ...] = (
    RMSNORM_DEFAULT,
    _rn_variant(1024),
    _rn_variant(64),
    _rn_variant(256, depth=1),
    KernelVariant(name="ref_unfused", impl="ref"),
)

register_kernel("flash_attention", FLASH_VARIANTS, FLASH_DEFAULT, flash_cost)
register_kernel("rmsnorm", RMSNORM_VARIANTS, RMSNORM_DEFAULT, rmsnorm_cost)


# ---------------------------------------------------------------------------
# shape extraction: kernel node dims -> the labels the cost model prices
# ---------------------------------------------------------------------------


def _node_dim_exprs(prim_name: str, node) -> Dict[str, Any]:
    """Map a kernel node's input dim exprs to cost-model labels."""
    if prim_name == "flash_attention":
        q, k = node.invals[0], node.invals[1]
        b, hq, s, hd = q.dims
        t = k.dims[2]
        return {"b": b, "hq": hq, "s": s, "t": t, "hd": hd}
    if prim_name == "rmsnorm":
        x = node.invals[0]
        lead, d = x.dims[:-1], x.dims[-1]
        n = None
        for e in lead:
            n = e if n is None else n * e
        return {"n": n if n is not None else 1, "d": d}
    raise KeyError(prim_name)


def _expr_bounds(expr, sg) -> Tuple[int, Optional[int]]:
    """(lo, hi) of one dim expression under the plan's shape graph."""
    if isinstance(expr, int):
        return expr, expr
    iv = sg.interval_of(expr)
    lo = iv.lo if iv.lo is not None and iv.lo >= 1 else 1
    return lo, iv.hi


def _probe_shapes(bounds: Mapping[str, Tuple[int, Optional[int]]]
                  ) -> List[Dict[str, int]]:
    """lo / geometric-mid / hi pricing corners (deduplicated)."""
    los = {k: lo for k, (lo, _hi) in bounds.items()}
    his = {k: hi if hi is not None else max(lo, _UNBOUNDED_PROBE)
           for k, (lo, hi) in bounds.items()}
    mids = {k: max(1, int(math.isqrt(los[k] * his[k]))) for k in bounds}
    probes, seen = [], set()
    for p in (los, mids, his):
        key = tuple(sorted(p.items()))
        if key not in seen:
            seen.add(key)
            probes.append(dict(p))
    return probes


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


def select_variant(prim_name: str,
                   bounds: Mapping[str, Tuple[int, Optional[int]]],
                   itemsize: int, params: Mapping[str, Any],
                   hw: HardwareModel = DEFAULT_HW,
                   forced: Optional[str] = None
                   ) -> Tuple[KernelVariant, Dict[str, float], List[Dict[str, int]], Tuple[str, ...]]:
    """Pick the cheapest VMEM-valid variant over one shape range.

    Returns ``(variant, scores, probes, invalid_names)``.  Validity is
    judged at the range's hi corner (``None`` = unbounded, sound because
    footprints are monotone in every dim); scores sum the model time over
    the lo/mid/hi pricing corners.  ``forced`` pins a variant by name
    (measured re-selection) — it must still be valid."""
    entry = _REGISTRY[prim_name]
    hi_shape = {k: hi for k, (_lo, hi) in bounds.items()}
    probes = _probe_shapes(bounds)
    scores: Dict[str, float] = {}
    invalid: List[str] = []
    valid: List[KernelVariant] = []
    for v in entry["variants"]:
        if not variant_valid(prim_name, v, hi_shape, itemsize, hw):
            invalid.append(v.name)
            continue
        valid.append(v)
        scores[v.name] = sum(
            entry["cost"](v, p, itemsize, params, hw).time_s for p in probes)
    if not valid:  # unreachable with a ref variant registered; be safe
        raise RuntimeError(
            f"no VMEM-valid {prim_name} variant over bounds {dict(bounds)}")
    if forced is not None:
        chosen = next((v for v in valid if v.name == forced), None)
        if chosen is None:
            raise ValueError(
                f"forced variant {forced!r} is not valid for {prim_name} "
                f"over bounds {dict(bounds)} (valid: "
                f"{[v.name for v in valid]})")
        return chosen, scores, probes, tuple(invalid)
    default = entry["default"]
    best = min(valid, key=lambda v: (scores[v.name],
                                     v.name != default.name, v.name))
    return best, scores, probes, tuple(invalid)


def node_bounds(node, sg) -> Dict[str, Tuple[int, Optional[int]]]:
    """A kernel node's cost-model dim bounds under one shape graph."""
    exprs = _node_dim_exprs(node.prim_name, node)
    return {k: _expr_bounds(e, sg) for k, e in exprs.items()}


def select_for_node(node, sg, hw: HardwareModel = DEFAULT_HW,
                    forced: Optional[str] = None) -> KernelSelection:
    """Select a variant for one kernel node under a plan's shape graph."""
    prim_name = node.prim_name
    bounds = node_bounds(node, sg)
    itemsize = int(node.invals[0].dtype.itemsize)
    variant, scores, probes, invalid = select_variant(
        prim_name, bounds, itemsize, node.params, hw, forced=forced)
    return KernelSelection(node_id=node.id, prim_name=prim_name,
                           variant=variant,
                           default=default_variant(prim_name),
                           scores=scores, bounds=bounds, probes=probes,
                           invalid=invalid, measured=forced is not None)


def select_kernels(graph, sg, hw: HardwareModel = DEFAULT_HW,
                   forced: Optional[Mapping[int, str]] = None,
                   decisions=None) -> Dict[int, KernelSelection]:
    """Select a variant for every registered kernel node in ``graph``.

    ``forced`` maps node id -> variant name (the measured-fallback path).
    Returns node id -> :class:`KernelSelection`; logs one
    ``kernel-select`` decision per node when a ``DecisionLog`` is given.
    """
    out: Dict[int, KernelSelection] = {}
    for node in graph.nodes:
        if node.prim_name not in _REGISTRY:
            continue
        sel = select_for_node(node, sg, hw,
                              forced=(forced or {}).get(node.id))
        out[node.id] = sel
        if decisions is not None:
            sel_us = sel.scores.get(sel.variant.name, 0.0) * 1e6
            def_us = sel.scores.get(sel.default.name, sel_us) * 1e6
            why = (f"measured re-selection over {sel.describe_bounds()}"
                   if sel.measured else
                   f"model {sel_us:.1f}us vs default {def_us:.1f}us "
                   f"over {sel.describe_bounds()}")
            decisions.add("kernel-select", f"%{node.id} {node.prim_name}",
                          sel.variant.name, why,
                          model_speedup=round(sel.model_speedup, 3),
                          n_scored=len(sel.scores),
                          invalid=list(sel.invalid))
    return out


def select_eager(prim_name: str, shape: Mapping[str, int], itemsize: int,
                 params: Mapping[str, Any],
                 hw: HardwareModel = DEFAULT_HW) -> KernelVariant:
    """Cost-model choice at one *concrete* shape (the eager-call path:
    ``kernels.rmsnorm(x, scale)`` with no explicit impl)."""
    bounds = {k: (int(v), int(v)) for k, v in shape.items()}
    variant, _scores, _probes, _invalid = select_variant(
        prim_name, bounds, itemsize, params, hw)
    return variant


# ---------------------------------------------------------------------------
# measured fallback: time the candidates at a representative shape
# ---------------------------------------------------------------------------


def measure_variants(prim_name: str, node, env: Mapping[str, int],
                     hw: HardwareModel = DEFAULT_HW, repeats: int = 3
                     ) -> Dict[str, float]:
    """Wall-time every VMEM-valid variant of ``node`` at ``env``.

    Builds random inputs at the node's concrete shapes (values are
    irrelevant to timing), runs each valid variant once to warm the jit
    cache, then takes the best of ``repeats`` timed calls.  Returns
    variant name -> seconds."""
    import time as _time

    import jax
    import numpy as np

    from . import ops as _ops

    def _dim(e):
        return int(e) if isinstance(e, int) else int(e.evaluate(dict(env)))

    arrays = []
    rng = np.random.default_rng(0)
    for i, v in enumerate(node.invals):
        shape = tuple(_dim(d) for d in v.dims)
        if np.issubdtype(v.dtype, np.floating):
            arr = rng.standard_normal(shape, dtype=np.float32).astype(v.dtype)
        else:
            arr = rng.integers(0, 8, size=shape).astype(v.dtype)
        arrays.append(jax.numpy.asarray(arr))
    exprs = _node_dim_exprs(prim_name, node)
    hi_shape = {k: _dim(e) for k, e in exprs.items()}
    itemsize = int(node.invals[0].dtype.itemsize)
    timings: Dict[str, float] = {}
    for variant in variants_for(prim_name):
        if not variant_valid(prim_name, variant, hi_shape, itemsize, hw):
            continue
        merged = {**node.params, **variant.overrides()}
        run = lambda: _ops.run_kernel(prim_name, arrays, merged)
        jax.block_until_ready(run())            # warm the jit cache
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = _time.perf_counter()
            jax.block_until_ready(run())
            best = min(best, _time.perf_counter() - t0)
        timings[variant.name] = best
    return timings
