from .hw_model import DEFAULT_HW, HardwareModel
from .ops import (flash_attention, masked_select, nonzero_pad, rmsnorm,
                  topk_dynamic, unique_bounded)
from .variants import (KernelSelection, KernelVariant, default_variant,
                       registered_kernels, select_kernels, variants_for)

__all__ = ["flash_attention", "rmsnorm", "nonzero_pad", "masked_select",
           "topk_dynamic", "unique_bounded", "HardwareModel", "DEFAULT_HW",
           "KernelVariant", "KernelSelection", "variants_for",
           "default_variant", "registered_kernels", "select_kernels"]
