from .ops import (flash_attention, masked_select, nonzero_pad, rmsnorm,
                  topk_dynamic, unique_bounded)

__all__ = ["flash_attention", "rmsnorm", "nonzero_pad", "masked_select",
           "topk_dynamic", "unique_bounded"]
