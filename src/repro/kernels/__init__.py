from .ops import flash_attention, rmsnorm

__all__ = ["flash_attention", "rmsnorm"]
