"""jit'd public wrappers for the Pallas kernels.

On a real TPU runtime call these with ``interpret=False`` (the default
resolves from the backend); this CPU container validates with
``interpret=True`` which executes the kernel body in Python.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import rmsnorm as _rn


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "softmax_scale", "block_q",
                                   "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    softmax_scale: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, Hq, S, hd); k/v: (B, Hkv, T, hd)."""
    interp = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal,
                               softmax_scale=softmax_scale, block_q=block_q,
                               block_kv=block_kv, interpret=interp)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: Optional[bool] = None):
    interp = _default_interpret() if interpret is None else interpret
    return _rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                       interpret=interp)
