"""Public kernel entry points, registered as first-class primitives.

``flash_attention`` and ``rmsnorm`` used to be plain jit'd wrappers — a
fixed Pallas configuration fused into whatever jaxpr traced them.  They
are now JAX primitives, so a traced graph carries one node per kernel
call and the compiler can *select* a configuration for it: the variant
registry + cost model in :mod:`repro.kernels.variants` pick block sizes,
pipeline depth, and the ref-vs-pallas crossover per compiled plan, and
the choice is baked into the lowered ``Compute`` instruction.

Dispatch rules of the wrappers:

* an explicit ``impl=`` always wins ('pallas' | 'ref');
* passing any Pallas-specific argument (``block_q``/``block_kv``/
  ``block_rows``/``interpret``) implies ``impl='pallas'`` — existing
  call sites keep their exact behavior;
* otherwise ``impl`` stays ``None`` — *auto*: an eager call resolves it
  through the cost model at the concrete shape (tiny-d ``rmsnorm`` hits
  the reference implementation instead of padding d up to 128), while a
  call under ``repro.optimize`` tracing leaves the sentinel in the node
  params for plan-time per-bucket selection to overwrite.

On a real TPU runtime ``interpret`` resolves to ``False``; this CPU
container validates with ``interpret=True`` which executes the kernel
body in Python.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from jax.extend.core import Primitive

from repro.core.ir.dynamism import DimIntroSpec, register_introduces_dim

from . import flash_attention as _fa
from . import ref as _ref
from . import rmsnorm as _rn
from . import variants as _variants


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# jit'd workers — every knob static so each resolved configuration
# compiles once and replays from cache
_fa_pallas = partial(jax.jit, static_argnames=(
    "causal", "softmax_scale", "block_q", "block_kv", "interpret"))(
        _fa.flash_attention)
_fa_ref = partial(jax.jit, static_argnames=("causal", "softmax_scale"))(
    _ref.reference_attention)
_rn_pallas = partial(jax.jit, static_argnames=(
    "eps", "block_rows", "interpret"))(_rn.rmsnorm)
_rn_ref = partial(jax.jit, static_argnames=("eps",))(_ref.reference_rmsnorm)


def _flash_run(q, k, v, *, causal: bool = True,
               softmax_scale: Optional[float] = None,
               block_q: Optional[int] = None, block_kv: Optional[int] = None,
               interpret: Optional[bool] = None, impl: Optional[str] = None,
               pipeline_depth: int = 2):
    """Concrete-shape dispatcher behind the flash_attention primitive."""
    del pipeline_depth  # VMEM-accounting knob only; Pallas double buffers
    if impl is None:
        b, hq, s, hd = q.shape
        t = k.shape[2]
        chosen = _variants.select_eager(
            "flash_attention", {"b": b, "hq": hq, "s": s, "t": t, "hd": hd},
            jnp.dtype(q.dtype).itemsize, {"causal": causal})
        impl = chosen.impl
        if impl == "pallas":
            block_q = block_q or chosen.block_of("block_q", 128)
            block_kv = block_kv or chosen.block_of("block_kv", 128)
    if impl == "ref":
        return _fa_ref(q, k, v, causal=causal, softmax_scale=softmax_scale)
    interp = _default_interpret() if interpret is None else interpret
    return _fa_pallas(q, k, v, causal=causal, softmax_scale=softmax_scale,
                      block_q=block_q or 128, block_kv=block_kv or 128,
                      interpret=interp)


def _rmsnorm_run(x, scale, *, eps: float = 1e-6,
                 block_rows: Optional[int] = None,
                 interpret: Optional[bool] = None, impl: Optional[str] = None,
                 pipeline_depth: int = 2):
    """Concrete-shape dispatcher behind the rmsnorm primitive."""
    del pipeline_depth
    if impl is None:
        d = x.shape[-1]
        n = 1
        for s in x.shape[:-1]:
            n *= s
        chosen = _variants.select_eager(
            "rmsnorm", {"n": n, "d": d}, jnp.dtype(x.dtype).itemsize, {})
        impl = chosen.impl
        if impl == "pallas":
            block_rows = block_rows or chosen.block_of("block_rows", 256)
    if impl == "ref":
        return _rn_ref(x, scale, eps=eps)
    interp = _default_interpret() if interpret is None else interpret
    return _rn_pallas(x, scale, eps=eps, block_rows=block_rows or 256,
                      interpret=interp)


def _kernel_primitive(name: str, run) -> Primitive:
    p = Primitive(name)
    p.def_impl(run)

    def abse(*avals, **params):
        from jax.core import ShapedArray
        a = avals[0]
        return ShapedArray(a.shape, a.dtype)

    p.def_abstract_eval(abse)
    try:  # usable under an outer jax.jit where available
        from jax.interpreters import mlir
        mlir.register_lowering(p, mlir.lower_fun(run, multiple_results=False))
    except Exception:
        pass
    return p


_flash_attention_p = _kernel_primitive("flash_attention", _flash_run)
_rmsnorm_p = _kernel_primitive("rmsnorm", _rmsnorm_run)


def run_kernel(prim_name: str, arrays: Sequence[Any],
               params: Dict[str, Any]):
    """Invoke a kernel dispatcher directly (the measured-fallback timer)."""
    if prim_name == "flash_attention":
        return _flash_run(*arrays, **params)
    if prim_name == "rmsnorm":
        return _rmsnorm_run(*arrays, **params)
    raise KeyError(prim_name)


def flash_attention(q, k, v, *, causal: bool = True,
                    softmax_scale: Optional[float] = None,
                    block_q: Optional[int] = None,
                    block_kv: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    impl: Optional[str] = None):
    """q: (B, Hq, S, hd); k/v: (B, Hkv, T, hd)."""
    if impl is None and (block_q is not None or block_kv is not None
                         or interpret is not None):
        impl = "pallas"
    return _flash_attention_p.bind(q, k, v, causal=causal,
                                   softmax_scale=softmax_scale,
                                   block_q=block_q, block_kv=block_kv,
                                   interpret=interpret, impl=impl,
                                   pipeline_depth=2)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: Optional[int] = None,
            interpret: Optional[bool] = None, impl: Optional[str] = None):
    if impl is None and (block_rows is not None or interpret is not None):
        impl = "pallas"
    return _rmsnorm_p.bind(x, scale, eps=eps, block_rows=block_rows,
                           interpret=interpret, impl=impl, pipeline_depth=2)


# ---------------------------------------------------------------------------
# Value-dependent bounded ops (dynamism *introducers*, SoD² taxonomy).
#
# Each primitive returns ``(payload, count)``: the payload is padded to
# its symbolic bound (the input's static/cap shape) with zeros past the
# valid prefix, and ``count`` is the measured i32 extent.  Registering
# with ``register_introduces_dim`` makes the tracer rewrite the payload's
# leading dim to a fresh bounded symbol ``__b<k> <= cap``, which the
# planner reserves at the cap and the runtime re-binds tight (``BindDim``).
# The eager impls are the padded-to-bound oracles in ``kernels.ref`` —
# both executors run the identical impl, keeping the differential
# contract bitwise.
# ---------------------------------------------------------------------------


def _i32_scalar(_: object = None):
    from jax.core import ShapedArray
    return ShapedArray((), jnp.int32)


def _bounded_primitive(name: str, impl, abstract_eval,
                       spec: Optional[DimIntroSpec] = None) -> Primitive:
    p = Primitive(name)
    p.multiple_results = True
    p.def_impl(lambda *xs, **kw: list(impl(*xs, **kw)))
    p.def_abstract_eval(abstract_eval)
    register_introduces_dim(name, spec)
    return p


def _abse_like(i):
    """Payload aval == input ``i``'s aval; plus the i32 count scalar."""
    def abse(*avals):
        from jax.core import ShapedArray
        a = avals[i]
        return [ShapedArray(a.shape, a.dtype), _i32_scalar()]
    return abse


def _abse_idx(*avals):
    from jax.core import ShapedArray
    return [ShapedArray(avals[0].shape, jnp.int32), _i32_scalar()]


_nonzero_pad_p = _bounded_primitive(
    "nonzero_pad", _ref.reference_nonzero_pad, _abse_idx)
_masked_select_p = _bounded_primitive(
    "masked_select", _ref.reference_masked_select, _abse_like(0))
_topk_dynamic_p = _bounded_primitive(
    "topk_dynamic", _ref.reference_topk_dynamic, _abse_like(0))
_unique_bounded_p = _bounded_primitive(
    "unique_bounded", _ref.reference_unique_bounded, _abse_like(0))


def nonzero_pad(x):
    """Indices of nonzero entries of 1-D ``x`` -> ``(idx_padded, count)``.

    ``idx_padded`` is i32 with the same length as ``x``; entries past
    ``count`` are zero.  Under ``optimize`` the output length becomes a
    bounded dim ``b <= len(x)``."""
    a, c = _nonzero_pad_p.bind(x)
    return a, c


def masked_select(x, mask):
    """Rows of ``x`` (leading axis) where 1-D ``mask`` holds, compacted
    to the front -> ``(rows_padded, count)``."""
    a, c = _masked_select_p.bind(x, mask)
    return a, c


def topk_dynamic(x, k):
    """Largest ``k`` values of 1-D ``x`` with a *data-dependent* ``k``
    (i32 scalar array), descending -> ``(vals_padded, count)``."""
    a, c = _topk_dynamic_p.bind(x, k)
    return a, c


def unique_bounded(x):
    """Sorted distinct values of 1-D ``x`` -> ``(unique_padded, count)``."""
    a, c = _unique_bounded_p.bind(x)
    return a, c
