"""jit'd public wrappers for the Pallas kernels.

On a real TPU runtime call these with ``interpret=False`` (the default
resolves from the backend); this CPU container validates with
``interpret=True`` which executes the kernel body in Python.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from jax.extend.core import Primitive

from repro.core.ir.dynamism import DimIntroSpec, register_introduces_dim

from . import flash_attention as _fa
from . import ref as _ref
from . import rmsnorm as _rn


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "softmax_scale", "block_q",
                                   "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    softmax_scale: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, Hq, S, hd); k/v: (B, Hkv, T, hd)."""
    interp = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal,
                               softmax_scale=softmax_scale, block_q=block_q,
                               block_kv=block_kv, interpret=interp)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: Optional[bool] = None):
    interp = _default_interpret() if interpret is None else interpret
    return _rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                       interpret=interp)


# ---------------------------------------------------------------------------
# Value-dependent bounded ops (dynamism *introducers*, SoD² taxonomy).
#
# Each primitive returns ``(payload, count)``: the payload is padded to
# its symbolic bound (the input's static/cap shape) with zeros past the
# valid prefix, and ``count`` is the measured i32 extent.  Registering
# with ``register_introduces_dim`` makes the tracer rewrite the payload's
# leading dim to a fresh bounded symbol ``__b<k> <= cap``, which the
# planner reserves at the cap and the runtime re-binds tight (``BindDim``).
# The eager impls are the padded-to-bound oracles in ``kernels.ref`` —
# both executors run the identical impl, keeping the differential
# contract bitwise.
# ---------------------------------------------------------------------------


def _i32_scalar(_: object = None):
    from jax.core import ShapedArray
    return ShapedArray((), jnp.int32)


def _bounded_primitive(name: str, impl, abstract_eval,
                       spec: Optional[DimIntroSpec] = None) -> Primitive:
    p = Primitive(name)
    p.multiple_results = True
    p.def_impl(lambda *xs, **kw: list(impl(*xs, **kw)))
    p.def_abstract_eval(abstract_eval)
    register_introduces_dim(name, spec)
    return p


def _abse_like(i):
    """Payload aval == input ``i``'s aval; plus the i32 count scalar."""
    def abse(*avals):
        from jax.core import ShapedArray
        a = avals[i]
        return [ShapedArray(a.shape, a.dtype), _i32_scalar()]
    return abse


def _abse_idx(*avals):
    from jax.core import ShapedArray
    return [ShapedArray(avals[0].shape, jnp.int32), _i32_scalar()]


_nonzero_pad_p = _bounded_primitive(
    "nonzero_pad", _ref.reference_nonzero_pad, _abse_idx)
_masked_select_p = _bounded_primitive(
    "masked_select", _ref.reference_masked_select, _abse_like(0))
_topk_dynamic_p = _bounded_primitive(
    "topk_dynamic", _ref.reference_topk_dynamic, _abse_like(0))
_unique_bounded_p = _bounded_primitive(
    "unique_bounded", _ref.reference_unique_bounded, _abse_like(0))


def nonzero_pad(x):
    """Indices of nonzero entries of 1-D ``x`` -> ``(idx_padded, count)``.

    ``idx_padded`` is i32 with the same length as ``x``; entries past
    ``count`` are zero.  Under ``optimize`` the output length becomes a
    bounded dim ``b <= len(x)``."""
    a, c = _nonzero_pad_p.bind(x)
    return a, c


def masked_select(x, mask):
    """Rows of ``x`` (leading axis) where 1-D ``mask`` holds, compacted
    to the front -> ``(rows_padded, count)``."""
    a, c = _masked_select_p.bind(x, mask)
    return a, c


def topk_dynamic(x, k):
    """Largest ``k`` values of 1-D ``x`` with a *data-dependent* ``k``
    (i32 scalar array), descending -> ``(vals_padded, count)``."""
    a, c = _topk_dynamic_p.bind(x, k)
    return a, c


def unique_bounded(x):
    """Sorted distinct values of 1-D ``x`` -> ``(unique_padded, count)``."""
    a, c = _unique_bounded_p.bind(x)
    return a, c
