"""Pallas TPU flash-attention kernel (causal GQA, online softmax).

TPU-native design (not a CUDA port):
  * grid = (batch·q_heads, q_blocks, kv_blocks) — the kv dimension is the
    innermost sequential grid axis, so the (m, l, acc) accumulators live in
    VMEM scratch across kv steps (revisiting semantics), exactly where the
    MXU wants its operands;
  * BlockSpecs tile Q (BLOCK_Q × head_dim) and K/V (BLOCK_KV × head_dim)
    into VMEM; head_dim and block sizes are multiples of 128 (MXU/VREG
    alignment) whenever the model's head_dim allows;
  * GQA is expressed in the K/V index_map (kv_head = q_head // group), so
    grouped heads reuse the same K/V tiles without materializing repeats;
  * the causal mask is generated from block indices with iota — no mask
    tensors stream from HBM.

Validated in interpret mode against ``ref.reference_attention``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_kv: int, causal: bool,
                  seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    kv_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv), 1)

    run = True
    if causal:
        # whole block strictly above the diagonal contributes nothing
        run = (ki * block_kv) <= (qi * block_q + block_q - 1)

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)            # (BKV, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = kv_pos < seq_len                      # KV padding
        if causal:
            mask &= kv_pos <= q_pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                          # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    softmax_scale: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, hd); k/v: (B, Hkv, T, hd) with Hq % Hkv == 0."""
    b, hq, s, hd = q.shape
    t, hkv = k.shape[2], k.shape[1]
    group = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)

    block_q = min(block_q, s)
    block_kv = min(block_kv, t)
    s_pad = -(-s // block_q) * block_q
    t_pad = -(-t // block_kv) * block_kv
    if s_pad != s:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, s_pad - s), (0, 0)])
    if t_pad != t:
        k = jnp.pad(k, [(0, 0), (0, 0), (0, t_pad - t), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, 0), (0, t_pad - t), (0, 0)])

    qf = q.reshape(b * hq, s_pad, hd)
    kf = k.reshape(b * hkv, t_pad, hd)
    vf = v.reshape(b * hkv, t_pad, hd)

    grid = (b * hq, s_pad // block_q, t_pad // block_kv)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_kv=block_kv, causal=causal, seq_len=t)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s_pad, hd), q.dtype),
        scratch_shapes=[
            _scratch((block_q, 1)),    # m (running max)
            _scratch((block_q, 1)),    # l (running denominator)
            _scratch((block_q, hd)),   # acc (weighted values)
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s_pad, hd)[:, :, :s]


def _scratch(shape):
    from jax.experimental import pallas as pl
    try:  # TPU memory space when available, plain VMEM otherwise
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover
        return pl.VMEM(shape, jnp.float32)
