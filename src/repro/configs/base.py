"""ModelConfig: one dataclass describes every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    ffn_kind: str = "swiglu"       # swiglu | geglu
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # attention
    attn_kind: str = "gqa"         # gqa | mla
    window: Optional[int] = None   # sliding-window size (hybrid layers)
    global_every: int = 0          # hybrid: every k-th layer uses global attn

    # MLA (deepseek-style)
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128
    r_kv: int = 512
    r_q: int = 1536

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_capacity: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2

    # xLSTM
    block_kind: str = "transformer"  # transformer | xlstm
    slstm_every: int = 8             # every k-th layer is sLSTM
    xlstm_proj_factor: float = 2.0

    # modality frontend (stub): tokens | embeddings | vlm
    input_mode: str = "tokens"
    n_codebooks: int = 0           # musicgen-style multi-head output
    vis_tokens: int = 256          # vlm: stub patch-embedding count

    # training / memory knobs
    remat_policy: str = "block"    # none | block | full
    optimizer_dtype: str = "float32"  # bf16 option for the 1T-class configs
    scan_layers: bool = True       # False: python-unrolled (the dynamic-shape
    #                                optimizer path needs a flat graph)

    # embedding-table padding: vocab dims that don't divide the model axis
    # (92553, 32001, ...) would force replicated embeddings + optimizer
    # states; tables are padded to this boundary (pad logits masked to -inf)
    pad_vocab_to: int = 128

    # -- derived ----------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.pad_vocab_to) * self.pad_vocab_to

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jax_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def mla_config(self):
        from ..models.mla import MLAConfig
        return MLAConfig(d_model=self.d_model, n_heads=self.n_heads,
                         qk_nope=self.qk_nope, qk_rope=self.qk_rope,
                         v_dim=self.v_dim, r_kv=self.r_kv, r_q=self.r_q,
                         rope_theta=self.rope_theta)

    def ssm_config(self):
        from ..models.ssm import SSMConfig
        return SSMConfig(d_model=self.d_model,
                         d_inner=self.ssm_expand * self.d_model,
                         d_state=self.ssm_state or 16)

    def xlstm_config(self):
        from ..models.xlstm import XLSTMConfig
        return XLSTMConfig(d_model=self.d_model, n_heads=self.n_heads,
                           proj_factor=self.xlstm_proj_factor)

    def window_for_layer(self, layer: int) -> Optional[int]:
        """hybrid archs: sliding window except periodic global layers."""
        if self.window is None:
            return None
        if self.global_every and (layer % self.global_every == 0):
            return None
        return self.window

    # -- parameter count (for roofline MODEL_FLOPS) -------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        if self.block_kind == "xlstm":
            di = int(self.xlstm_proj_factor * d)
            per_m = d * 2 * di + 3 * di * di + di * d + 2 * di * self.n_heads
            per_s = 4 * d * di + di * d
            n_s = self.n_layers // self.slstm_every if self.slstm_every else 0
            layers = per_m * (self.n_layers - n_s) + per_s * n_s
            return layers + self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.attn_kind == "mla":
            attn = (d * self.r_q + self.r_q * self.n_heads * (self.qk_nope + self.qk_rope)
                    + d * self.r_kv + self.r_kv * self.n_heads * (self.qk_nope + self.v_dim)
                    + d * self.qk_rope + self.n_heads * self.v_dim * d)
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        if self.n_experts:
            expert = 3 * d * f
            n_exp = self.top_k if active_only else self.n_experts
            ffn = n_exp * expert + self.n_shared * expert + d * self.n_experts
        else:
            ffn = 3 * d * f
        if self.family == "hybrid":
            di = self.ssm_expand * d
            r = -(-d // 16)
            ffn += d * 2 * di + di * (r + 2 * (self.ssm_state or 16)) + r * di \
                + di * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.n_codebooks:
            emb += self.n_codebooks * d * self.vocab
        return self.n_layers * (attn + ffn) + emb


# -- input shape sets (assigned) ---------------------------------------------------

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# long_500k requires sub-quadratic attention: only SSM/hybrid run it.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cells_for(cfg: ModelConfig):
    """The (shape_name, spec) cells this arch runs; skips are recorded."""
    out = []
    for name, spec in SHAPES.items():
        if name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES \
                and cfg.block_kind != "xlstm":
            out.append((name, dict(spec, skip="full-attention arch: no "
                                   "sub-quadratic mechanism at 500k")))
        else:
            out.append((name, dict(spec, skip=None)))
    return out
