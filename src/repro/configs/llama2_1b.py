"""llama2-1b — the paper's evaluation model: Llama-2-7b with n_layers=4.

32H d_model=4096 kv=32 d_ff=11008 vocab=32000 (Table 1 / §3 of the paper).
SMOKE is the width-reduced version used for CPU-runnable dynamic-shape
training benchmarks.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-1b", family="dense",
    n_layers=4, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=32000,
    ffn_kind="swiglu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llama2-smoke", family="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=344, vocab=512,
    ffn_kind="swiglu", tie_embeddings=False, dtype="float32",
)
