"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import LONG_CONTEXT_FAMILIES, SHAPES, ModelConfig, cells_for

ARCHS: List[str] = [
    "hymba_1p5b", "internvl2_2b", "musicgen_medium", "starcoder2_7b",
    "granite_8b", "gemma_7b", "gemma_2b", "deepseek_v3_671b",
    "kimi_k2_1t_a32b", "xlstm_1p3b", "llama2_1b",
]

_ALIASES = {
    "hymba-1.5b": "hymba_1p5b", "internvl2-2b": "internvl2_2b",
    "musicgen-medium": "musicgen_medium", "starcoder2-7b": "starcoder2_7b",
    "granite-8b": "granite_8b", "gemma-7b": "gemma_7b", "gemma-2b": "gemma_2b",
    "deepseek-v3-671b": "deepseek_v3_671b", "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "xlstm-1.3b": "xlstm_1p3b", "llama2-1b": "llama2_1b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.SMOKE


__all__ = ["ARCHS", "ModelConfig", "SHAPES", "LONG_CONTEXT_FAMILIES",
           "cells_for", "get_config", "get_smoke_config", "canonical"]
