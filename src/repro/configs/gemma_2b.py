"""gemma-2b — GeGLU, MQA (kv=1), head_dim=256 [arXiv:2403.08295; hf].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=256000, head_dim=256,
    ffn_kind="geglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=256, head_dim=32,
    ffn_kind="geglu", tie_embeddings=True, dtype="float32",
)
