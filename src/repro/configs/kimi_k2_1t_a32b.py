"""kimi-k2-1t-a32b — trillion-param MoE 384e top-8 [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840.
Per the assignment table this config uses GQA kv=8 (the public K2 report
uses MLA; we follow the assigned table — see DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840,
    n_experts=384, top_k=8, n_shared=1, ffn_kind="swiglu",
    tie_embeddings=False, optimizer_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="kimi-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab=128,
    n_experts=8, top_k=2, n_shared=1, ffn_kind="swiglu",
    tie_embeddings=False, dtype="float32",
)
