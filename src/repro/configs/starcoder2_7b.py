"""starcoder2-7b — GQA + RoPE code model [arXiv:2402.19173; hf].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152,
    ffn_kind="swiglu", rope_theta=1e5, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense",
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=2,
    d_ff=128, vocab=128,
    ffn_kind="swiglu", tie_embeddings=False, dtype="float32",
)
