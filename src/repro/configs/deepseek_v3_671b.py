"""deepseek-v3-671b — MLA + MoE 256e top-8 (+1 shared) [arXiv:2412.19437; hf].

61L d_model=7168 128H d_ff=2048(expert) vocab=129280; MLA r_kv=512 r_q=1536,
qk_nope/v=128, qk_rope=64.  MTP head omitted (noted in DESIGN.md);
optimizer states in bf16 for the trillion-class configs.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280,
    attn_kind="mla", qk_nope=128, qk_rope=64, v_dim=128, r_kv=512, r_q=1536,
    n_experts=256, top_k=8, n_shared=1, ffn_kind="swiglu",
    tie_embeddings=False, optimizer_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab=128,
    attn_kind="mla", qk_nope=16, qk_rope=8, v_dim=16, r_kv=24, r_q=32,
    n_experts=8, top_k=2, n_shared=1, ffn_kind="swiglu",
    tie_embeddings=False, dtype="float32",
)
