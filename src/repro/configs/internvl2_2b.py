"""internvl2-2b — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The ViT frontend is
a stub per the assignment: input_specs() provides precomputed patch
embeddings; loss is over text positions.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553,
    ffn_kind="swiglu", input_mode="vlm", vis_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=128,
    ffn_kind="swiglu", input_mode="vlm", vis_tokens=8,
    dtype="float32",
)
