"""gemma-7b — GeGLU, head_dim=256 [arXiv:2403.08295; hf].

28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24576, vocab=256000, head_dim=256,
    ffn_kind="geglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=32,
    ffn_kind="geglu", tie_embeddings=True, dtype="float32",
)
