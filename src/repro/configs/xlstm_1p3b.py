"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1) [arXiv:2405.04517; unverified].

48L d_model=2048 4H d_ff=0 vocab=50304; runs long_500k (recurrent).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    block_kind="xlstm", slstm_every=8, xlstm_proj_factor=2.0,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab=128,
    block_kind="xlstm", slstm_every=4, xlstm_proj_factor=2.0,
    dtype="float32",
)
