"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048, 4 codebooks.
EnCodec frontend is a stub: input_specs() provides frame embeddings;
the LM head predicts all 4 codebooks per frame.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    ffn_kind="swiglu", input_mode="embeddings", n_codebooks=4,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=64,
    ffn_kind="swiglu", input_mode="embeddings", n_codebooks=4,
    tie_embeddings=False, dtype="float32",
)
