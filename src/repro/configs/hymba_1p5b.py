"""hymba-1.5b — hybrid parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention except global layers {first, middle, last};
runs long_500k (sub-quadratic via SSM + SWA).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    ffn_kind="swiglu", window=1024, ssm_state=16, ssm_expand=2,
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=2, d_model=80, n_heads=5, n_kv_heads=1,
    d_ff=128, vocab=128, head_dim=16,
    ffn_kind="swiglu", window=16, ssm_state=4, ssm_expand=2,
    dtype="float32",
)
