"""Public API of the BladeDISC++-style memory optimizer.

    opt = optimize(train_step, example_args, dynamic_dims={...})
    out = opt(*concrete_args)                 # any batch/seq shape, no retrace
    opt.last_report.stats.device_peak         # exact peak bytes

``optimize`` performs the paper's full pipeline once at "compile" time:
symbolic trace → symbolic shape graph → op scheduling (§2.2) → remat
planning (§2.3 compile half).  Calls then execute through the runtime
interpreter (§2.3 runtime half) under an optional memory limit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
from jax import export, tree_util

from .executor.interpreter import PlanInterpreter, RunReport
from .ir.trace import trace_to_graph
from .memplan import ArenaPlan, build_arena_plan
from .remat.planner import ExecutionPlan, build_plan
from .scheduling.memsim import simulate_peak, simulate_peak_bound
from .scheduling.scheduler import ScheduleResult, schedule_graph
from .symbolic import ShapeGraph, declare_dim_ranges


def symbolic_dim(name: str):
    """A fresh symbolic dimension usable inside ShapeDtypeStruct shapes."""
    (d,) = export.symbolic_shape(name)
    return d


def symbolic_dims(spec: str):
    return export.symbolic_shape(spec)


@dataclass
class OptimizeReport:
    schedule: ScheduleResult
    n_candidates: int
    n_recomputable: int
    used_scheduled_order: bool
    # candidates whose regen method interval bounds fixed at compile time
    n_static_regen: int = 0
    # guaranteed worst-case peak bytes over the declared dim ranges
    # (None when some dim has no declared upper bound)
    peak_bound_bytes: Optional[int] = None
    peak_bound_lo: Optional[int] = None
    # memory planner (memory_plan="arena"): guaranteed worst-case arena
    # size over the declared dim ranges, slot count, planned reuse split
    arena_bound_bytes: Optional[int] = None
    n_arena_slots: int = 0
    n_provable_reuses: int = 0
    n_checked_reuses: int = 0


class DynamicShapeFunction:
    """A compiled-once, run-any-shape callable with memory optimization."""

    def __init__(self, plan: ExecutionPlan, in_tree, out_tree,
                 report: OptimizeReport, *,
                 memory_limit: Optional[int] = None,
                 donate_inputs: bool = False,
                 count_inputs: bool = True):
        self.plan = plan
        self._in_tree = in_tree
        self._out_tree = out_tree
        self.report = report
        self.interp = PlanInterpreter(plan, memory_limit=memory_limit,
                                      donate_inputs=donate_inputs,
                                      count_inputs=count_inputs)
        self.last_report: Optional[RunReport] = None

    def __call__(self, *args, **kwargs):
        flat, in_tree = tree_util.tree_flatten((args, kwargs))
        if in_tree != self._in_tree:
            raise TypeError(
                f"pytree structure mismatch: traced {self._in_tree}, got {in_tree}")
        outs, report = self.interp.run(flat)
        self.last_report = report
        return tree_util.tree_unflatten(self._out_tree, outs)

    @property
    def guaranteed_peak_bytes(self) -> Optional[int]:
        """Compile-time worst-case peak over the declared dim ranges.

        ``None`` unless every symbolic dim was given an upper bound via
        ``optimize(..., dynamic_dims=...)``.  For every call whose dims lie
        within the declared ranges, the free-run device peak is <= this.
        """
        return self.report.peak_bound_bytes

    @property
    def arena_plan(self) -> Optional["ArenaPlan"]:
        return self.plan.arena_plan

    @property
    def arena_bound_bytes(self) -> Optional[int]:
        """Compile-time worst-case planned arena size over the declared dim
        ranges (``None`` without ``memory_plan="arena"`` + bounded dims)."""
        return self.report.arena_bound_bytes

    # reconfigure without retracing
    def with_memory_limit(self, limit: Optional[int]) -> "DynamicShapeFunction":
        return DynamicShapeFunction(self.plan, self._in_tree, self._out_tree,
                                    self.report,
                                    memory_limit=limit,
                                    donate_inputs=self.interp.donate_inputs,
                                    count_inputs=self.interp.count_inputs)


def optimize(
    fn: Callable,
    *example_args,
    shape_graph: Optional[ShapeGraph] = None,
    dynamic_dims: Optional[Dict[str, Any]] = None,
    enable_scheduling: bool = True,
    enable_remat: bool = True,
    memory_limit: Optional[int] = None,
    donate_inputs: bool = False,
    count_inputs: bool = True,
    max_subgraph: int = 24,
    guard_env: Optional[Dict[str, int]] = None,
    memory_plan: str = "arena",
    **example_kwargs,
) -> DynamicShapeFunction:
    """Trace ``fn`` symbolically and build the optimized dynamic-shape plan.

    ``example_args``: ShapeDtypeStructs (shapes may contain symbolic dims
    from :func:`symbolic_dim`).  ``dynamic_dims``: declared ranges per
    symbolic dim name — e.g. ``{"b": (1, 64), "s": "<=4096"}`` (see
    :func:`repro.core.symbolic.parse_range_spec`) — feeding the interval
    fallback of symbolic comparisons; with every dim bounded above, the
    report carries a guaranteed worst-case peak (``peak_bound_bytes``).
    ``guard_env``: representative dim binding used to verify the scheduled
    order does not regress peak memory vs the original program order
    (best-of safeguard); defaults to all dims = 64, clamped into the
    declared ranges.
    ``memory_plan``: ``"arena"`` (default) runs the symbolic memory
    planner — compile-time buffer-reuse slots + a runtime arena whose
    stats land on ``last_report.stats`` (``arena_bytes``, ``slots``,
    ``reuse_ratio``, ``fragmentation_bytes``); ``"none"`` disables it.
    """
    if memory_plan not in ("arena", "none"):
        raise ValueError(
            f"memory_plan must be 'arena' or 'none', got {memory_plan!r}")
    graph, _ = trace_to_graph(fn, *example_args, **example_kwargs)
    sg = shape_graph if shape_graph is not None else ShapeGraph()
    if dynamic_dims:
        known = graph.free_symbols()
        unknown = sorted(set(dynamic_dims) - known)
        if unknown:
            raise ValueError(
                f"dynamic_dims names {unknown} are not symbolic dims of the "
                f"traced function (known: {sorted(known)})")
    declare_dim_ranges(sg, dynamic_dims)

    def _clamp(name: str, v: int) -> int:
        iv = sg.declared_ranges.get(name)
        if iv is None:
            return v
        if iv.lo is not None:
            v = max(v, iv.lo)
        if iv.hi is not None:
            v = min(v, iv.hi)
        return v

    if enable_scheduling:
        sched = schedule_graph(graph, sg)
        env = dict(guard_env) if guard_env else {
            name: 64 for name in graph.free_symbols()}
        for name in graph.free_symbols():
            env.setdefault(name, 64)
        env = {k: _clamp(k, v) for k, v in env.items()}
        probe_envs = [env,
                      {k: _clamp(k, max(1, v // 4)) for k, v in env.items()},
                      {k: _clamp(k, v * 4) for k, v in env.items()}]
        base = simulate_peak(graph, graph.nodes, env, count_inputs=count_inputs)
        tuned = simulate_peak(graph, sched.order, env, count_inputs=count_inputs)
        used_sched = tuned.peak_bytes <= base.peak_bytes
        kept_peak = min(tuned.peak_bytes, base.peak_bytes)
        if not used_sched:  # keep the better order (never regress)
            sched = ScheduleResult(list(graph.nodes), sched.symbolic_decisions,
                                   sched.tiebreak_decisions)
        # pairwise-exchange refinement (beyond-paper; guarded at probe envs);
        # the kept order's peak is already known — only the refined order
        # needs a fresh simulation
        from .scheduling.exchange import exchange_pass
        refined = exchange_pass(graph, sched.order, probe_envs)
        if simulate_peak(graph, refined, env,
                         count_inputs=count_inputs).peak_bytes <= kept_peak:
            sched = ScheduleResult(refined, sched.symbolic_decisions,
                                   sched.tiebreak_decisions)
    else:
        sched = ScheduleResult(list(graph.nodes), 0, 0)
        used_sched = False

    arena_plan = None
    if memory_plan == "arena":
        arena_plan = build_arena_plan(graph, sched.order, sg,
                                      donate_inputs=donate_inputs)
    plan = build_plan(graph, sched, sg, enable_remat=enable_remat,
                      max_subgraph=max_subgraph, arena_plan=arena_plan)
    peak_lo = peak_hi = None
    if sg.declared_ranges:  # without ranges the bound is vacuous (hi = None)
        peak_lo, peak_hi = simulate_peak_bound(graph, sched.order, sg,
                                               count_inputs=count_inputs,
                                               donate_inputs=donate_inputs)
    report = OptimizeReport(schedule=sched,
                            n_candidates=plan.n_candidates,
                            n_recomputable=plan.n_recomputable,
                            used_scheduled_order=used_sched,
                            n_static_regen=plan.n_static_regen,
                            peak_bound_bytes=peak_hi,
                            peak_bound_lo=peak_lo)
    if arena_plan is not None:
        # None whenever some live dim has no declared upper bound
        report.arena_bound_bytes = arena_plan.arena_bound_bytes
        report.n_arena_slots = arena_plan.n_slots
        report.n_provable_reuses = arena_plan.n_provable_reuses
        report.n_checked_reuses = arena_plan.n_checked_reuses

    flat, in_tree = tree_util.tree_flatten((example_args, example_kwargs))
    out_shapes = jax.eval_shape(fn, *example_args, **example_kwargs)
    _, out_tree = tree_util.tree_flatten(out_shapes)
    return DynamicShapeFunction(plan, in_tree, out_tree, report,
                                memory_limit=memory_limit,
                                donate_inputs=donate_inputs,
                                count_inputs=count_inputs)
