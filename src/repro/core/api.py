"""Public API of the BladeDISC++-style memory optimizer.

    opt = optimize(train_step, example_args, dynamic_dims={...})
    out = opt(*concrete_args)                 # any batch/seq shape, no retrace
    opt.last_report.stats.device_peak         # exact peak bytes

``optimize`` performs the paper's full pipeline once at "compile" time:
symbolic trace → symbolic shape graph → op scheduling (§2.2) → remat
planning (§2.3 compile half) → memory planning → lowering to a flat
``Program``.  Calls then execute through the register ``ProgramVM``
(§2.3 runtime half; ``executor="reference"`` keeps the op-by-op
interpreter) under an optional memory limit.

With ``buckets=...`` the declared shape space is additionally partitioned
into buckets and the schedule → remat → memplan pipeline re-runs lazily
once per bucket under the bucket's tighter bounds; each call dispatches to
its bucket's plan in O(log n) per dim through a :class:`SpecializationTable`
with LRU retention.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, \
    Sequence, Tuple, Union

import jax
from jax import export, tree_util

from .dispatch import BucketKey, BucketPlan, BucketSpace, BucketsSpec, \
    SpecializationTable, build_bucket_space
from .executor.interpreter import PlanInterpreter, RunReport
from .executor.memory import MemoryLimitExceeded
from .executor.vm import ProgramVM
from .ir.dynamism import complete_bound_env
from .ir.trace import check_declared_ranges, solve_env, trace_to_graph
from .lowering import Program, lower_plan
from .memplan import ArenaPlan, build_arena_plan
from .memplan.arena import ArenaExhausted
from .obs import NULL_TRACER, DecisionLog, Telemetry, Tracer
from .remat.planner import ExecutionPlan, build_plan
from .resilience import (BucketQuarantined, CircuitBreaker, CompileFault,
                         FaultPlan, FaultPlanRef, FaultSpec, OffloadFailure,
                         RegenFailure, RequestFailed, ResilienceConfig,
                         ResilienceController, TransientKernelError)
from .scheduling.memsim import simulate_peak, simulate_peak_bound
from .scheduling.scheduler import ScheduleResult, schedule_graph
from .symbolic import ShapeGraph, declare_dim_ranges

__all__ = [
    "optimize", "DynamicShapeFunction", "OptimizeReport",
    "symbolic_dim", "symbolic_dims",
    "BucketSpace", "SpecializationTable", "BucketPlan", "build_bucket_space",
    "Program", "ProgramVM", "lower_plan", "scan",
    "FaultPlan", "FaultSpec", "ResilienceConfig", "RequestFailed",
]

_EXECUTORS = ("vm", "reference")


def _build_executor(plan: ExecutionPlan, report: "OptimizeReport",
                    executor: str, *,
                    memory_limit: Optional[int],
                    donate_inputs: bool, count_inputs: bool,
                    size_cache=None, params_cache=None,
                    tracer=NULL_TRACER, arena_hard_cap=None):
    """Lower + wrap ``plan`` for one executor kind.

    ``executor="vm"`` lowers the plan to a flat :class:`Program` (the
    guaranteed peak bound decides whether the evict path is emitted) and
    runs it on :class:`ProgramVM`; ``"reference"`` keeps the op-by-op
    :class:`PlanInterpreter` for differential testing.  Returns
    ``(runner, program)`` — ``program`` is ``None`` for the reference
    interpreter."""
    if executor not in _EXECUTORS:
        raise ValueError(
            f"executor must be one of {_EXECUTORS}, got {executor!r}")
    if executor == "reference":
        interp = PlanInterpreter(plan, memory_limit=memory_limit,
                                 donate_inputs=donate_inputs,
                                 count_inputs=count_inputs,
                                 size_cache=size_cache,
                                 params_cache=params_cache,
                                 arena_hard_cap=arena_hard_cap)
        return interp, None
    with tracer.span("lower") as sp:
        program = lower_plan(plan, memory_limit=memory_limit,
                             donate_inputs=donate_inputs,
                             count_inputs=count_inputs,
                             peak_bound_bytes=report.peak_bound_bytes)
        sp.attrs["n_instructions"] = program.n_instructions
        sp.attrs["has_evict_path"] = program.has_evict_path
    return ProgramVM(program, size_cache=size_cache,
                     params_cache=params_cache,
                     arena_hard_cap=arena_hard_cap), program


def symbolic_dim(name: str):
    """A fresh symbolic dimension usable inside ShapeDtypeStruct shapes."""
    (d,) = export.symbolic_shape(name)
    return d


def symbolic_dims(spec: str):
    return export.symbolic_shape(spec)


def scan(body, init, xs=None, length=None):
    """``jax.lax.scan`` with rolled-loop compilation under ``optimize``.

    Inside a function passed to :func:`optimize`, a scan whose trip count
    is a *symbolic* dimension is traced once as a sub-graph and compiled
    to a single ``Loop`` node: the lowered ``Program`` stays O(body size)
    and the planned arena bound is independent of the trip count (carried
    values ping-pong between two slot generations across the back-edge;
    per-iteration temporaries die and their slots are reused every
    iteration).  Static-length scans — and bodies the roll gate cannot
    prove safe — fall back to ordinary unrolled tracing with identical
    results.  Outside ``optimize`` this is exactly ``jax.lax.scan``.
    """
    return jax.lax.scan(body, init, xs=xs, length=length)


@dataclass
class OptimizeReport:
    schedule: ScheduleResult
    n_candidates: int
    n_recomputable: int
    used_scheduled_order: bool
    # candidates whose regen method interval bounds fixed at compile time
    n_static_regen: int = 0
    # guaranteed worst-case peak bytes over the declared dim ranges
    # (None when some dim has no declared upper bound)
    peak_bound_bytes: Optional[int] = None
    peak_bound_lo: Optional[int] = None
    # memory planner (memory_plan="arena"): guaranteed worst-case arena
    # size over the declared dim ranges, slot count, planned reuse split
    arena_bound_bytes: Optional[int] = None
    n_arena_slots: int = 0
    n_provable_reuses: int = 0
    n_checked_reuses: int = 0
    # snapshot of ShapeGraph.cmp_stats after this compile: how many symbolic
    # comparisons resolved by constant difference / interval separation /
    # not at all (per-bucket reports show the specialization gain), plus the
    # memo-table cache_hit/cache_miss/inherited counters
    cmp_stats: Dict[str, int] = field(default_factory=dict)
    # the bucket partition (whole-range report only; None without buckets=)
    buckets: Optional[BucketSpace] = None
    # incremental bucket compile: True when this report's schedule + remat
    # plan were inherited from the whole-range compile because no verdict
    # they depended on flipped under the bucket's narrowed ranges
    reused_parent_schedule: bool = False
    # weaker reuse: the bucket's scheduler re-ran (some remat verdict
    # flipped) but reproduced the parent's raw order, so the parent's
    # guard/exchange post-pass result was adopted without re-simulation
    reused_parent_postpass: bool = False

    @property
    def cmp_symbolic_fraction(self) -> float:
        """Fraction of comparisons resolved (constant or interval layer)."""
        total = sum(self.cmp_stats.get(k, 0)
                    for k in ("const", "interval", "unknown"))
        if not total:
            return 1.0
        return 1.0 - self.cmp_stats.get("unknown", 0) / total


@dataclass
class PipelineArtifacts:
    """What one ``_compile_pipeline`` run hands to incremental re-runs.

    ``cmp_keys`` is the set of ``ShapeGraph.compare`` keys the scheduling
    and remat phases consulted (recorded via
    :meth:`ShapeGraph.record_cmp_keys`); ``sg`` is the graph whose memo
    holds those verdicts.  A bucket compile under ``sg.specialized(...)``
    reuses ``sched``/``candidates`` wholesale when
    :meth:`ShapeGraph.verdicts_match` proves none of those verdicts flip
    under the narrowed ranges — only the bounds-dependent phases (memory
    planning, peak bounds, lowering) re-run.
    """

    sched: ScheduleResult
    used_sched: bool
    candidates: Dict[int, Any]            # value id -> CandidateInfo
    cmp_keys: frozenset
    sg: ShapeGraph
    # the scheduler's raw order (node ids, before the best-of guard and
    # exchange refinement): a bucket whose re-run scheduler reproduces it
    # adopts the parent's guarded + exchanged final order without re-paying
    # the probe simulations
    raw_order_ids: Tuple[int, ...] = ()
    # shared range-independent expression caches: scheduler impact
    # polynomials and remat-search (impact, sources)/flops — re-running a
    # phase under a narrowed graph re-decides verdicts, not expressions
    sched_expr_cache: Dict = field(default_factory=dict)
    remat_expr_cache: Dict = field(default_factory=dict)
    # per-candidate compare keys of the remat search, for candidate-granular
    # reuse when only some verdicts flip under a bucket
    cand_cmp_keys: Dict[int, frozenset] = field(default_factory=dict)


def _compile_pipeline(
    graph, sg: ShapeGraph, *,
    enable_scheduling: bool = True,
    enable_remat: bool = True,
    memory_plan: str = "arena",
    donate_inputs: bool = False,
    count_inputs: bool = True,
    max_subgraph: int = 24,
    guard_env: Optional[Dict[str, int]] = None,
    parent: Optional[PipelineArtifacts] = None,
    collect: bool = False,
    tracer: Any = NULL_TRACER,
    decisions: Optional[DecisionLog] = None,
    kernel_select: bool = True,
    kernel_forced: Optional[Mapping[int, str]] = None,
) -> Tuple[ExecutionPlan, OptimizeReport, Optional[PipelineArtifacts]]:
    """schedule → remat → memplan over an already-traced graph.

    The compile-time half of :func:`optimize`, factored out so bucketed
    specialization can re-run it per bucket: the same graph compiles under
    a narrowed ``ShapeGraph`` (see :meth:`ShapeGraph.specialized`) and the
    tighter bounds resolve more decisions statically.

    ``collect=True`` records the compare keys the schedule + remat phases
    depend on and returns them as :class:`PipelineArtifacts` (third tuple
    element, else ``None``).  ``parent=`` makes this run *incremental*:
    when no recorded verdict flips under ``sg``'s narrowed ranges, the
    parent's schedule and remat candidates are reused (intervals refreshed
    under the tighter bounds) and only memory planning + peak bounds run.
    """
    from .remat.search import respecialize_candidates

    dl = decisions if decisions is not None else DecisionLog()

    def _cmp_delta(before: Dict[str, int]) -> Dict[str, int]:
        """How many comparisons this phase resolved per layer."""
        return {k: sg.cmp_stats.get(k, 0) - before.get(k, 0)
                for k in set(sg.cmp_stats) | set(before)
                if sg.cmp_stats.get(k, 0) != before.get(k, 0)}

    def _clamp(name: str, v: int) -> int:
        iv = sg.declared_ranges.get(name)
        if iv is None:
            return v
        if iv.lo is not None:
            v = max(v, iv.lo)
        if iv.hi is not None:
            v = min(v, iv.hi)
        return v

    sched = None
    candidates: Optional[Dict[int, Any]] = None
    used_sched = False
    reused = False
    reused_postpass = False
    raw_order_ids: Tuple[int, ...] = ()
    recorded: set = set()
    cand_keys: Dict[int, frozenset] = {}
    sched_cache = parent.sched_expr_cache if parent is not None else {}
    remat_cache = parent.remat_expr_cache if parent is not None else {}
    cmp0 = dict(sg.cmp_stats)
    with tracer.span("schedule", n_nodes=len(graph.nodes)) as _ssp:
        if parent is not None and enable_scheduling and \
                sg.verdicts_match(parent.sg, parent.cmp_keys):
            # incremental fast path: every schedule/remat decision would come
            # out identical — reuse them; bounds-dependent phases still re-run
            sched = parent.sched
            used_sched = parent.used_sched
            candidates = respecialize_candidates(parent.candidates, sg) \
                if enable_remat else {}
            reused = True
            dl.add("bucket-reuse", "schedule+remat", "inherit",
                   "no compare verdict the parent depended on flips under "
                   "the narrowed ranges",
                   n_candidates=len(candidates or {}))
        elif enable_scheduling:
            with sg.record_cmp_keys() as keys:
                sched = schedule_graph(graph, sg,
                                       impact_expr_cache=sched_cache)
            recorded |= keys
            raw_order_ids = tuple(n.id for n in sched.order)
            if parent is not None and parent.raw_order_ids == raw_order_ids:
                # the narrowed ranges changed some remat verdict but not the
                # schedule itself: adopt the parent's guarded + exchanged final
                # order (already proven no worse at the parent's probe envs)
                sched = ScheduleResult(list(parent.sched.order),
                                       sched.symbolic_decisions,
                                       sched.tiebreak_decisions)
                used_sched = parent.used_sched
                reused_postpass = True
                dl.add("bucket-reuse", "schedule post-pass", "inherit",
                       "re-run scheduler reproduced the parent's raw order; "
                       "adopting its guarded + exchanged result")
            else:
                # guard envs bind the *base* dims only; value-dependent
                # bounded dims complete to their caps per probe env (a
                # bounded dim's guard value must track its cap, not a
                # fixed 64)
                free_syms = graph.free_symbols() - set(graph.bound_dims)
                env = dict(guard_env) if guard_env else {
                    name: 64 for name in free_syms}
                for name in free_syms:
                    env.setdefault(name, 64)
                env = {k: _clamp(k, v) for k, v in env.items()}

                def _complete(e: Dict[str, int]) -> Dict[str, int]:
                    return complete_bound_env(graph, e) \
                        if graph.bound_dims else e

                probe_envs = [_complete(env),
                              _complete({k: _clamp(k, max(1, v // 4))
                                         for k, v in env.items()}),
                              _complete({k: _clamp(k, v * 4)
                                         for k, v in env.items()})]
                env = probe_envs[0]
                base = simulate_peak(graph, graph.nodes, env,
                                     count_inputs=count_inputs)
                tuned = simulate_peak(graph, sched.order, env,
                                      count_inputs=count_inputs)
                used_sched = tuned.peak_bytes <= base.peak_bytes
                kept_peak = min(tuned.peak_bytes, base.peak_bytes)
                dl.add("schedule-guard", "scheduled order",
                       "keep" if used_sched else "revert",
                       f"scheduled peak {tuned.peak_bytes:,} vs program "
                       f"order {base.peak_bytes:,} at the guard env",
                       guard_env=dict(env),
                       scheduled_peak=tuned.peak_bytes,
                       base_peak=base.peak_bytes)
                if not used_sched:  # keep the better order (never regress)
                    sched = ScheduleResult(list(graph.nodes),
                                           sched.symbolic_decisions,
                                           sched.tiebreak_decisions)
                # pairwise-exchange refinement (beyond-paper; guarded at probe
                # envs); the kept order's peak is already known — only the
                # refined order needs a fresh simulation
                from .scheduling.exchange import exchange_pass
                with tracer.span("exchange") as _xsp:
                    n_sw0 = len(dl.entries("exchange-swap"))
                    refined = exchange_pass(graph, sched.order, probe_envs,
                                            decisions=dl)
                    _xsp.attrs["n_swaps"] = \
                        len(dl.entries("exchange-swap")) - n_sw0
                refined_peak = simulate_peak(
                    graph, refined, env, count_inputs=count_inputs).peak_bytes
                if refined_peak <= kept_peak:
                    sched = ScheduleResult(refined, sched.symbolic_decisions,
                                           sched.tiebreak_decisions)
                    _xsp.attrs["adopted"] = True
                else:
                    dl.add("schedule-guard", "exchange refinement", "discard",
                           f"refined peak {refined_peak:,} exceeds kept "
                           f"peak {kept_peak:,} at the guard env")
                    _xsp.attrs["adopted"] = False
        else:
            sched = ScheduleResult(list(graph.nodes), 0, 0)
        _ssp.attrs.update(reused_parent=reused,
                          reused_postpass=reused_postpass,
                          used_scheduled_order=used_sched,
                          cmp=_cmp_delta(cmp0))

    arena_plan = None
    if memory_plan == "arena":
        with tracer.span("memplan") as _msp:
            arena_plan = build_arena_plan(graph, sched.order, sg,
                                          donate_inputs=donate_inputs)
            _msp.attrs.update(
                n_slots=arena_plan.n_slots,
                arena_bound_bytes=arena_plan.arena_bound_bytes,
                n_provable_reuses=arena_plan.n_provable_reuses,
                n_checked_reuses=arena_plan.n_checked_reuses)
            dl.add("slot-pack", "arena",
                   f"{arena_plan.n_slots} slots",
                   "liveness intervals packed by symbolic-size compatibility "
                   "(reuse proven through the shape graph)",
                   n_provable_reuses=arena_plan.n_provable_reuses,
                   n_checked_reuses=arena_plan.n_checked_reuses,
                   arena_bound_bytes=arena_plan.arena_bound_bytes)
    if candidates is not None:
        plan = ExecutionPlan(graph=graph, order=list(sched.order),
                             shape_graph=sg, candidates=candidates,
                             arena_plan=arena_plan)
    else:
        cmp1 = dict(sg.cmp_stats)
        with tracer.span("remat") as _rsp:
            with sg.record_cmp_keys() as keys:
                plan = build_plan(graph, sched, sg, enable_remat=enable_remat,
                                  max_subgraph=max_subgraph,
                                  arena_plan=arena_plan,
                                  remat_expr_cache=remat_cache,
                                  cand_keys_out=cand_keys if collect else None,
                                  parent_remat=None if parent is None else
                                  (parent.sg, parent.candidates,
                                   parent.cand_cmp_keys))
            _rsp.attrs.update(n_candidates=plan.n_candidates,
                              n_recomputable=plan.n_recomputable,
                              n_static_regen=plan.n_static_regen,
                              cmp=_cmp_delta(cmp1))
        recorded |= keys
        for vid, method in sorted(plan.static_methods.items()):
            dl.add("remat-static", f"%{vid}", method,
                   "interval bounds over the declared ranges fix the cheaper "
                   "regeneration method at compile time")
    if kernel_select:
        # kernel-variant selection: score every registered variant of every
        # kernel node over THIS plan's interval bounds — a bucket's narrowed
        # ranges pick aggressive blocks (or the reference crossover for
        # small shapes), the whole-range plan keeps whatever stays valid at
        # its widest corner.  Overrides live on the plan, never on the
        # shared graph nodes.
        from repro.kernels.variants import select_kernels
        with tracer.span("kernel-select") as _kspan:
            sels = select_kernels(graph, sg, forced=kernel_forced,
                                  decisions=dl)
            plan.kernel_selections = sels
            plan.kernel_overrides = {
                nid: s.variant.overrides() for nid, s in sels.items()}
            _kspan.attrs.update(
                n_kernels=len(sels),
                n_non_default=sum(1 for s in sels.values()
                                  if not s.is_default))
    peak_lo = peak_hi = None
    if sg.declared_ranges:  # without ranges the bound is vacuous (hi = None)
        with tracer.span("bounds") as _bsp:
            peak_lo, peak_hi = simulate_peak_bound(
                graph, sched.order, sg, count_inputs=count_inputs,
                donate_inputs=donate_inputs)
            _bsp.attrs.update(peak_bound_lo=peak_lo, peak_bound_bytes=peak_hi)
    report = OptimizeReport(schedule=sched,
                            n_candidates=plan.n_candidates,
                            n_recomputable=plan.n_recomputable,
                            used_scheduled_order=used_sched,
                            n_static_regen=plan.n_static_regen,
                            peak_bound_bytes=peak_hi,
                            peak_bound_lo=peak_lo,
                            cmp_stats=dict(sg.cmp_stats),
                            reused_parent_schedule=reused,
                            reused_parent_postpass=reused_postpass)
    if arena_plan is not None:
        # None whenever some live dim has no declared upper bound
        report.arena_bound_bytes = arena_plan.arena_bound_bytes
        report.n_arena_slots = arena_plan.n_slots
        report.n_provable_reuses = arena_plan.n_provable_reuses
        report.n_checked_reuses = arena_plan.n_checked_reuses
    artifacts = None
    if collect:
        artifacts = PipelineArtifacts(sched=sched, used_sched=used_sched,
                                      candidates=dict(plan.candidates),
                                      cmp_keys=frozenset(recorded), sg=sg,
                                      raw_order_ids=raw_order_ids,
                                      sched_expr_cache=sched_cache,
                                      remat_expr_cache=remat_cache,
                                      cand_cmp_keys=cand_keys)
    return plan, report, artifacts


class DynamicShapeFunction:
    """A compiled-once, run-any-shape callable with memory optimization."""

    def __init__(self, plan: ExecutionPlan, in_tree, out_tree,
                 report: OptimizeReport, *,
                 memory_limit: Optional[int] = None,
                 donate_inputs: bool = False,
                 count_inputs: bool = True,
                 executor: str = "vm",
                 table: Optional[SpecializationTable] = None,
                 table_factory: Optional[
                     Callable[[Optional[int]], SpecializationTable]] = None,
                 tracer: Optional[Tracer] = None,
                 decisions: Optional[DecisionLog] = None,
                 kernel_forced: Optional[Dict[Optional[BucketKey],
                                              Dict[int, str]]] = None,
                 kernel_remeasure_after: Optional[int] = None,
                 resilience_config: Optional[ResilienceConfig] = None,
                 fault_ref: Optional[FaultPlanRef] = None):
        self.plan = plan
        self._in_tree = in_tree
        self._out_tree = out_tree
        self.report = report
        self.executor = executor
        # observability: compile-span tree + decision log (shared with every
        # bucket compile), per-call telemetry off by default (see
        # enable_telemetry — the disabled hot path pays one attribute test)
        self.trace = tracer if tracer is not None else Tracer()
        self.decisions = decisions if decisions is not None else DecisionLog()
        self._telemetry: Optional[Telemetry] = None
        self._dispatch_ns_total = 0
        # lifetime counters shared across threads get one lock (the chaos
        # suite drives a single function from many request threads)
        self._stats_lock = threading.Lock()
        # resilience: degradation ladder + fault injection, off by default
        # (the disabled hot path is one attribute test, like telemetry).
        # The FaultPlanRef is shared with the bucket-compile closure so
        # inject_faults() can swap plans after the table factory captured it
        self._fault_ref = fault_ref if fault_ref is not None else FaultPlanRef()
        self._resilience_config = resilience_config
        self._resilience: Optional[ResilienceController] = None
        if resilience_config is not None:
            self._resilience = ResilienceController(
                resilience_config, fault_ref=self._fault_ref,
                decisions=self.decisions)
        arena_hard_cap = None
        if resilience_config is not None \
                and resilience_config.enforce_arena_bound:
            arena_hard_cap = report.arena_bound_bytes
        # `interp` is the runner for the monolithic plan: a ProgramVM over
        # the lowered Program (default) or the reference PlanInterpreter.
        # A background table already lowered the identical whole-range plan
        # for its fallback — adopt it instead of lowering twice
        if table is not None and table.fallback is not None:
            self.interp = table.fallback.interp
            self._program = table.fallback.program
        else:
            self.interp, self._program = _build_executor(
                plan, report, executor, memory_limit=memory_limit,
                donate_inputs=donate_inputs, count_inputs=count_inputs,
                tracer=self.trace, arena_hard_cap=arena_hard_cap)
        self.last_report: Optional[RunReport] = None
        # arena bound of the plan the most recent call actually executed
        # (the serving plan's guarantee: a bucket's tight bound on a hit,
        # the whole-range bound on fallback/monolithic calls)
        self.last_arena_bound: Optional[int] = None
        self._table = table
        self._table_factory = table_factory
        # bucket key the most recent call dispatched to (None: monolithic)
        self.last_bucket: Optional[BucketKey] = None
        # kernel measured fallback: per-bucket forced variants (shared with
        # the bucket compile closure — recompiles read it), the auto-trigger
        # threshold, per-bucket call counts, and in-flight measure threads
        self._memory_limit = memory_limit
        self._kernel_forced = kernel_forced if kernel_forced is not None else {}
        self._kernel_remeasure_after = kernel_remeasure_after
        self._kernel_calls: Dict[Optional[BucketKey], int] = {}
        self._kernel_measured: set = set()
        self._remeasure_threads: List[threading.Thread] = []

    def __call__(self, *args, **kwargs):
        flat, in_tree = tree_util.tree_flatten((args, kwargs))
        if in_tree != self._in_tree:
            raise TypeError(
                f"pytree structure mismatch: traced {self._in_tree}, got {in_tree}")
        res = self._resilience
        if res is not None:
            outs = self._call_resilient(res, flat)
        else:
            outs = self._dispatch(flat)
        return tree_util.tree_unflatten(self._out_tree, outs)

    def _dispatch(self, flat: List[Any], *, force_fallback: bool = False,
                  faults=None) -> List[Any]:
        """Select a plan and execute once (one ladder attempt).

        ``force_fallback=True`` serves the whole-range plan regardless of
        bucketing — the degradation ladder's remat-heavier retry rung,
        bitwise-identical to the bucket plans.  ``faults`` is an armed
        :class:`~repro.core.resilience.CallFaults` probe threaded down to
        the executor (``None`` keeps every hot loop uninstrumented)."""
        if self._table is None or force_fallback:
            if self._table is not None:
                env = solve_env(self.plan.graph, flat)
                self._check_declared(env)
                self.last_bucket = self._table.key_of(env)
                self.last_arena_bound = self.report.arena_bound_bytes
                outs, report = self.interp.run(flat, env=env, faults=faults)
            else:
                self.last_bucket = None
                self.last_arena_bound = self.report.arena_bound_bytes
                outs, report = self.interp.run(flat, faults=faults)
            prog = self._program
        else:
            t0 = time.perf_counter_ns()
            env = solve_env(self.plan.graph, flat)
            self._check_declared(env)
            bp, _hit = self._table.lookup(env)
            dispatch_ns = time.perf_counter_ns() - t0
            # bp.key is None when a background miss served the whole-range
            # fallback; re-derive the bucket from this request's own env
            # (shared table state could have moved under concurrent traffic).
            # Set before the run so a fault aborting it still leaves the
            # failing bucket on record for the degradation events.
            self.last_bucket = bp.key if bp.key is not None \
                else self._table.key_of(env)
            self.last_arena_bound = bp.report.arena_bound_bytes
            # env is solved + validated once, here; the interpreter trusts
            # it.  The began/ended bracket tells the background worker a
            # request is mid-flight so compiles defer instead of contending
            # (skipped without a worker: it is two lock round-trips per call)
            if self._table.background:
                self._table.request_began()
                try:
                    outs, report = bp.interp.run(flat, env=env, faults=faults)
                finally:
                    self._table.request_ended()
            else:
                outs, report = bp.interp.run(flat, env=env, faults=faults)
            st = report.stats
            st.last_dispatch_ns = dispatch_ns
            with self._stats_lock:
                self._dispatch_ns_total += dispatch_ns
                st.dispatch_ns_total = self._dispatch_ns_total
            st.bucket_hits = self._table.hits
            st.specialize_count = self._table.specialize_count
            prog = bp.program
            if self._kernel_remeasure_after is not None and \
                    self.last_bucket is not None:
                self._maybe_remeasure(self.last_bucket, env)
        self.last_report = report
        tel = self._telemetry
        if tel is not None:
            self._record_call(tel, report, prog)
        return outs

    def _call_resilient(self, res: ResilienceController,
                        flat: List[Any]) -> List[Any]:
        """Degradation-ladder dispatch (resilience enabled).

        Rungs, in order: the plain dispatch (whose executor already runs
        eviction under memory pressure before anything escapes), a
        bounded same-plan retry for transient faults, a retry on the
        remat-heavier whole-range fallback plan for memory pressure and
        quarantined/failed bucket compiles (bitwise-identical results),
        and finally a structured :class:`RequestFailed`.  Every step is
        recorded as a :class:`~repro.core.resilience.DegradationEvent`
        on the controller, the decision log, and Prometheus counters.
        Malformed requests reject immediately — client errors never
        retry."""
        seq = res.begin_call()
        fp = res.fault_plan
        armed = fp.arm_call(seq) if fp is not None else None
        if armed is not None and armed.take_malformed():
            res.note_degraded_call()
            ev = res.record("reject-malformed", seq=seq, attempt=0,
                            cause="malformed-env")
            raise RequestFailed(
                f"call {seq}: malformed request rejected before dispatch",
                attempts=0, events=(ev,))
        pol = res.config.retry
        events: List[Any] = []
        attempt = 0
        force_fb = False
        degraded = False
        while True:
            try:
                return self._dispatch(flat, force_fallback=force_fb,
                                      faults=armed)
            except (TransientKernelError, RegenFailure,
                    OffloadFailure) as e:
                err, rung, fb_next = e, "retry-transient", force_fb
            except (MemoryLimitExceeded, ArenaExhausted) as e:
                err, rung, fb_next = e, "retry-fallback", True
            except (CompileFault, BucketQuarantined) as e:
                err, rung, fb_next = e, "retry-fallback", True
            if not degraded:
                degraded = True
                res.note_degraded_call()
            if attempt >= pol.max_retries:
                events.append(res.record("reject", seq=seq, attempt=attempt,
                                         cause=err, bucket=self.last_bucket))
                try:
                    env = solve_env(self.plan.graph, flat)
                except Exception:
                    env = None
                raise RequestFailed(
                    f"call {seq} failed after {attempt + 1} attempt(s): "
                    f"{err!r}", env=env, bucket=self.last_bucket,
                    attempts=attempt + 1, cause=err,
                    events=tuple(events)) from err
            backoff = pol.backoff_s(attempt)
            events.append(res.record(rung, seq=seq, attempt=attempt,
                                     cause=err, backoff_s=backoff,
                                     bucket=self.last_bucket))
            if backoff > 0:
                res.sleep(backoff)
            force_fb = fb_next
            attempt += 1
            # re-arm per attempt: specs spent on this attempt no longer
            # match, which is what lets a bounded retry actually recover
            armed = fp.arm_call(seq) if fp is not None else None

    def _record_call(self, tel: Telemetry, report: RunReport,
                     program: Optional[Program]) -> None:
        """Telemetry-enabled path only (never reached when disabled)."""
        trips: Tuple[int, ...] = ()
        if program is not None and program.loops:
            trips = tuple(rl.trip
                          for rl in program.resolve(report.env).loops)
        key = self.last_bucket if self._table is not None else None
        tel.on_call(key, report, program=program, loop_trips=trips)

    def _check_declared(self, env: Dict[str, int]) -> None:
        """Declared-range contract check against the *whole-range* graph —
        before bucket dispatch, so an out-of-range dim cannot land in an
        edge bucket and fail there with a misleading sub-range message.
        Same helper both executors use on the non-bucketed path."""
        check_declared_ranges(self.plan.shape_graph, env)

    # -- observability ----------------------------------------------------------
    def explain(self, env: Optional[Dict[str, int]] = None) -> str:
        """Human-readable compile report: phase spans, decision log,
        per-slot symbolic sizes + liveness intervals, frozen-vs-runtime
        remat decisions, bucket table — and, when ``env`` is given, the
        plan-vs-actual memory timeline diff at that dim binding."""
        from .obs.explain import build_explain
        return build_explain(self, env=env)

    def memory_timeline(self, env: Mapping[str, int]):
        """Plan-vs-actual :class:`~repro.core.obs.timeline.TimelineDiff`
        at one env: reconstructed actual arena occupancy over the program
        counter, diffed against the plan's predicted occupancy (VM
        executor only — the reference interpreter has no lowered stream).
        Uses the env's bucket Program when one is resident."""
        from .obs.timeline import diff_timeline
        env = dict(env)
        prog = self._program
        if self._table is not None:
            bp = self._table.peek(self._table.key_of(env))
            if bp is not None and bp.program is not None:
                prog = bp.program
        if prog is None:
            raise ValueError(
                'memory_timeline requires executor="vm" (no lowered '
                "Program under the reference interpreter)")
        return diff_timeline(prog, env)

    def enable_telemetry(self, capacity: int = 256,
                         sample_timeline_every: int = 0) -> Telemetry:
        """Attach a per-call telemetry ring (see
        :class:`repro.core.obs.Telemetry`).  ``sample_timeline_every=N``
        additionally reconstructs the exact per-instruction memory
        timeline of every N-th call (off the hot path, VM executor only).
        Returns the live aggregate; read it any time, detach with
        :meth:`disable_telemetry`."""
        self._telemetry = Telemetry(
            capacity=capacity, sample_timeline_every=sample_timeline_every)
        return self._telemetry

    def disable_telemetry(self) -> Optional[Telemetry]:
        """Detach and return the telemetry aggregate (``None`` if off).
        The hot path reverts to the single disabled-check immediately."""
        tel, self._telemetry = self._telemetry, None
        return tel

    @property
    def telemetry(self) -> Optional[Telemetry]:
        return self._telemetry

    # -- resilience --------------------------------------------------------------
    @property
    def resilience(self) -> Optional[ResilienceController]:
        """The attached resilience controller (``None`` when disabled)."""
        return self._resilience

    def enable_resilience(self, config: Optional[ResilienceConfig] = None
                          ) -> ResilienceController:
        """Attach the degradation ladder (see :class:`ResilienceConfig`).

        Calls then route through ``_call_resilient``: runtime failures
        walk retry-transient → retry-fallback → structured
        :class:`RequestFailed` instead of escaping raw.  Returns the live
        controller (counters, recent events); detach with
        :meth:`disable_resilience` — the hot path reverts to the single
        disabled check immediately."""
        self._resilience = ResilienceController(
            config, fault_ref=self._fault_ref, decisions=self.decisions)
        self._resilience_config = self._resilience.config
        return self._resilience

    def disable_resilience(self) -> Optional[ResilienceController]:
        """Detach and return the controller (``None`` if off)."""
        res, self._resilience = self._resilience, None
        return res

    @contextmanager
    def inject_faults(self, plan: FaultPlan):
        """Install a :class:`FaultPlan` for the duration of the block.

        Enables a default-config resilience controller if none is
        attached (and detaches it again on exit); the previously
        installed plan is restored either way.  Yields the active
        controller so the block can read counters/events directly."""
        prev_plan = self._fault_ref.plan
        attached = self._resilience is None
        if attached:
            self.enable_resilience()
        self._fault_ref.plan = plan
        try:
            yield self._resilience
        finally:
            self._fault_ref.plan = prev_plan
            if attached:
                self._resilience = None

    @property
    def program(self) -> Optional[Program]:
        """The lowered executable artifact (``None`` with the reference
        executor).  With ``buckets=...`` this is the whole-range plan's
        Program; per-bucket Programs live on the specialization table's
        ``BucketPlan.program``."""
        return self._program

    # -- bucketed specialization ------------------------------------------------
    @property
    def specialization_table(self) -> Optional[SpecializationTable]:
        """The per-bucket plan cache (``None`` without ``buckets=...``)."""
        return self._table

    def warmup(self, envs: Iterable[Mapping[str, int]]) -> List[BucketKey]:
        """Compile the buckets containing ``envs`` before serving traffic.

        Synchronous, idempotent, runs nothing — it only specializes plans
        so first-request latency does not pay the compile.  ``envs`` is an
        iterable of dim bindings (a single mapping is also accepted);
        returns the distinct bucket keys now resident.
        """
        if self._table is None:
            raise ValueError(
                "warmup() requires bucketed dispatch — pass "
                "optimize(..., buckets=...)")
        if isinstance(envs, Mapping):
            envs = [envs]
        return self._table.warmup(envs)

    def drain_specializations(self, timeout: Optional[float] = None) -> List[BucketKey]:
        """Block until every in-flight background specialization lands.

        The deterministic join for ``background_specialize=True``: after it
        returns, every bucket that traffic has touched is compiled and the
        table's ``specialize_count`` matches what synchronous specialization
        would have produced.  Returns the bucket keys that completed while
        draining; a no-op (empty list) without bucketed dispatch or with
        nothing in flight."""
        if self._table is None:
            return []
        for t in list(self._remeasure_threads):
            t.join(timeout)
            if not t.is_alive():
                self._remeasure_threads.remove(t)
        return self._table.drain_background(timeout=timeout)

    # -- kernel-variant measured fallback ---------------------------------------
    def _maybe_remeasure(self, key: "BucketKey", env: Dict[str, int]) -> None:
        """Auto-trigger: after ``kernel_remeasure_after`` calls land in a
        bucket, time the variant candidates at that bucket's traffic shape
        and re-select — once per bucket.  Runs off-thread on a background
        table (the compile lock serializes the swap); inline otherwise."""
        n = self._kernel_calls.get(key, 0) + 1
        self._kernel_calls[key] = n
        if key in self._kernel_measured or n < self._kernel_remeasure_after:
            return
        if not self.plan.kernel_selections:
            return
        self._kernel_measured.add(key)
        if self._table is not None and self._table.background:
            t = threading.Thread(
                target=lambda: self.remeasure_kernels(env),
                name="kernel-remeasure", daemon=True)
            self._remeasure_threads.append(t)
            t.start()
        else:
            self.remeasure_kernels(env)

    def remeasure_kernels(self, env: Optional[Mapping[str, int]] = None, *,
                          repeats: int = 3) -> Dict[int, str]:
        """Measured fallback for kernel-variant selection.

        Wall-times every VMEM-valid variant of every kernel node at the
        concrete dim binding ``env`` (default: the most recent call's),
        forces the per-node winners — restricted to variants that stay
        valid over the *whole* target range, so the swapped plan keeps the
        fallback-safety property — and rebuilds the plan: the env's bucket
        plan under bucketed dispatch (atomically swapped via the table),
        else the monolithic plan.  Returns node id -> winning variant name;
        the timings land in the decision log (kind ``kernel-measure``).
        """
        from repro.kernels.variants import (measure_variants, node_bounds,
                                            select_kernels, variant_valid,
                                            variants_for)
        if env is None:
            if self.last_report is None:
                raise ValueError(
                    "remeasure_kernels needs an env (no call recorded yet)")
            env = self.last_report.env
        env = dict(env)
        if not self.plan.kernel_selections:
            return {}
        key = None
        sg = self.plan.shape_graph
        if self._table is not None:
            key = self._table.key_of(env)
            sg = sg.specialized(self._table.space.ranges_of(key))
        graph = self.plan.graph
        forced: Dict[int, str] = {}
        for nid, sel in self.plan.kernel_selections.items():
            node = self.plan.node_by_id[nid]
            timings = measure_variants(sel.prim_name, node, env,
                                       repeats=repeats)
            # the winner must stay valid at the target range's hi corner,
            # not just at this env — never trade safety for speed
            hi = {k: h for k, (_lo, h) in node_bounds(node, sg).items()}
            itemsize = int(node.invals[0].dtype.itemsize)
            ranked = sorted(timings.items(), key=lambda kv: kv[1])
            by_name = {v.name: v for v in variants_for(sel.prim_name)}
            for name, t_s in ranked:
                if variant_valid(sel.prim_name, by_name[name], hi, itemsize):
                    forced[nid] = name
                    break
            self.decisions.add(
                "kernel-measure", f"%{nid} {sel.prim_name}",
                forced.get(nid, sel.variant.name),
                f"measured best-of-{repeats} at "
                + " ".join(f"{k}={v}" for k, v in sorted(env.items())),
                timings_us={k: round(v * 1e6, 1) for k, v in ranked},
                bucket=key)
        if self._table is not None:
            self._kernel_forced[key] = forced
            self._table.recompile(key)
        else:
            sels = select_kernels(graph, sg, forced=forced,
                                  decisions=self.decisions)
            self.plan.kernel_selections = sels
            self.plan.kernel_overrides = {
                nid: s.variant.overrides() for nid, s in sels.items()}
            self.interp, self._program = _build_executor(
                self.plan, self.report, self.executor,
                memory_limit=self._memory_limit,
                donate_inputs=self.interp.donate_inputs,
                count_inputs=self.interp.count_inputs,
                tracer=self.trace,
                arena_hard_cap=getattr(self.interp, "arena_hard_cap", None))
        return forced

    @property
    def guaranteed_peak_bytes(self) -> Optional[int]:
        """Compile-time worst-case peak over the declared dim ranges.

        ``None`` unless every symbolic dim was given an upper bound via
        ``optimize(..., dynamic_dims=...)``.  For every call whose dims lie
        within the declared ranges, the free-run device peak is <= this.
        """
        return self.report.peak_bound_bytes

    @property
    def arena_plan(self) -> Optional["ArenaPlan"]:
        return self.plan.arena_plan

    @property
    def arena_bound_bytes(self) -> Optional[int]:
        """Compile-time worst-case planned arena size over the declared dim
        ranges (``None`` without ``memory_plan="arena"`` + bounded dims).
        Per-bucket bounds are tighter: see
        ``specialization_table.arena_bound_bytes(key)``."""
        return self.report.arena_bound_bytes

    # reconfigure without retracing (the VM re-lowers — cheap next to the
    # pipeline — because the limit decides whether the evict path is emitted)
    def with_memory_limit(self, limit: Optional[int]) -> "DynamicShapeFunction":
        table = self._table_factory(limit) if self._table_factory else None
        return DynamicShapeFunction(self.plan, self._in_tree, self._out_tree,
                                    self.report,
                                    memory_limit=limit,
                                    donate_inputs=self.interp.donate_inputs,
                                    count_inputs=self.interp.count_inputs,
                                    executor=self.executor,
                                    table=table,
                                    table_factory=self._table_factory,
                                    tracer=self.trace,
                                    decisions=self.decisions,
                                    kernel_forced=self._kernel_forced,
                                    kernel_remeasure_after=self._kernel_remeasure_after,
                                    resilience_config=self._resilience_config,
                                    fault_ref=self._fault_ref)


def optimize(
    fn: Callable,
    *example_args,
    shape_graph: Optional[ShapeGraph] = None,
    dynamic_dims: Optional[Dict[str, Any]] = None,
    enable_scheduling: bool = True,
    enable_remat: bool = True,
    memory_limit: Optional[int] = None,
    donate_inputs: bool = False,
    count_inputs: bool = True,
    max_subgraph: int = 24,
    guard_env: Optional[Dict[str, int]] = None,
    memory_plan: str = "arena",
    buckets: Optional[BucketsSpec] = None,
    max_cached_plans: int = 16,
    background_specialize: bool = False,
    executor: str = "vm",
    kernel_select: bool = True,
    kernel_remeasure_after: Optional[int] = None,
    resilience: Union[ResilienceConfig, bool, None] = None,
    fault_plan: Optional[FaultPlan] = None,
    **example_kwargs,
) -> DynamicShapeFunction:
    """Trace ``fn`` symbolically and build the optimized dynamic-shape plan.

    ``example_args``: ShapeDtypeStructs (shapes may contain symbolic dims
    from :func:`symbolic_dim`).  ``dynamic_dims``: declared ranges per
    symbolic dim name — e.g. ``{"b": (1, 64), "s": "<=4096"}`` (see
    :func:`repro.core.symbolic.parse_range_spec`) — feeding the interval
    fallback of symbolic comparisons; with every dim bounded above, the
    report carries a guaranteed worst-case peak (``peak_bound_bytes``).
    ``guard_env``: representative dim binding used to verify the scheduled
    order does not regress peak memory vs the original program order
    (best-of safeguard); defaults to all dims = 64, clamped into the
    declared ranges.
    ``memory_plan``: ``"arena"`` (default) runs the symbolic memory
    planner — compile-time buffer-reuse slots + a runtime arena whose
    stats land on ``last_report.stats`` (``arena_bytes``, ``slots``,
    ``reuse_ratio``, ``fragmentation_bytes``); ``"none"`` disables it.
    ``buckets``: partition the declared ranges into shape buckets and
    specialize the whole pipeline per bucket — ``"geometric"`` / an int
    count / a per-dim mapping ``{dim: count | [edges...]}`` (see
    :func:`repro.core.dispatch.build_bucket_space`); requires
    ``dynamic_dims``.  Calls dispatch to their bucket's plan; buckets
    compile lazily on first use (or via :meth:`DynamicShapeFunction.warmup`)
    and at most ``max_cached_plans`` stay resident (LRU).
    ``background_specialize``: with ``buckets=``, a bucket miss no longer
    compiles on the request thread — the request is served immediately by
    the whole-range fallback plan (always valid for any in-range env)
    while a background worker runs the bucket's pipeline and atomically
    swaps the compiled plan into the table; join deterministically via
    :meth:`DynamicShapeFunction.warmup` or
    :meth:`DynamicShapeFunction.drain_specializations`.
    ``executor``: ``"vm"`` (default) lowers each compiled plan to a flat
    :class:`Program` executed by the register VM — per-call work is one
    cached ``resolve`` plus the instruction stream; ``"reference"`` keeps
    the op-by-op :class:`PlanInterpreter` (differential testing).
    ``kernel_select``: score the registered kernel-variant tables
    (:mod:`repro.kernels.variants`) over each plan's interval bounds and
    bake the cheapest valid configuration — block sizes, pipeline depth,
    ref-vs-pallas crossover — into the lowered ``Compute`` params;
    per-bucket plans select per bucket, the whole-range plan keeps a
    variant valid anywhere in its range.  ``False`` leaves every kernel on
    its call-site/default configuration.
    ``kernel_remeasure_after``: measured fallback — after N calls land in
    a bucket, wall-time the variant candidates at that traffic's shape and
    atomically swap a re-selected plan if the model mispredicted (see
    :meth:`DynamicShapeFunction.remeasure_kernels` for the manual form).
    ``resilience``: attach the fault-tolerant call path — ``True`` for
    the default :class:`ResilienceConfig`, or a config instance.  Runtime
    failures then walk the degradation ladder (same-plan retry for
    transient faults, whole-range-fallback retry for memory pressure and
    quarantined buckets, structured :class:`RequestFailed` when retries
    exhaust) instead of escaping raw; bucket-compile failures quarantine
    behind a circuit breaker with exponential backoff while the fallback
    keeps serving.  ``None``/``False`` keeps the zero-overhead direct
    path (one attribute test per call).
    ``fault_plan``: install a deterministic
    :class:`~repro.core.resilience.FaultPlan` (chaos testing); implies
    ``resilience=True`` when ``resilience`` is unset.  Swap plans later
    with :meth:`DynamicShapeFunction.inject_faults`.
    """
    if memory_plan not in ("arena", "none"):
        raise ValueError(
            f"memory_plan must be 'arena' or 'none', got {memory_plan!r}")
    if background_specialize and buckets is None:
        raise ValueError(
            "background_specialize=True requires bucketed dispatch — pass "
            "optimize(..., buckets=...)")
    if executor not in _EXECUTORS:
        raise ValueError(
            f"executor must be one of {_EXECUTORS}, got {executor!r}")
    if isinstance(resilience, ResilienceConfig):
        r_cfg: Optional[ResilienceConfig] = resilience
    elif resilience:
        r_cfg = ResilienceConfig()
    elif resilience is None and fault_plan is not None:
        r_cfg = ResilienceConfig()   # a fault plan implies the ladder
    else:
        r_cfg = None
    fault_ref = FaultPlanRef(fault_plan)
    tracer = Tracer()
    decisions = DecisionLog()
    with tracer.span("trace") as _tsp:
        graph, _ = trace_to_graph(fn, *example_args, **example_kwargs)
        _tsp.attrs["n_nodes"] = len(graph.nodes)
    sg = shape_graph if shape_graph is not None else ShapeGraph()
    if dynamic_dims:
        known = graph.free_symbols()
        unknown = sorted(set(dynamic_dims) - known)
        if unknown:
            raise ValueError(
                f"dynamic_dims names {unknown} are not symbolic dims of the "
                f"traced function (known: {sorted(known)})")
    declare_dim_ranges(sg, dynamic_dims)
    # value-dependent bounded dims: the trace introduced fresh symbols with a
    # cap expression over input dims — declare each so interval/compare
    # queries answer through the cap without a user-declared range
    for _bname, _cap in graph.bound_dims.items():
        sg.declare_bound(_bname, _cap)

    knobs = dict(enable_scheduling=enable_scheduling,
                 enable_remat=enable_remat,
                 memory_plan=memory_plan,
                 donate_inputs=donate_inputs,
                 count_inputs=count_inputs,
                 max_subgraph=max_subgraph,
                 guard_env=guard_env,
                 tracer=tracer,
                 decisions=decisions,
                 kernel_select=kernel_select)
    # measured-fallback channel: bucket key -> {node id -> forced variant}.
    # remeasure_kernels fills it, then a table recompile re-runs the bucket
    # pipeline, whose selection honors the forced names (None: whole-range)
    kernel_forced: Dict[Optional[BucketKey], Dict[int, str]] = {}
    # collect the schedule/remat artifacts + their compare-key dependencies
    # so per-bucket specialization can re-run incrementally
    plan, report, artifacts = _compile_pipeline(graph, sg, collect=True,
                                                **knobs)

    table_factory = None
    if buckets is not None:
        # bucket space spans base (call-entry) dims only: bound dims are
        # measured mid-call, so dispatch can never key on them — per-bucket
        # specialization re-derives their caps from the narrowed base ranges
        space = build_bucket_space(
            {k: v for k, v in sg.declared_ranges.items()
             if k not in graph.bound_dims}, buckets)
        report.buckets = space
        # one shared per-env cache pair across every bucket interpreter:
        # plan swap between buckets re-derives no sizes/params
        size_cache: Dict[Tuple, Dict[int, int]] = {}
        params_cache: Dict[Tuple, Dict[int, Dict[str, Any]]] = {}

        def _hard_cap(rep: OptimizeReport) -> Optional[int]:
            """Per-plan enforced cap under resilience.enforce_arena_bound:
            each executor is held to *its own* plan's guarantee."""
            if r_cfg is not None and r_cfg.enforce_arena_bound:
                return rep.arena_bound_bytes
            return None

        def table_factory(limit: Optional[int],
                          _space=space) -> SpecializationTable:
            def compile_bucket(key, ranges) -> BucketPlan:
                # chaos hook: an installed fault plan may schedule this
                # bucket's specialization to fail or hang (the breaker
                # quarantines it; the fallback keeps serving)
                fpl = fault_ref.plan
                if fpl is not None:
                    fpl.check_compile(key)
                # a background-worker compile shows up as its own root span
                # (the tracer's span stack is thread-local) tagged here, so
                # traces distinguish swap-in compiles from blocking ones
                bg = threading.current_thread().name.startswith("specialize")
                with tracer.span("specialize", bucket=key,
                                 background=bg) as sp:
                    sub_sg = sg.specialized(ranges)
                    b_plan, b_report, _ = _compile_pipeline(
                        graph, sub_sg, parent=artifacts,
                        kernel_forced=kernel_forced.get(key), **knobs)
                    runner, b_program = _build_executor(
                        b_plan, b_report, executor, memory_limit=limit,
                        donate_inputs=donate_inputs,
                        count_inputs=count_inputs,
                        size_cache=size_cache, params_cache=params_cache,
                        tracer=tracer, arena_hard_cap=_hard_cap(b_report))
                    sp.attrs.update(
                        reused_parent_schedule=b_report.reused_parent_schedule,
                        reused_parent_postpass=b_report.reused_parent_postpass,
                        arena_bound_bytes=b_report.arena_bound_bytes)
                return BucketPlan(key=key, ranges=ranges, plan=b_plan,
                                  report=b_report, interp=runner,
                                  program=b_program)
            fallback = None
            if background_specialize:
                f_runner, f_program = _build_executor(
                    plan, report, executor, memory_limit=limit,
                    donate_inputs=donate_inputs, count_inputs=count_inputs,
                    size_cache=size_cache, params_cache=params_cache,
                    tracer=tracer, arena_hard_cap=_hard_cap(report))
                fallback = BucketPlan(key=None, ranges=dict(sg.declared_ranges),
                                      plan=plan, report=report,
                                      interp=f_runner, program=f_program)
            return SpecializationTable(
                _space, compile_bucket,
                max_live=max_cached_plans,
                background=background_specialize,
                fallback=fallback,
                breaker=CircuitBreaker(r_cfg.breaker if r_cfg else None),
                compile_timeout_s=(r_cfg.compile_timeout_s
                                   if r_cfg else None))

    flat, in_tree = tree_util.tree_flatten((example_args, example_kwargs))
    out_shapes = jax.eval_shape(fn, *example_args, **example_kwargs)
    _, out_tree = tree_util.tree_flatten(out_shapes)
    return DynamicShapeFunction(
        plan, in_tree, out_tree, report,
        memory_limit=memory_limit,
        donate_inputs=donate_inputs,
        count_inputs=count_inputs,
        executor=executor,
        table=table_factory(memory_limit) if table_factory else None,
        table_factory=table_factory,
        tracer=tracer,
        decisions=decisions,
        kernel_forced=kernel_forced,
        kernel_remeasure_after=kernel_remeasure_after,
        resilience_config=r_cfg,
        fault_ref=fault_ref)
