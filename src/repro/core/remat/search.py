"""Compile-time recomputation-subgraph search (paper §2.3).

For each rematerialization candidate tensor, grow a recompute subgraph
backwards from its producer, evaluating the *symbolic* memory impact of each
candidate subgraph:

    impact(S) = bytes(target) − Σ bytes(sources of S that are not always-live)

Graph inputs and constants are always live, so they contribute no cost
(this reproduces the paper's Listing-1 walkthrough: {Reduce} → −11007·S1,
{Reduce,Dot} → −11·S1, {Reduce,Dot,Reshape} → +1·S1).  The best subgraph
seen is kept; a candidate is *recomputable* iff its best impact is
definitely positive under the shape graph.  Reload (offload) plans are
always available and memory-neutral.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..ir.graph import Graph, Node, Value
from ..ir.loop import is_loop_node
from ..symbolic import Cmp, Interval, ShapeGraph, SymbolicExpr, ZERO

# Relative cost model shared by compile-time pruning (here) and runtime victim
# scoring (remat/runtime.py): recompute cost ~ flops * RECOMPUTE_COST_PER_FLOP,
# offload+reload cost ~ bytes * (D2H + H2D).  Only the ratios matter.
RECOMPUTE_COST_PER_FLOP = 1.0 / 50.0   # flops are cheap relative to transfers
RELOAD_COST_PER_BYTE = 1.0             # H2D per byte
OFFLOAD_COST_PER_BYTE = 1.0            # D2H per byte (paid at eviction)

# rough per-primitive cost model (symbolic FLOPs) -----------------------------


def node_flops(n: Node) -> SymbolicExpr:
    if n.prim_name == "dot_general":
        dnums = n.params.get("dimension_numbers")
        lhs, rhs, out = n.invals[0], n.invals[1], n.outvals[0]
        # flops = 2 * prod(out dims) * prod(contracting dims of lhs)
        (lc, _rc), _ = dnums
        k = ZERO + 1
        for d in lc:
            k = k * lhs.dims[d]
        return 2 * out.size_expr * k
    if n.prim_name in ("conv_general_dilated",):
        return 2 * n.outvals[0].size_expr  # lower bound; convs unused in LMs here
    # elementwise / data movement: one flop per output element
    total = ZERO
    for ov in n.outvals:
        total = total + ov.size_expr
    return total


@dataclass
class RecomputePlan:
    target: Value
    node_ids: Tuple[int, ...]            # topo-ordered subgraph (graph node ids)
    source_ids: Tuple[int, ...]          # value ids that must be materialized
    impact: SymbolicExpr                 # symbolic memory benefit of evicting
    flops: SymbolicExpr                  # symbolic recompute cost
    # guaranteed ranges over the shape graph's declared dim bounds, computed
    # once at search time so the runtime policy never re-derives them
    impact_interval: Interval = Interval()
    flops_interval: Interval = Interval()


@dataclass
class CandidateInfo:
    value: Value
    recompute: Optional[RecomputePlan]   # None if no beneficial subgraph found
    offloadable: bool = True             # reload is always available
    bytes_interval: Interval = Interval()  # guaranteed range of device bytes
    # True when a beneficial recompute plan existed but interval bounds
    # proved reload always cheaper, so it was dropped at compile time
    recompute_pruned_by_bounds: bool = False


def static_regen_method(cand: CandidateInfo) -> Optional[str]:
    """Decide recompute-vs-offload at compile time when bounds prove it.

    Returns ``'recompute'`` / ``'offload'`` when one regeneration method is
    cheaper for *every* env within the declared dim ranges, else ``None``
    (the runtime policy evaluates concretely).  Candidates without a
    recompute plan are always ``'offload'``.
    """
    if cand.recompute is None:
        return "offload"
    flops = cand.recompute.flops_interval
    nbytes = cand.bytes_interval
    per_byte = RELOAD_COST_PER_BYTE + OFFLOAD_COST_PER_BYTE
    if flops.hi is not None and nbytes.lo is not None and \
            flops.hi * RECOMPUTE_COST_PER_FLOP <= nbytes.lo * per_byte:
        return "recompute"
    if nbytes.hi is not None and flops.lo is not None and \
            flops.lo * RECOMPUTE_COST_PER_FLOP >= nbytes.hi * per_byte:
        return "offload"
    return None


class RecomputeSearcher:
    """``expr_cache`` (optional, shareable) memoizes the *expressions* the
    search builds — subgraph impacts, source lists, per-node flops — keyed
    on graph structure only.  They are range-independent, so bucketed
    specialization passes one cache to every per-bucket search: each
    bucket re-decides the (cheap, memoized) ``compare`` verdicts under its
    narrowed ranges but never rebuilds a polynomial the whole-range search
    already assembled."""

    def __init__(self, graph: Graph, shape_graph: Optional[ShapeGraph] = None,
                 *, max_subgraph: int = 24,
                 expr_cache: Optional[Dict] = None):
        self.g = graph
        self.sg = shape_graph if shape_graph is not None else ShapeGraph()
        self.max_subgraph = max_subgraph
        self._output_ids = {v.id for v in graph.outputs}
        self._cache: Dict = expr_cache if expr_cache is not None else {}
        # pick-the-biggest-source results, keyed by the tuple of candidate
        # *size-expression* uids.  Transformer layers repeat the same size
        # tuples hundreds of times; the argmax depends only on the sizes and
        # this graph's verdicts, so it is shared per searcher (per compile),
        # not across shape graphs.  Each entry stores the compare keys its
        # argmax consulted: a memo hit replays them into any active
        # dependency recording (per-candidate reuse would otherwise miss
        # verdicts a flipped bucket could change)
        self._pick_memo: Dict[Tuple[int, ...], Tuple[int, frozenset]] = {}

    def _sources(self, nodes: Set[Node]) -> List[Value]:
        produced = {ov.id for n in nodes for ov in n.outvals}
        srcs: Dict[int, Value] = {}
        for n in nodes:
            for iv in n.invals:
                if iv.id not in produced:
                    srcs[iv.id] = iv
        return list(srcs.values())

    def _impact(self, target: Value, nodes: Set[Node]) -> SymbolicExpr:
        imp = target.nbytes_expr
        for src in self._sources(nodes):
            if src.is_materialized_input():
                continue  # always live, no extra retention cost
            imp = imp - src.nbytes_expr
        return imp

    def _node_flops(self, n: Node) -> SymbolicExpr:
        key = ("nflops", n.id)
        hit = self._cache.get(key)
        if hit is None:
            hit = node_flops(n)
            self._cache[key] = hit
        return hit

    def search(self, target: Value,
               bytes_interval: Optional[Interval] = None) -> Optional[RecomputePlan]:
        """Greedy backward growth, keeping the best symbolic impact seen.

        The subgraph's impact expression and source set are maintained
        *incrementally* as nodes are absorbed — absorbing ``p`` removes the
        sources ``p`` produces (their bytes return to the impact) and adds
        ``p``'s own unproduced inputs — and each grown state is memoized in
        ``expr_cache`` keyed on ``(target, subgraph)``, so re-searching the
        same region (another bucket's compile, an overlapping candidate)
        replays cached polynomials instead of rebuilding them term by term.
        """
        if target.producer is None or is_loop_node(target.producer):
            # rolled loops are remat barriers: re-running a trip-count-many
            # iteration body is never the cheap side of the trade, and remat
            # decisions are hoisted out of the body by construction
            return None
        # bounds-based compile-time prune: a target whose worst-case byte
        # count is zero can never free memory, skip the subgraph search
        if bytes_interval is None:
            bytes_interval = self.sg.interval_of(target.nbytes_expr)
        if bytes_interval.hi == 0:
            return None
        p0 = target.producer
        sub_ids = frozenset((p0.id,))
        sub_nodes: Set[Node] = {p0}
        produced = {ov.id for ov in p0.outvals}
        key = (target.id, sub_ids)
        hit = self._cache.get(key)
        if hit is not None:
            imp, srcs_t, flops = hit
            srcs = {v.id: v for v in srcs_t}
        else:
            srcs = {}
            imp = target.nbytes_expr
            for iv in p0.invals:
                if iv.id in produced or iv.id in srcs:
                    continue
                srcs[iv.id] = iv
                if not iv.is_materialized_input():
                    imp = imp - iv.nbytes_expr
            flops = self._node_flops(p0)
            self._cache[key] = (imp, tuple(srcs.values()), flops)
        best = (imp, sub_ids, set(sub_nodes), flops)
        while len(sub_ids) < self.max_subgraph:
            # pick the most expensive non-always-live source to absorb
            cand = [s for s in srcs.values()
                    if not s.is_materialized_input() and s.producer is not None
                    and not is_loop_node(s.producer)]    # loops don't absorb
            if not cand:
                break
            sizes = tuple(s.nbytes_expr.uid for s in cand)
            hit = self._pick_memo.get(sizes)
            if hit is not None:
                idx, pick_keys = hit
                self.sg.note_cmp_keys(pick_keys)
            else:
                with self.sg.record_cmp_keys() as pick_keys:
                    idx = 0
                    for j in range(1, len(cand)):
                        if self.sg.compare(cand[j].nbytes_expr,
                                           cand[idx].nbytes_expr) is Cmp.GT:
                            idx = j
                self._pick_memo[sizes] = (idx, frozenset(pick_keys))
            pick = cand[idx]
            p = pick.producer
            if p.id in sub_ids:
                break
            sub_ids = sub_ids | {p.id}
            sub_nodes.add(p)
            key = (target.id, sub_ids)
            hit = self._cache.get(key)
            if hit is not None:
                imp, srcs_t, flops = hit
                srcs = {v.id: v for v in srcs_t}
                for ov in p.outvals:
                    produced.add(ov.id)
            else:
                for ov in p.outvals:
                    produced.add(ov.id)
                    s = srcs.pop(ov.id, None)
                    if s is not None and not s.is_materialized_input():
                        imp = imp + s.nbytes_expr   # no longer a source
                for iv in p.invals:
                    if iv.id in produced or iv.id in srcs:
                        continue
                    srcs[iv.id] = iv
                    if not iv.is_materialized_input():
                        imp = imp - iv.nbytes_expr
                flops = flops + self._node_flops(p)
                self._cache[key] = (imp, tuple(srcs.values()), flops)
            if self.sg.compare(imp, best[0]) is Cmp.GT:
                best = (imp, sub_ids, set(sub_nodes), flops)
            # early exit: impact can't improve once all sources are always-live
        best_imp, best_ids, best_nodes, best_flops = best
        # beneficial iff impact definitely > 0
        if self.sg.compare(best_imp, ZERO) is not Cmp.GT:
            return None
        order = [n for n in self.g.nodes if n in best_nodes]  # topo by construction
        node_ids = tuple(n.id for n in order)
        sources = tuple(s.id for s in self._cache[(target.id, best_ids)][1])
        return RecomputePlan(target, node_ids, sources,
                             best_imp, best_flops,
                             impact_interval=self.sg.interval_of(best_imp),
                             flops_interval=self.sg.interval_of(best_flops))

    # -- full exploration (paper: "explores all rematerialization candidates") --
    def explore(self, order: Sequence[Node], *,
                cand_keys_out: Optional[Dict[int, frozenset]] = None,
                parent_sg: Optional[ShapeGraph] = None,
                parent_cands: Optional[Dict[int, CandidateInfo]] = None,
                parent_cand_keys: Optional[Dict[int, frozenset]] = None,
                ) -> Dict[int, CandidateInfo]:
        """Search regeneration plans for every remat candidate.

        Candidates are intermediate values with at least one consumer that is
        not their producer's immediate successor (i.e. they stay live across
        other ops) and that are not graph outputs.

        ``cand_keys_out`` (a dict to fill) records, per candidate, the
        compare keys its search consulted.  With ``parent_*`` set, the
        exploration is **incremental**: a candidate whose parent search
        consulted only verdicts that are unchanged under this (narrowed)
        graph would retrace the identical growth path, so its parent result
        is reused with intervals refreshed and the bounds prunes re-applied
        (:func:`respecialize_candidates` logic) — only candidates an
        actually-flipped verdict touches are re-searched.
        """
        pos = {n.id: i for i, n in enumerate(order)}
        out: Dict[int, CandidateInfo] = {}
        for v in self.g.values:
            if v.kind != "intermediate" or v.id in self._output_ids:
                continue
            if v.producer is None or not v.consumers:
                continue
            if is_loop_node(v.producer):
                continue  # loop outputs are remat barriers
            if self.g.bound_dims and \
                    v.nbytes_expr.free_vars() & set(self.g.bound_dims):
                # bound-dependent values are remat barriers too: their
                # tight size exists only in the live call env, and
                # re-running the introducing op re-measures — the planner
                # cannot price or replay that statically
                continue
            p = pos.get(v.producer.id)
            if p is None:
                continue
            last_use = max(pos[c.id] for c in v.consumers if c.id in pos)
            if last_use <= p + 1:
                continue  # never idle: evicting it can't help
            bytes_iv = self.sg.interval_of(v.nbytes_expr)
            if bytes_iv.hi == 0:
                continue  # provably empty for every env: never profitable
            if parent_cands is not None and v.id in parent_cands:
                pk = (parent_cand_keys or {}).get(v.id)
                if pk is not None and self.sg.verdicts_match(parent_sg, pk):
                    out[v.id] = _respecialize_one(parent_cands[v.id],
                                                  self.sg, bytes_iv)
                    if cand_keys_out is not None:
                        cand_keys_out[v.id] = pk
                    continue
            if cand_keys_out is not None:
                with self.sg.record_cmp_keys() as keys:
                    rp = self.search(v, bytes_iv)
                cand_keys_out[v.id] = frozenset(keys)
            else:
                rp = self.search(v, bytes_iv)
            info = CandidateInfo(value=v, recompute=rp,
                                 bytes_interval=bytes_iv)
            if info.recompute is not None and \
                    static_regen_method(info) == "offload":
                # bounds prove reload is cheaper for every env in range:
                # drop the recompute plan at compile time so the runtime
                # never scores it
                info = CandidateInfo(value=v, recompute=None,
                                     bytes_interval=bytes_iv,
                                     recompute_pruned_by_bounds=True)
            out[v.id] = info
        return out


def _respecialize_one(info: CandidateInfo, sg: ShapeGraph,
                      bytes_iv: Interval) -> CandidateInfo:
    """One candidate's intervals refreshed + bounds prunes re-applied under
    a narrowed graph (see :func:`respecialize_candidates`)."""
    from dataclasses import replace

    rp = info.recompute
    if rp is not None:
        rp = replace(rp,
                     impact_interval=sg.interval_of(rp.impact),
                     flops_interval=sg.interval_of(rp.flops))
    new = CandidateInfo(value=info.value, recompute=rp,
                        bytes_interval=bytes_iv,
                        recompute_pruned_by_bounds=
                        info.recompute_pruned_by_bounds)
    if new.recompute is not None and static_regen_method(new) == "offload":
        new = CandidateInfo(value=info.value, recompute=None,
                            bytes_interval=bytes_iv,
                            recompute_pruned_by_bounds=True)
    return new


def respecialize_candidates(candidates: Dict[int, CandidateInfo],
                            sg: ShapeGraph) -> Dict[int, CandidateInfo]:
    """Re-derive a candidate set's interval data under a narrowed graph.

    The *structure* of the search result — which subgraph regenerates each
    candidate — depends only on ``ShapeGraph.compare`` verdicts; when the
    incremental compile path has proven those unchanged under a bucket's
    narrowed ranges, re-running :meth:`RecomputeSearcher.explore` would
    reproduce the same subgraphs.  This reproduces its *output* instead:
    refresh every stored interval under the narrowed bounds (tighter
    buckets pin more regen decisions statically) and re-apply the two
    bounds-based prunes, both of which are monotone under narrowing —
    ``bytes_interval.hi == 0`` only becomes true as ranges shrink, and a
    parent-pruned recompute plan (reload provably cheaper everywhere) stays
    pruned on every sub-range.
    """
    out: Dict[int, CandidateInfo] = {}
    for vid, info in candidates.items():
        bytes_iv = sg.interval_of(info.value.nbytes_expr)
        if bytes_iv.hi == 0:
            continue          # explore() would have skipped it outright
        out[vid] = _respecialize_one(info, sg, bytes_iv)
    return out
