"""Compile-time recomputation-subgraph search (paper §2.3).

For each rematerialization candidate tensor, grow a recompute subgraph
backwards from its producer, evaluating the *symbolic* memory impact of each
candidate subgraph:

    impact(S) = bytes(target) − Σ bytes(sources of S that are not always-live)

Graph inputs and constants are always live, so they contribute no cost
(this reproduces the paper's Listing-1 walkthrough: {Reduce} → −11007·S1,
{Reduce,Dot} → −11·S1, {Reduce,Dot,Reshape} → +1·S1).  The best subgraph
seen is kept; a candidate is *recomputable* iff its best impact is
definitely positive under the shape graph.  Reload (offload) plans are
always available and memory-neutral.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..ir.graph import Graph, Node, Value
from ..symbolic import Cmp, Interval, ShapeGraph, SymbolicExpr, ZERO

# Relative cost model shared by compile-time pruning (here) and runtime victim
# scoring (remat/runtime.py): recompute cost ~ flops * RECOMPUTE_COST_PER_FLOP,
# offload+reload cost ~ bytes * (D2H + H2D).  Only the ratios matter.
RECOMPUTE_COST_PER_FLOP = 1.0 / 50.0   # flops are cheap relative to transfers
RELOAD_COST_PER_BYTE = 1.0             # H2D per byte
OFFLOAD_COST_PER_BYTE = 1.0            # D2H per byte (paid at eviction)

# rough per-primitive cost model (symbolic FLOPs) -----------------------------


def node_flops(n: Node) -> SymbolicExpr:
    if n.prim_name == "dot_general":
        dnums = n.params.get("dimension_numbers")
        lhs, rhs, out = n.invals[0], n.invals[1], n.outvals[0]
        # flops = 2 * prod(out dims) * prod(contracting dims of lhs)
        (lc, _rc), _ = dnums
        k = ZERO + 1
        for d in lc:
            k = k * lhs.dims[d]
        return 2 * out.size_expr * k
    if n.prim_name in ("conv_general_dilated",):
        return 2 * n.outvals[0].size_expr  # lower bound; convs unused in LMs here
    # elementwise / data movement: one flop per output element
    total = ZERO
    for ov in n.outvals:
        total = total + ov.size_expr
    return total


@dataclass
class RecomputePlan:
    target: Value
    node_ids: Tuple[int, ...]            # topo-ordered subgraph (graph node ids)
    source_ids: Tuple[int, ...]          # value ids that must be materialized
    impact: SymbolicExpr                 # symbolic memory benefit of evicting
    flops: SymbolicExpr                  # symbolic recompute cost
    # guaranteed ranges over the shape graph's declared dim bounds, computed
    # once at search time so the runtime policy never re-derives them
    impact_interval: Interval = Interval()
    flops_interval: Interval = Interval()


@dataclass
class CandidateInfo:
    value: Value
    recompute: Optional[RecomputePlan]   # None if no beneficial subgraph found
    offloadable: bool = True             # reload is always available
    bytes_interval: Interval = Interval()  # guaranteed range of device bytes
    # True when a beneficial recompute plan existed but interval bounds
    # proved reload always cheaper, so it was dropped at compile time
    recompute_pruned_by_bounds: bool = False


def static_regen_method(cand: CandidateInfo) -> Optional[str]:
    """Decide recompute-vs-offload at compile time when bounds prove it.

    Returns ``'recompute'`` / ``'offload'`` when one regeneration method is
    cheaper for *every* env within the declared dim ranges, else ``None``
    (the runtime policy evaluates concretely).  Candidates without a
    recompute plan are always ``'offload'``.
    """
    if cand.recompute is None:
        return "offload"
    flops = cand.recompute.flops_interval
    nbytes = cand.bytes_interval
    per_byte = RELOAD_COST_PER_BYTE + OFFLOAD_COST_PER_BYTE
    if flops.hi is not None and nbytes.lo is not None and \
            flops.hi * RECOMPUTE_COST_PER_FLOP <= nbytes.lo * per_byte:
        return "recompute"
    if nbytes.hi is not None and flops.lo is not None and \
            flops.lo * RECOMPUTE_COST_PER_FLOP >= nbytes.hi * per_byte:
        return "offload"
    return None


class RecomputeSearcher:
    def __init__(self, graph: Graph, shape_graph: Optional[ShapeGraph] = None,
                 *, max_subgraph: int = 24):
        self.g = graph
        self.sg = shape_graph if shape_graph is not None else ShapeGraph()
        self.max_subgraph = max_subgraph
        self._output_ids = {v.id for v in graph.outputs}

    def _sources(self, nodes: Set[Node]) -> List[Value]:
        node_ids = {n.id for n in nodes}
        produced = {ov.id for n in nodes for ov in n.outvals}
        srcs: Dict[int, Value] = {}
        for n in nodes:
            for iv in n.invals:
                if iv.id not in produced:
                    srcs[iv.id] = iv
        return list(srcs.values())

    def _impact(self, target: Value, nodes: Set[Node]) -> SymbolicExpr:
        imp = target.nbytes_expr
        for src in self._sources(nodes):
            if src.is_materialized_input():
                continue  # always live, no extra retention cost
            imp = imp - src.nbytes_expr
        return imp

    def search(self, target: Value,
               bytes_interval: Optional[Interval] = None) -> Optional[RecomputePlan]:
        """Greedy backward growth, keeping the best symbolic impact seen."""
        if target.producer is None:
            return None
        # bounds-based compile-time prune: a target whose worst-case byte
        # count is zero can never free memory, skip the subgraph search
        if bytes_interval is None:
            bytes_interval = self.sg.interval_of(target.nbytes_expr)
        if bytes_interval.hi == 0:
            return None
        sub: Set[Node] = {target.producer}
        best_nodes = set(sub)
        best_imp = self._impact(target, sub)
        while len(sub) < self.max_subgraph:
            # pick the most expensive non-always-live source to absorb
            srcs = [s for s in self._sources(sub)
                    if not s.is_materialized_input() and s.producer is not None]
            if not srcs:
                break
            pick = srcs[0]
            for s in srcs[1:]:
                if self.sg.compare(s.nbytes_expr, pick.nbytes_expr) is Cmp.GT:
                    pick = s
            if pick.producer in sub:
                break
            sub.add(pick.producer)
            imp = self._impact(target, sub)
            if self.sg.compare(imp, best_imp) is Cmp.GT:
                best_imp, best_nodes = imp, set(sub)
            # early exit: impact can't improve once all sources are always-live
        # beneficial iff impact definitely > 0
        if self.sg.compare(best_imp, ZERO) is not Cmp.GT:
            return None
        order = [n for n in self.g.nodes if n in best_nodes]  # topo by construction
        flops = ZERO
        for n in order:
            flops = flops + node_flops(n)
        sources = tuple(s.id for s in self._sources(best_nodes))
        return RecomputePlan(target, tuple(n.id for n in order), sources,
                             best_imp, flops,
                             impact_interval=self.sg.interval_of(best_imp),
                             flops_interval=self.sg.interval_of(flops))

    # -- full exploration (paper: "explores all rematerialization candidates") --
    def explore(self, order: Sequence[Node]) -> Dict[int, CandidateInfo]:
        """Search regeneration plans for every remat candidate.

        Candidates are intermediate values with at least one consumer that is
        not their producer's immediate successor (i.e. they stay live across
        other ops) and that are not graph outputs.
        """
        pos = {n.id: i for i, n in enumerate(order)}
        out: Dict[int, CandidateInfo] = {}
        for v in self.g.values:
            if v.kind != "intermediate" or v.id in self._output_ids:
                continue
            if v.producer is None or not v.consumers:
                continue
            p = pos.get(v.producer.id)
            if p is None:
                continue
            last_use = max(pos[c.id] for c in v.consumers if c.id in pos)
            if last_use <= p + 1:
                continue  # never idle: evicting it can't help
            bytes_iv = self.sg.interval_of(v.nbytes_expr)
            if bytes_iv.hi == 0:
                continue  # provably empty for every env: never profitable
            info = CandidateInfo(value=v,
                                 recompute=self.search(v, bytes_iv),
                                 bytes_interval=bytes_iv)
            if info.recompute is not None and \
                    static_regen_method(info) == "offload":
                # bounds prove reload is cheaper for every env in range:
                # drop the recompute plan at compile time so the runtime
                # never scores it
                info = CandidateInfo(value=v, recompute=None,
                                     bytes_interval=bytes_iv,
                                     recompute_pruned_by_bounds=True)
            out[v.id] = info
        return out
