from .planner import ExecutionPlan, build_plan
from .runtime import EvictionDecision, RuntimeRematPolicy
from .search import (CandidateInfo, RecomputePlan, RecomputeSearcher,
                     node_flops, respecialize_candidates)

__all__ = [
    "ExecutionPlan", "build_plan",
    "EvictionDecision", "RuntimeRematPolicy",
    "CandidateInfo", "RecomputePlan", "RecomputeSearcher", "node_flops",
    "respecialize_candidates",
]
