"""Export the remat analysis to the executable paths.

Two consumers:

* the lowered runtime — :func:`export_regen_programs` turns each
  candidate's recompute subgraph into a register-addressed
  ``RegenProgram`` the ``ProgramVM`` runs inline (the paper's
  ``Remat::RegenerateOp``, compiled instead of interpreted);
* the compiled (XLA) path — :func:`recommend_policy` derives which
  jax.checkpoint policy a scanned-layer stack should use from the §2.3
  search results (how much of the block is cheap to recompute).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, Dict, Optional

import jax

from ..ir.graph import Graph
from ..remat.planner import ExecutionPlan
from ..remat.search import node_flops
from ..symbolic import ShapeGraph

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..lowering.program import RegenProgram


def export_regen_programs(plan: ExecutionPlan, reg_of: Dict[int, int],
                          params_cidx_of: Dict[int, int],
                          ) -> Dict[int, "RegenProgram"]:
    """Lower every candidate's recompute subgraph over VM registers.

    ``reg_of`` maps value ids to the main program's dense registers,
    ``params_cidx_of`` maps node ids to their Compute params entry (the
    sub-program reuses the main program's per-env refined params — no
    second refinement pass).  Returns ``{target register: RegenProgram}``
    with sub-program-local temps for values produced inside the subgraph
    and main registers (materialized recursively at runtime) for the
    subgraph's sources.
    """
    from ..lowering.program import RegenProgram, RegenStep

    out: Dict[int, "RegenProgram"] = {}
    for vid, cand in plan.candidates.items():
        rp = cand.recompute
        if rp is None:
            continue
        temp_of: Dict[int, int] = {}
        steps = []
        for nid in rp.node_ids:
            node = plan.node_by_id[nid]
            in_refs = []
            for iv in node.invals:
                t = temp_of.get(iv.id)
                in_refs.append((True, t) if t is not None
                               else (False, reg_of[iv.id]))
            writes = []
            for oi, ov in enumerate(node.outvals):
                ti = temp_of.setdefault(ov.id, len(temp_of))
                writes.append((oi, ti))
            steps.append(RegenStep(
                node=node, prim=node.prim,
                multi=bool(node.prim is not None
                           and node.prim.multiple_results),
                dim_as_value=node.prim_name == "dim_as_value",
                params_cidx=params_cidx_of[nid],
                in_refs=tuple(in_refs), writes=tuple(writes)))
        out[reg_of[vid]] = RegenProgram(
            target_reg=reg_of[vid], target_vid=vid,
            source_regs=tuple(reg_of[s] for s in rp.source_ids),
            n_temps=len(temp_of), steps=tuple(steps),
            target_temp=temp_of[vid], flops_expr=rp.flops)
    return out


@dataclass
class RematRecommendation:
    policy_name: str              # 'block' | 'dots_saveable' | 'none'
    policy: Optional[Callable]    # jax.checkpoint policy (None = save all)
    recompute_flop_fraction: float
    recomputable_byte_fraction: float
    rationale: str


def recommend_policy(plan: ExecutionPlan, env: Dict[str, int],
                     *, memory_headroom: float = 0.25) -> RematRecommendation:
    """Pick a scan-body checkpoint policy from the §2.3 search results.

    Heuristic (validated in the §Perf log): if most candidate bytes are
    cheaply recomputable (elementwise-dominated regeneration subgraphs),
    full block remat is nearly free — use 'block'.  If regeneration cost
    concentrates in matmuls, saving dot outputs trades memory for ~7% FLOPs
    — use 'dots_saveable' only when there is HBM headroom to spend.
    """
    g: Graph = plan.graph
    total_flops = sum(node_flops(n).evaluate(env) for n in g.nodes) or 1
    recomp_flops = 0
    recomp_bytes = 0
    total_bytes = 0
    for cand in plan.candidates.values():
        b = cand.value.nbytes_expr.evaluate(env)
        total_bytes += b
        if cand.recompute is not None:
            recomp_bytes += b
            recomp_flops += cand.recompute.flops.evaluate(env)
    flop_frac = recomp_flops / total_flops
    byte_frac = recomp_bytes / max(total_bytes, 1)

    if byte_frac >= 0.5 and flop_frac <= 0.35:
        return RematRecommendation(
            "block", None, flop_frac, byte_frac,
            f"{byte_frac:.0%} of candidate bytes regenerate for "
            f"{flop_frac:.0%} of step FLOPs: full block remat is cheap")
    if memory_headroom >= 0.3:
        return RematRecommendation(
            "dots_saveable",
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            flop_frac, byte_frac,
            "regeneration is matmul-heavy and HBM headroom exists: save "
            "dot outputs, recompute the elementwise chains")
    return RematRecommendation(
        "block", None, flop_frac, byte_frac,
        "matmul-heavy regeneration but no HBM headroom: block remat")
