"""Export the remat analysis to the compiled (XLA) path.

The interpreter is the paper-faithful runtime; at 1000-node scale the
train step runs under jit.  This module carries the §2.3 analysis across:
from the symbolic recompute-subgraph search over a *single block's* graph,
derive which jax.checkpoint policy the scanned-layer stack should use —
i.e. how much of the block is cheap to recompute (elementwise chains)
versus worth saving (matmul outputs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax

from ..ir.graph import Graph
from ..remat.planner import ExecutionPlan
from ..remat.search import node_flops
from ..symbolic import ShapeGraph


@dataclass
class RematRecommendation:
    policy_name: str              # 'block' | 'dots_saveable' | 'none'
    policy: Optional[Callable]    # jax.checkpoint policy (None = save all)
    recompute_flop_fraction: float
    recomputable_byte_fraction: float
    rationale: str


def recommend_policy(plan: ExecutionPlan, env: Dict[str, int],
                     *, memory_headroom: float = 0.25) -> RematRecommendation:
    """Pick a scan-body checkpoint policy from the §2.3 search results.

    Heuristic (validated in the §Perf log): if most candidate bytes are
    cheaply recomputable (elementwise-dominated regeneration subgraphs),
    full block remat is nearly free — use 'block'.  If regeneration cost
    concentrates in matmuls, saving dot outputs trades memory for ~7% FLOPs
    — use 'dots_saveable' only when there is HBM headroom to spend.
    """
    g: Graph = plan.graph
    total_flops = sum(node_flops(n).evaluate(env) for n in g.nodes) or 1
    recomp_flops = 0
    recomp_bytes = 0
    total_bytes = 0
    for cand in plan.candidates.values():
        b = cand.value.nbytes_expr.evaluate(env)
        total_bytes += b
        if cand.recompute is not None:
            recomp_bytes += b
            recomp_flops += cand.recompute.flops.evaluate(env)
    flop_frac = recomp_flops / total_flops
    byte_frac = recomp_bytes / max(total_bytes, 1)

    if byte_frac >= 0.5 and flop_frac <= 0.35:
        return RematRecommendation(
            "block", None, flop_frac, byte_frac,
            f"{byte_frac:.0%} of candidate bytes regenerate for "
            f"{flop_frac:.0%} of step FLOPs: full block remat is cheap")
    if memory_headroom >= 0.3:
        return RematRecommendation(
            "dots_saveable",
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            flop_frac, byte_frac,
            "regeneration is matmul-heavy and HBM headroom exists: save "
            "dot outputs, recompute the elementwise chains")
    return RematRecommendation(
        "block", None, flop_frac, byte_frac,
        "matmul-heavy regeneration but no HBM headroom: block remat")
