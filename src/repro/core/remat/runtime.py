"""Runtime rematerialization decisions (paper §2.3 runtime half).

When the memory limit is about to be surpassed, choose which live candidate
tensors to evict and how to regenerate each (reload vs recompute), weighing
memory savings against end-to-end performance impact — the scoring follows
the DELTA[10]-style heuristic the paper cites: prefer victims with large
bytes, cheap regeneration, and distant next use.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .planner import ExecutionPlan
from .search import (OFFLOAD_COST_PER_BYTE, RECOMPUTE_COST_PER_FLOP,
                     RELOAD_COST_PER_BYTE)

_RECOMPUTE_COST_PER_FLOP = RECOMPUTE_COST_PER_FLOP
_RELOAD_COST_PER_BYTE = RELOAD_COST_PER_BYTE
_OFFLOAD_COST_PER_BYTE = OFFLOAD_COST_PER_BYTE


@dataclass
class EvictionDecision:
    vid: int
    method: str           # 'recompute' | 'offload'
    bytes_freed: int
    est_cost: float


class RuntimeRematPolicy:
    """Chooses victims among live candidates at an evict point."""

    def __init__(self, plan: ExecutionPlan, env: Dict[str, int]):
        self.plan = plan
        self.env = env
        self._flops_cache: Dict[int, int] = {}

    def _next_use_distance(self, vid: int, step: int) -> int:
        uses = self.plan.use_positions.get(vid, [])
        for u in uses:
            if u >= step:
                return u - step + 1
        return len(self.plan.order) - step + 1  # only needed for outputs/never

    def _regen_cost(self, vid: int, nbytes: int) -> Tuple[str, float]:
        cand = self.plan.candidates.get(vid)
        per_byte = _RELOAD_COST_PER_BYTE + _OFFLOAD_COST_PER_BYTE
        if cand is None or cand.recompute is None:
            return "offload", nbytes * per_byte
        # interval bounds may have fixed the method at compile time — skip
        # the symbolic flops evaluation entirely for statically-offload
        # candidates and keep only the (cached) cost lookup for recompute
        static = self.plan.static_methods.get(vid)
        if static == "offload":
            return "offload", nbytes * per_byte
        flops = self._flops_cache.get(vid)
        if flops is None:
            flops = max(1, cand.recompute.flops.evaluate(self.env))
            self._flops_cache[vid] = flops
        rc = flops * _RECOMPUTE_COST_PER_FLOP
        if static == "recompute":
            return "recompute", rc
        ol = nbytes * per_byte
        return ("recompute", rc) if rc <= ol else ("offload", ol)

    def choose_victims(
        self,
        need_bytes: int,
        live_candidates: Dict[int, int],   # vid -> device bytes
        pinned: frozenset,                 # vids that must stay (current op)
        step: int,
    ) -> List[EvictionDecision]:
        scored: List[Tuple[float, EvictionDecision]] = []
        for vid, nbytes in live_candidates.items():
            if vid in pinned or nbytes <= 0:
                continue
            if vid not in self.plan.candidates:
                continue
            method, cost = self._regen_cost(vid, nbytes)
            dist = self._next_use_distance(vid, step)
            # DELTA-like: benefit-per-cost, discounted for imminent reuse
            score = (nbytes * dist) / (cost + 1.0)
            scored.append((score, EvictionDecision(vid, method, nbytes, cost)))
        scored.sort(key=lambda t: -t[0])
        out: List[EvictionDecision] = []
        freed = 0
        for _score, dec in scored:
            if freed >= need_bytes:
                break
            out.append(dec)
            freed += dec.bytes_freed
        return out
