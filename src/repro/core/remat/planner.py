"""Execution-plan assembly (paper §2.3 compile-time half).

Combines the scheduled order with the regeneration-plan search results into
an ``ExecutionPlan``: conceptually the original graph with a
``Remat::EvictOp`` after every op (realised as the interpreter's evict check
at op boundaries) and ``Remat::RegenerateOp`` before every consumer of a
candidate tensor (realised as the interpreter's materialize-on-demand).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

from ..ir.graph import Graph, Node
from ..scheduling.scheduler import ScheduleResult
from ..symbolic import ShapeGraph
from .search import CandidateInfo, RecomputeSearcher, static_regen_method

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..memplan.assign import ArenaPlan


@dataclass
class ExecutionPlan:
    graph: Graph
    order: List[Node]
    shape_graph: ShapeGraph
    candidates: Dict[int, CandidateInfo]          # value id -> regen info
    node_by_id: Dict[int, Node] = field(default_factory=dict)
    # positions for next-use estimation at runtime
    pos: Dict[int, int] = field(default_factory=dict)
    # value id -> sorted consumer positions
    use_positions: Dict[int, List[int]] = field(default_factory=dict)
    # value id -> regen method fixed at compile time by interval bounds
    # ('recompute' | 'offload'); absent keys stay env-dependent at runtime
    static_methods: Dict[int, str] = field(default_factory=dict)
    # compile-time buffer-reuse plan (None with memory_plan="none")
    arena_plan: Optional["ArenaPlan"] = None
    # kernel-variant selection (node id -> param overrides / selection
    # record); baked into lowered Compute params, never into the shared
    # ``node.params`` — plans for other buckets see their own choices
    kernel_overrides: Dict[int, Dict[str, object]] = field(default_factory=dict)
    kernel_selections: Dict[int, object] = field(default_factory=dict)

    def __post_init__(self):
        self.node_by_id = {n.id: n for n in self.graph.nodes}
        self.pos = {n.id: i for i, n in enumerate(self.order)}
        for v in self.graph.values:
            self.use_positions[v.id] = sorted(
                self.pos[c.id] for c in v.consumers if c.id in self.pos)
        if not self.static_methods:
            for vid, cand in self.candidates.items():
                if cand.recompute_pruned_by_bounds:
                    # bounds dropped the recompute plan during the search
                    self.static_methods[vid] = "offload"
                elif cand.recompute is not None:
                    m = static_regen_method(cand)
                    if m is not None:
                        self.static_methods[vid] = m
                # recompute=None without the pruned flag means the search
                # simply found no beneficial subgraph — the bounds decided
                # nothing, so it is not a static decision

    @property
    def n_static_regen(self) -> int:
        """Candidates whose regen method the bounds fixed at compile time."""
        return len(self.static_methods)

    @property
    def n_candidates(self) -> int:
        return len(self.candidates)

    @property
    def n_recomputable(self) -> int:
        return sum(1 for c in self.candidates.values() if c.recompute is not None)


def build_plan(graph: Graph, schedule: ScheduleResult,
               shape_graph: Optional[ShapeGraph] = None,
               *, enable_remat: bool = True,
               max_subgraph: int = 24,
               arena_plan: Optional["ArenaPlan"] = None,
               remat_expr_cache: Optional[Dict] = None,
               cand_keys_out: Optional[Dict[int, frozenset]] = None,
               parent_remat: Optional[Tuple] = None) -> ExecutionPlan:
    """``cand_keys_out``/``parent_remat`` thread the incremental-compile
    protocol into the search: the former collects each candidate's compare
    keys, the latter — ``(parent shape graph, parent candidates, parent
    candidate keys)`` — lets :meth:`RecomputeSearcher.explore` reuse every
    parent candidate whose verdicts are unchanged under ``shape_graph``."""
    sg = shape_graph if shape_graph is not None else ShapeGraph()
    candidates: Dict[int, CandidateInfo] = {}
    if enable_remat:
        searcher = RecomputeSearcher(graph, sg, max_subgraph=max_subgraph,
                                     expr_cache=remat_expr_cache)
        p_sg = p_cands = p_keys = None
        if parent_remat is not None:
            p_sg, p_cands, p_keys = parent_remat
        candidates = searcher.explore(schedule.order,
                                      cand_keys_out=cand_keys_out,
                                      parent_sg=p_sg,
                                      parent_cands=p_cands,
                                      parent_cand_keys=p_keys)
    return ExecutionPlan(graph=graph, order=list(schedule.order),
                         shape_graph=sg, candidates=candidates,
                         arena_plan=arena_plan)
