from .expr import Atom, OpAtom, SymbolicExpr, ZERO, ONE, size_of
from .shape_graph import Cmp, ShapeGraph
from .from_jax import dim_to_expr, is_symbolic_dim, refine_dim, shape_to_exprs

__all__ = [
    "Atom", "OpAtom", "SymbolicExpr", "ZERO", "ONE", "size_of",
    "Cmp", "ShapeGraph",
    "dim_to_expr", "is_symbolic_dim", "refine_dim", "shape_to_exprs",
]
