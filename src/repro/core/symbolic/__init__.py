from .expr import Atom, OpAtom, SymbolicExpr, ZERO, ONE, size_of
from .intervals import BoundEnv, Interval, as_interval
from .shape_graph import Cmp, ShapeGraph
from .from_jax import (declare_dim_ranges, dim_to_expr, is_symbolic_dim,
                       parse_range_spec, refine_dim, shape_to_exprs)

__all__ = [
    "Atom", "OpAtom", "SymbolicExpr", "ZERO", "ONE", "size_of",
    "BoundEnv", "Interval", "as_interval",
    "Cmp", "ShapeGraph",
    "declare_dim_ranges", "dim_to_expr", "is_symbolic_dim",
    "parse_range_spec", "refine_dim", "shape_to_exprs",
]
