"""SymbolicExpr: the algebraic representation of symbolic shape dimensions.

This is the paper's ``SymbolicExpr`` (§2.1): a canonical multivariate
polynomial over *atoms*.  An atom is either a plain symbolic dimension
(``@S0`` in the paper, a free variable such as a batch or sequence length)
or an *opaque* compound (floordiv / mod / max / min over sub-expressions)
which participates in the polynomial as an indivisible variable but can
still be evaluated numerically and bounded.

Representation: ``terms`` maps a *monomial* — a sorted tuple of
``(atom, exponent)`` pairs — to an integer coefficient.  The empty monomial
is the constant term.  This canonical form makes equality, addition and
multiplication exact, which is what the paper's comparisons build on.

Expressions are **hash-consed**: construction interns the canonical term
tuple in a weak table, so structurally-equal expressions are (almost
always) the *same* object, equality fast-paths on identity, the
structural hash is computed once, and every expression carries a stable
``uid`` that compile-path memo tables (``ShapeGraph``) key on.  The
common arithmetic cases — adding 0, multiplying by a constant, folding
constants — skip the general polynomial merge entirely.
"""
from __future__ import annotations

import itertools
import weakref
from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Iterable, Mapping, Optional, Tuple, Union

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .intervals import BoundEnv, Interval

# declared ranges accepted by SymbolicExpr.interval / .bounds
BoundsLike = Union[None, "BoundEnv", Mapping[str, object]]

# ---------------------------------------------------------------------------
# Atoms
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Atom:
    """A symbolic dimension variable (paper's ``SymbolicDim``)."""

    name: str

    def evaluate(self, env: Mapping[str, int]) -> int:
        try:
            return int(env[self.name])
        except KeyError:
            raise KeyError(f"unbound symbolic dim {self.name!r}") from None

    def free_vars(self) -> frozenset:
        return frozenset({self.name})

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return self.name


@dataclass(frozen=True)
class OpAtom:
    """An opaque compound atom: floordiv/mod/max/min over SymbolicExprs.

    These arise from shape arithmetic that is not polynomial.  They are
    treated as indivisible variables by the polynomial algebra, remain
    evaluable, and expose conservative bounds.
    """

    op: str  # 'floordiv' | 'mod' | 'max' | 'min'
    operands: Tuple["SymbolicExpr", ...]

    def evaluate(self, env: Mapping[str, int]) -> int:
        vals = [x.evaluate(env) for x in self.operands]
        if self.op == "floordiv":
            return vals[0] // vals[1]
        if self.op == "mod":
            return vals[0] % vals[1]
        if self.op == "max":
            return max(vals)
        if self.op == "min":
            return min(vals)
        raise ValueError(f"unknown op atom {self.op}")

    def free_vars(self) -> frozenset:
        out: frozenset = frozenset()
        for x in self.operands:
            out |= x.free_vars()
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{self.op}({', '.join(map(repr, self.operands))})"


AtomT = Union[Atom, OpAtom]
Monomial = Tuple[Tuple[AtomT, int], ...]  # sorted by atom repr
_EMPTY: Monomial = ()


def _mono_key(item: Tuple[AtomT, int]) -> str:
    return repr(item[0])


def _mono_mul(a: Monomial, b: Monomial) -> Monomial:
    powers: Dict[AtomT, int] = {}
    for atom, exp in itertools.chain(a, b):
        powers[atom] = powers.get(atom, 0) + exp
    items = [(atom, exp) for atom, exp in powers.items() if exp != 0]
    items.sort(key=_mono_key)
    return tuple(items)


# ---------------------------------------------------------------------------
# SymbolicExpr
# ---------------------------------------------------------------------------


class SymbolicExpr:
    """Canonical integer polynomial over atoms.  Immutable, hash-consed."""

    __slots__ = ("terms", "_hash", "uid", "_atoms", "__weakref__")

    # canonical terms tuple -> the one live instance carrying it.  Weak so
    # transient compile-time expressions do not accumulate forever; memo
    # tables that key on ``uid`` hold strong refs to what they cache.
    _intern: "weakref.WeakValueDictionary" = weakref.WeakValueDictionary()
    _uid_counter = itertools.count(1)
    # small-constant cache (strong refs: these recur constantly)
    _const_cache: Dict[int, "SymbolicExpr"] = {}

    def __new__(cls, terms: Mapping[Monomial, int]):
        clean = {m: c for m, c in terms.items() if c != 0}
        # sort monomials by (atom repr, exponent) pairs: exponents must
        # participate or `s` and `s^2` tie and the term order (hence the
        # canonical form) would depend on insertion order
        key = tuple(sorted(
            clean.items(),
            key=lambda kv: tuple((repr(a), e) for a, e in kv[0])))
        self = cls._intern.get(key)
        if self is not None:
            return self
        self = object.__new__(cls)
        self.terms = key
        self._hash = hash(key)
        self.uid = next(cls._uid_counter)
        self._atoms = None
        cls._intern[key] = self
        return self

    # -- constructors -------------------------------------------------------
    @staticmethod
    def constant(c: int) -> "SymbolicExpr":
        c = int(c)
        e = SymbolicExpr._const_cache.get(c)
        if e is None:
            e = SymbolicExpr({_EMPTY: c})
            if -4096 <= c <= 4096 or len(SymbolicExpr._const_cache) < 65536:
                SymbolicExpr._const_cache[c] = e
        return e

    @staticmethod
    def var(name: str) -> "SymbolicExpr":
        return SymbolicExpr({((Atom(name), 1),): 1})

    @staticmethod
    def from_atom(atom: AtomT) -> "SymbolicExpr":
        return SymbolicExpr({((atom, 1),): 1})

    @staticmethod
    def wrap(x: "ExprLike") -> "SymbolicExpr":
        if isinstance(x, SymbolicExpr):
            return x
        if isinstance(x, (int,)):
            return SymbolicExpr.constant(x)
        raise TypeError(f"cannot wrap {type(x)} as SymbolicExpr")

    # -- inspection ----------------------------------------------------------
    def as_dict(self) -> Dict[Monomial, int]:
        return dict(self.terms)

    def is_constant(self) -> bool:
        return all(m == _EMPTY for m, _ in self.terms)

    def constant_value(self) -> Optional[int]:
        if not self.terms:
            return 0
        if self.is_constant():
            return self.terms[0][1]
        return None

    def free_vars(self) -> frozenset:
        return frozenset(a.name for a in self.atom_closure()
                         if isinstance(a, Atom))

    def atoms(self) -> frozenset:
        out = set()
        for mono, _ in self.terms:
            for atom, _exp in mono:
                out.add(atom)
        return frozenset(out)

    def atom_closure(self) -> frozenset:
        """All atoms appearing at any depth (OpAtom operands included).

        Cached on the interned instance — this is the substitution fast
        path's disjointness test and the memo tables' dependency set.
        """
        if self._atoms is None:
            out = set()
            for mono, _ in self.terms:
                for atom, _exp in mono:
                    out.add(atom)
                    if isinstance(atom, OpAtom):
                        for op in atom.operands:
                            out |= op.atom_closure()
            self._atoms = frozenset(out)
        return self._atoms

    # -- algebra -------------------------------------------------------------
    def __add__(self, other: "ExprLike") -> "SymbolicExpr":
        if isinstance(other, int):
            if other == 0:
                return self
            other = SymbolicExpr.constant(other)
        elif not isinstance(other, SymbolicExpr):
            other = SymbolicExpr.wrap(other)
        if not other.terms:
            return self
        if not self.terms:
            return other
        acc = dict(self.terms)
        for m, c in other.terms:
            acc[m] = acc.get(m, 0) + c
        return SymbolicExpr(acc)

    __radd__ = __add__

    def __neg__(self) -> "SymbolicExpr":
        return SymbolicExpr({m: -c for m, c in self.terms})

    def __sub__(self, other: "ExprLike") -> "SymbolicExpr":
        return self + (-SymbolicExpr.wrap(other))

    def __rsub__(self, other: "ExprLike") -> "SymbolicExpr":
        return SymbolicExpr.wrap(other) + (-self)

    def __mul__(self, other: "ExprLike") -> "SymbolicExpr":
        if isinstance(other, int):
            if other == 1:
                return self
            if other == 0:
                return ZERO
            return SymbolicExpr({m: c * other for m, c in self.terms})
        other = SymbolicExpr.wrap(other)
        # constant × polynomial: scale coefficients, skip the double loop
        oc = other.constant_value()
        if oc is not None:
            return self * oc
        sc = self.constant_value()
        if sc is not None:
            return other * sc
        acc: Dict[Monomial, int] = {}
        for m1, c1 in self.terms:
            for m2, c2 in other.terms:
                m = _mono_mul(m1, m2)
                acc[m] = acc.get(m, 0) + c1 * c2
        return SymbolicExpr(acc)

    __rmul__ = __mul__

    def floordiv(self, other: "ExprLike") -> "SymbolicExpr":
        other = SymbolicExpr.wrap(other)
        oc = other.constant_value()
        if oc is not None and oc != 0:
            # exact division of every coefficient -> stay polynomial
            if all(c % oc == 0 for _, c in self.terms):
                return SymbolicExpr({m: c // oc for m, c in self.terms})
        sc = self.constant_value()
        if sc is not None and oc is not None and oc != 0:
            return SymbolicExpr.constant(sc // oc)
        return SymbolicExpr.from_atom(OpAtom("floordiv", (self, other)))

    def mod(self, other: "ExprLike") -> "SymbolicExpr":
        other = SymbolicExpr.wrap(other)
        sc, oc = self.constant_value(), other.constant_value()
        if sc is not None and oc is not None and oc != 0:
            return SymbolicExpr.constant(sc % oc)
        if oc is not None and oc != 0 and all(c % oc == 0 for _, c in self.terms):
            return SymbolicExpr.constant(0)
        return SymbolicExpr.from_atom(OpAtom("mod", (self, other)))

    @staticmethod
    def max_of(a: "ExprLike", b: "ExprLike") -> "SymbolicExpr":
        a, b = SymbolicExpr.wrap(a), SymbolicExpr.wrap(b)
        if a == b:
            return a
        ca, cb = a.constant_value(), b.constant_value()
        if ca is not None and cb is not None:
            return SymbolicExpr.constant(max(ca, cb))
        return SymbolicExpr.from_atom(OpAtom("max", (a, b)))

    @staticmethod
    def min_of(a: "ExprLike", b: "ExprLike") -> "SymbolicExpr":
        a, b = SymbolicExpr.wrap(a), SymbolicExpr.wrap(b)
        if a == b:
            return a
        ca, cb = a.constant_value(), b.constant_value()
        if ca is not None and cb is not None:
            return SymbolicExpr.constant(min(ca, cb))
        return SymbolicExpr.from_atom(OpAtom("min", (a, b)))

    # -- evaluation / substitution -------------------------------------------
    def evaluate(self, env: Mapping[str, int]) -> int:
        total = 0
        for mono, coeff in self.terms:
            v = coeff
            for atom, exp in mono:
                v *= atom.evaluate(env) ** exp
            total += v
        return total

    def substitute(self, mapping: Mapping[AtomT, "SymbolicExpr"]) -> "SymbolicExpr":
        """Replace atoms by expressions (used by the shape graph's rewriting)."""
        # fast path: nothing to replace anywhere in this expression
        if not mapping or self.atom_closure().isdisjoint(mapping):
            return self
        out = SymbolicExpr.constant(0)
        for mono, coeff in self.terms:
            term = SymbolicExpr.constant(coeff)
            for atom, exp in mono:
                rep = mapping.get(atom)
                if rep is None:
                    # rebuild OpAtoms whose operands may contain replaced atoms
                    if isinstance(atom, OpAtom):
                        new_ops = tuple(o.substitute(mapping) for o in atom.operands)
                        if new_ops != atom.operands:
                            base = _rebuild_op_atom(atom.op, new_ops)
                        else:
                            base = SymbolicExpr.from_atom(atom)
                    else:
                        base = SymbolicExpr.from_atom(atom)
                else:
                    base = rep
                for _ in range(exp):
                    term = term * base
            out = out + term
        return out

    # -- bounds ----------------------------------------------------------------
    def interval(self, env_bounds: "BoundsLike" = None) -> "Interval":
        """Conservative integer interval of this expression.

        ``env_bounds`` maps symbolic dim names to declared ranges — a
        :class:`~repro.core.symbolic.intervals.BoundEnv`, a plain mapping
        ``{name: (lo, hi)}`` (``None`` = unbounded; a bare int declares
        only the upper bound), or ``None`` for the default assumption that
        every dim is ``>= 1``.  Opaque atoms (floordiv/mod/max/min) use the
        exact interval rules from :mod:`intervals`.
        """
        from .intervals import BoundEnv, Interval

        env = env_bounds if isinstance(env_bounds, BoundEnv) else BoundEnv(env_bounds)
        # fast path — size-style polynomials: every coefficient positive,
        # every atom a plain dim with a nonnegative declared range.  Such a
        # polynomial is monotone in every dim, so its exact hull is just the
        # two corner evaluations (no interval products, no .power calls)
        monotone = True
        for mono, coeff in self.terms:
            if coeff < 0:
                monotone = False
                break
            for atom, _exp in mono:
                if type(atom) is not Atom:
                    monotone = False
                    break
                lo = env.lookup(atom.name).lo
                if lo is None or lo < 0:
                    monotone = False
                    break
            else:
                continue
            break
        if monotone:
            lo_env, hi_env, bounded = {}, {}, True
            for mono, _coeff in self.terms:
                for atom, _exp in mono:
                    iv = env.lookup(atom.name)
                    lo_env[atom.name] = iv.lo
                    if iv.hi is None:
                        bounded = False
                    else:
                        hi_env[atom.name] = iv.hi
            return Interval(self.evaluate(lo_env),
                            self.evaluate(hi_env) if bounded else None)
        total = Interval.point(0)
        for mono, coeff in self.terms:
            term = Interval.point(coeff)
            for atom, exp in mono:
                term = term * _atom_interval(atom, env).power(exp)
            total = total + term
        return total

    def bounds(self, env_bounds: "BoundsLike" = None) -> Tuple[Optional[int], Optional[int]]:
        """``(lo, hi)`` integer bounds of this expression; see :meth:`interval`.

        ``None`` means unbounded in that direction.  Sound: for every env
        within the declared ranges, ``lo <= self.evaluate(env) <= hi``.
        """
        iv = self.interval(env_bounds)
        return iv.lo, iv.hi

    # -- dunder -----------------------------------------------------------------
    def __eq__(self, other: object) -> bool:
        if self is other:          # interned: the overwhelmingly common case
            return True
        if isinstance(other, int):
            c = self.constant_value()
            return c is not None and c == other
        if not isinstance(other, SymbolicExpr):
            return NotImplemented
        # structural fallback: interning is best-effort under threads, so
        # two live equal instances are possible (rare) and must still match
        return self.terms == other.terms

    def __hash__(self) -> int:
        return self._hash

    def __repr__(self) -> str:
        if not self.terms:
            return "0"
        parts = []
        for mono, coeff in self.terms:
            if not mono:
                parts.append(str(coeff))
                continue
            factors = "*".join(
                (repr(a) if e == 1 else f"{a!r}^{e}") for a, e in mono
            )
            if coeff == 1:
                parts.append(factors)
            elif coeff == -1:
                parts.append(f"-{factors}")
            else:
                parts.append(f"{coeff}*{factors}")
        return " + ".join(parts).replace("+ -", "- ")


def _rebuild_op_atom(op: str, operands: Tuple[SymbolicExpr, ...]) -> SymbolicExpr:
    if op == "floordiv":
        return operands[0].floordiv(operands[1])
    if op == "mod":
        return operands[0].mod(operands[1])
    if op == "max":
        return SymbolicExpr.max_of(*operands)
    if op == "min":
        return SymbolicExpr.min_of(*operands)
    raise ValueError(op)


def _atom_interval(atom: AtomT, env) -> "Interval":
    """Interval of a single atom under a BoundEnv (exact OpAtom rules)."""
    from .intervals import Interval

    if isinstance(atom, Atom):
        return env.lookup(atom.name)
    # opaque compound: recurse into operand expressions
    ops = [o.interval(env) for o in atom.operands]
    if atom.op == "floordiv":
        return ops[0].floordiv(ops[1])
    if atom.op == "mod":
        return ops[0].mod(ops[1])
    if atom.op == "max":
        out = ops[0]
        for o in ops[1:]:
            out = out.max_(o)
        return out
    if atom.op == "min":
        out = ops[0]
        for o in ops[1:]:
            out = out.min_(o)
        return out
    return Interval(0, None)  # unknown opaque op: nonnegative dim arithmetic


ExprLike = Union[int, SymbolicExpr]

ZERO = SymbolicExpr.constant(0)
ONE = SymbolicExpr.constant(1)


def size_of(shape: Iterable[ExprLike]) -> SymbolicExpr:
    """Element count of a shape whose dims are ints or SymbolicExprs."""
    out = ONE
    for d in shape:
        out = out * SymbolicExpr.wrap(d)
    return out
