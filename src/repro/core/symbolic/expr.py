"""SymbolicExpr: the algebraic representation of symbolic shape dimensions.

This is the paper's ``SymbolicExpr`` (§2.1): a canonical multivariate
polynomial over *atoms*.  An atom is either a plain symbolic dimension
(``@S0`` in the paper, a free variable such as a batch or sequence length)
or an *opaque* compound (floordiv / mod / max / min over sub-expressions)
which participates in the polynomial as an indivisible variable but can
still be evaluated numerically and bounded.

Representation: ``terms`` maps a *monomial* — a sorted tuple of
``(atom, exponent)`` pairs — to an integer coefficient.  The empty monomial
is the constant term.  This canonical form makes equality, addition and
multiplication exact, which is what the paper's comparisons build on.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Mapping, Optional, Tuple, Union

# ---------------------------------------------------------------------------
# Atoms
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Atom:
    """A symbolic dimension variable (paper's ``SymbolicDim``)."""

    name: str

    def evaluate(self, env: Mapping[str, int]) -> int:
        try:
            return int(env[self.name])
        except KeyError:
            raise KeyError(f"unbound symbolic dim {self.name!r}") from None

    def free_vars(self) -> frozenset:
        return frozenset({self.name})

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return self.name


@dataclass(frozen=True)
class OpAtom:
    """An opaque compound atom: floordiv/mod/max/min over SymbolicExprs.

    These arise from shape arithmetic that is not polynomial.  They are
    treated as indivisible variables by the polynomial algebra, remain
    evaluable, and expose conservative bounds.
    """

    op: str  # 'floordiv' | 'mod' | 'max' | 'min'
    operands: Tuple["SymbolicExpr", ...]

    def evaluate(self, env: Mapping[str, int]) -> int:
        vals = [x.evaluate(env) for x in self.operands]
        if self.op == "floordiv":
            return vals[0] // vals[1]
        if self.op == "mod":
            return vals[0] % vals[1]
        if self.op == "max":
            return max(vals)
        if self.op == "min":
            return min(vals)
        raise ValueError(f"unknown op atom {self.op}")

    def free_vars(self) -> frozenset:
        out: frozenset = frozenset()
        for x in self.operands:
            out |= x.free_vars()
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{self.op}({', '.join(map(repr, self.operands))})"


AtomT = Union[Atom, OpAtom]
Monomial = Tuple[Tuple[AtomT, int], ...]  # sorted by atom repr
_EMPTY: Monomial = ()


def _mono_key(item: Tuple[AtomT, int]) -> str:
    return repr(item[0])


def _mono_mul(a: Monomial, b: Monomial) -> Monomial:
    powers: Dict[AtomT, int] = {}
    for atom, exp in itertools.chain(a, b):
        powers[atom] = powers.get(atom, 0) + exp
    items = [(atom, exp) for atom, exp in powers.items() if exp != 0]
    items.sort(key=_mono_key)
    return tuple(items)


# ---------------------------------------------------------------------------
# SymbolicExpr
# ---------------------------------------------------------------------------


class SymbolicExpr:
    """Canonical integer polynomial over atoms.  Immutable."""

    __slots__ = ("terms", "_hash")

    def __init__(self, terms: Mapping[Monomial, int]):
        clean = {m: c for m, c in terms.items() if c != 0}
        object.__setattr__(self, "terms", tuple(sorted(clean.items(), key=lambda kv: tuple(map(_mono_key, kv[0])))))
        object.__setattr__(self, "_hash", None)

    # -- constructors -------------------------------------------------------
    @staticmethod
    def constant(c: int) -> "SymbolicExpr":
        return SymbolicExpr({_EMPTY: int(c)})

    @staticmethod
    def var(name: str) -> "SymbolicExpr":
        return SymbolicExpr({((Atom(name), 1),): 1})

    @staticmethod
    def from_atom(atom: AtomT) -> "SymbolicExpr":
        return SymbolicExpr({((atom, 1),): 1})

    @staticmethod
    def wrap(x: "ExprLike") -> "SymbolicExpr":
        if isinstance(x, SymbolicExpr):
            return x
        if isinstance(x, (int,)):
            return SymbolicExpr.constant(x)
        raise TypeError(f"cannot wrap {type(x)} as SymbolicExpr")

    # -- inspection ----------------------------------------------------------
    def as_dict(self) -> Dict[Monomial, int]:
        return dict(self.terms)

    def is_constant(self) -> bool:
        return all(m == _EMPTY for m, _ in self.terms)

    def constant_value(self) -> Optional[int]:
        if not self.terms:
            return 0
        if self.is_constant():
            return self.terms[0][1]
        return None

    def free_vars(self) -> frozenset:
        out: frozenset = frozenset()
        for mono, _ in self.terms:
            for atom, _exp in mono:
                out |= atom.free_vars()
        return out

    def atoms(self) -> frozenset:
        out = set()
        for mono, _ in self.terms:
            for atom, _exp in mono:
                out.add(atom)
        return frozenset(out)

    # -- algebra -------------------------------------------------------------
    def __add__(self, other: "ExprLike") -> "SymbolicExpr":
        other = SymbolicExpr.wrap(other)
        acc = dict(self.terms)
        for m, c in other.terms:
            acc[m] = acc.get(m, 0) + c
        return SymbolicExpr(acc)

    __radd__ = __add__

    def __neg__(self) -> "SymbolicExpr":
        return SymbolicExpr({m: -c for m, c in self.terms})

    def __sub__(self, other: "ExprLike") -> "SymbolicExpr":
        return self + (-SymbolicExpr.wrap(other))

    def __rsub__(self, other: "ExprLike") -> "SymbolicExpr":
        return SymbolicExpr.wrap(other) + (-self)

    def __mul__(self, other: "ExprLike") -> "SymbolicExpr":
        other = SymbolicExpr.wrap(other)
        acc: Dict[Monomial, int] = {}
        for m1, c1 in self.terms:
            for m2, c2 in other.terms:
                m = _mono_mul(m1, m2)
                acc[m] = acc.get(m, 0) + c1 * c2
        return SymbolicExpr(acc)

    __rmul__ = __mul__

    def floordiv(self, other: "ExprLike") -> "SymbolicExpr":
        other = SymbolicExpr.wrap(other)
        oc = other.constant_value()
        if oc is not None and oc != 0:
            # exact division of every coefficient -> stay polynomial
            if all(c % oc == 0 for _, c in self.terms):
                return SymbolicExpr({m: c // oc for m, c in self.terms})
        sc = self.constant_value()
        if sc is not None and oc is not None and oc != 0:
            return SymbolicExpr.constant(sc // oc)
        return SymbolicExpr.from_atom(OpAtom("floordiv", (self, other)))

    def mod(self, other: "ExprLike") -> "SymbolicExpr":
        other = SymbolicExpr.wrap(other)
        sc, oc = self.constant_value(), other.constant_value()
        if sc is not None and oc is not None and oc != 0:
            return SymbolicExpr.constant(sc % oc)
        if oc is not None and oc != 0 and all(c % oc == 0 for _, c in self.terms):
            return SymbolicExpr.constant(0)
        return SymbolicExpr.from_atom(OpAtom("mod", (self, other)))

    @staticmethod
    def max_of(a: "ExprLike", b: "ExprLike") -> "SymbolicExpr":
        a, b = SymbolicExpr.wrap(a), SymbolicExpr.wrap(b)
        if a == b:
            return a
        ca, cb = a.constant_value(), b.constant_value()
        if ca is not None and cb is not None:
            return SymbolicExpr.constant(max(ca, cb))
        return SymbolicExpr.from_atom(OpAtom("max", (a, b)))

    @staticmethod
    def min_of(a: "ExprLike", b: "ExprLike") -> "SymbolicExpr":
        a, b = SymbolicExpr.wrap(a), SymbolicExpr.wrap(b)
        if a == b:
            return a
        ca, cb = a.constant_value(), b.constant_value()
        if ca is not None and cb is not None:
            return SymbolicExpr.constant(min(ca, cb))
        return SymbolicExpr.from_atom(OpAtom("min", (a, b)))

    # -- evaluation / substitution -------------------------------------------
    def evaluate(self, env: Mapping[str, int]) -> int:
        total = 0
        for mono, coeff in self.terms:
            v = coeff
            for atom, exp in mono:
                v *= atom.evaluate(env) ** exp
            total += v
        return total

    def substitute(self, mapping: Mapping[AtomT, "SymbolicExpr"]) -> "SymbolicExpr":
        """Replace atoms by expressions (used by the shape graph's rewriting)."""
        out = SymbolicExpr.constant(0)
        for mono, coeff in self.terms:
            term = SymbolicExpr.constant(coeff)
            for atom, exp in mono:
                rep = mapping.get(atom)
                if rep is None:
                    # rebuild OpAtoms whose operands may contain replaced atoms
                    if isinstance(atom, OpAtom):
                        new_ops = tuple(o.substitute(mapping) for o in atom.operands)
                        if new_ops != atom.operands:
                            base = _rebuild_op_atom(atom.op, new_ops)
                        else:
                            base = SymbolicExpr.from_atom(atom)
                    else:
                        base = SymbolicExpr.from_atom(atom)
                else:
                    base = rep
                for _ in range(exp):
                    term = term * base
            out = out + term
        return out

    # -- bounds ----------------------------------------------------------------
    def bounds(
        self,
        lo_env: Callable[[AtomT], Optional[int]],
        hi_env: Callable[[AtomT], Optional[int]],
    ) -> Tuple[Optional[int], Optional[int]]:
        """Interval bound of the polynomial given per-atom bounds.

        Atoms are assumed nonnegative (tensor dims), so a monomial with
        positive coefficient is minimized at atom lower bounds and maximized
        at upper bounds (and vice versa for negative coefficients).  Returns
        (lo, hi); ``None`` means unbounded in that direction.
        """
        total_lo: Optional[int] = 0
        total_hi: Optional[int] = 0
        for mono, coeff in self.terms:
            if not mono:  # constant
                if total_lo is not None:
                    total_lo += coeff
                if total_hi is not None:
                    total_hi += coeff
                continue
            mono_lo, mono_hi = 1, 1  # product of atom bounds
            for atom, exp in mono:
                alo, ahi = _atom_bounds(atom, lo_env, hi_env)
                mono_lo = None if (mono_lo is None or alo is None) else mono_lo * (alo ** exp)
                mono_hi = None if (mono_hi is None or ahi is None) else mono_hi * (ahi ** exp)
            if coeff > 0:
                t_lo = None if mono_lo is None else coeff * mono_lo
                t_hi = None if mono_hi is None else coeff * mono_hi
            else:
                t_lo = None if mono_hi is None else coeff * mono_hi
                t_hi = None if mono_lo is None else coeff * mono_lo
            total_lo = None if (total_lo is None or t_lo is None) else total_lo + t_lo
            total_hi = None if (total_hi is None or t_hi is None) else total_hi + t_hi
        return total_lo, total_hi

    # -- dunder -----------------------------------------------------------------
    def __eq__(self, other: object) -> bool:
        if isinstance(other, int):
            return self.terms == SymbolicExpr.constant(other).terms
        if not isinstance(other, SymbolicExpr):
            return NotImplemented
        return self.terms == other.terms

    def __hash__(self) -> int:
        h = object.__getattribute__(self, "_hash")
        if h is None:
            h = hash(self.terms)
            object.__setattr__(self, "_hash", h)
        return h

    def __repr__(self) -> str:
        if not self.terms:
            return "0"
        parts = []
        for mono, coeff in self.terms:
            if not mono:
                parts.append(str(coeff))
                continue
            factors = "*".join(
                (repr(a) if e == 1 else f"{a!r}^{e}") for a, e in mono
            )
            if coeff == 1:
                parts.append(factors)
            elif coeff == -1:
                parts.append(f"-{factors}")
            else:
                parts.append(f"{coeff}*{factors}")
        return " + ".join(parts).replace("+ -", "- ")


def _rebuild_op_atom(op: str, operands: Tuple[SymbolicExpr, ...]) -> SymbolicExpr:
    if op == "floordiv":
        return operands[0].floordiv(operands[1])
    if op == "mod":
        return operands[0].mod(operands[1])
    if op == "max":
        return SymbolicExpr.max_of(*operands)
    if op == "min":
        return SymbolicExpr.min_of(*operands)
    raise ValueError(op)


def _atom_bounds(
    atom: AtomT,
    lo_env: Callable[[AtomT], Optional[int]],
    hi_env: Callable[[AtomT], Optional[int]],
) -> Tuple[Optional[int], Optional[int]]:
    lo, hi = lo_env(atom), hi_env(atom)
    if isinstance(atom, OpAtom) and (lo is None or hi is None):
        # derive conservative bounds from operand bounds
        ob = [o.bounds(lambda a: lo_env(a), lambda a: hi_env(a)) for o in atom.operands]
        if atom.op == "floordiv":
            (nlo, nhi), (dlo, dhi) = ob
            d_lo = 0 if (nlo is None or dhi is None or dhi <= 0) else nlo // dhi
            d_hi = None if (nhi is None or dlo is None or dlo <= 0) else nhi // dlo
            lo = d_lo if lo is None else lo
            hi = d_hi if hi is None else hi
        elif atom.op == "mod":
            _, (dlo, dhi) = ob
            lo = 0 if lo is None else lo
            hi = (dhi - 1 if dhi is not None else None) if hi is None else hi
        elif atom.op == "max":
            los = [b[0] for b in ob]
            his = [b[1] for b in ob]
            lo = (max(x for x in los if x is not None) if any(x is not None for x in los) else None) if lo is None else lo
            hi = (None if any(x is None for x in his) else max(his)) if hi is None else hi
        elif atom.op == "min":
            los = [b[0] for b in ob]
            his = [b[1] for b in ob]
            lo = (None if any(x is None for x in los) else min(los)) if lo is None else lo
            hi = (min(x for x in his if x is not None) if any(x is not None for x in his) else None) if hi is None else hi
    if lo is None:
        lo = 0  # tensor dims are nonnegative
    return lo, hi


ExprLike = Union[int, SymbolicExpr]

ZERO = SymbolicExpr.constant(0)
ONE = SymbolicExpr.constant(1)


def size_of(shape: Iterable[ExprLike]) -> SymbolicExpr:
    """Element count of a shape whose dims are ints or SymbolicExprs."""
    out = ONE
    for d in shape:
        out = out * SymbolicExpr.wrap(d)
    return out
