"""Conversion between JAX shape-polymorphism dims and our SymbolicExpr.

JAX's ``jax.export.symbolic_shape`` dims are ``_DimExpr`` polynomials whose
terms/factors we walk structurally (``_sorted_terms`` → ``(_DimTerm, coeff)``;
``_DimTerm._factors`` → ``(_DimFactor, exp)``; a factor is either a plain
variable or an operation (floordiv/mod/max/min) over sub-_DimExprs).

This module is the bridge between the tracing frontend (jaxprs with
polymorphic avals) and the paper's symbolic machinery.  If JAX internals
shift, ``dim_to_expr`` falls back to parsing nothing — it raises, and the
caller treats the dim as a fresh opaque symbol, which is sound (it only
reduces comparability, never correctness).
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Tuple

from .expr import Atom, OpAtom, SymbolicExpr

try:  # JAX >= 0.4.30 layout
    from jax._src.export import shape_poly as _sp

    _DimExpr = _sp._DimExpr
except Exception:  # pragma: no cover - environment without jax.export internals
    _DimExpr = ()


def is_symbolic_dim(d: Any) -> bool:
    return isinstance(d, _DimExpr) if _DimExpr else False


def dim_to_expr(d: Any) -> SymbolicExpr:
    """Convert an int or jax _DimExpr into a SymbolicExpr."""
    if isinstance(d, (int,)):
        return SymbolicExpr.constant(d)
    if not is_symbolic_dim(d):
        raise TypeError(f"not a dimension: {type(d)}")
    out = SymbolicExpr.constant(0)
    for term, coeff in d._sorted_terms:
        t = SymbolicExpr.constant(int(coeff))
        for factor, exp in term._factors:
            base = _factor_to_expr(factor)
            for _ in range(int(exp)):
                t = t * base
        out = out + t
    return out


def _factor_to_expr(factor: Any) -> SymbolicExpr:
    if factor.var is not None:
        return SymbolicExpr.var(str(factor.var))
    op = str(factor.operation)
    operands = tuple(dim_to_expr(o) if is_symbolic_dim(o) else SymbolicExpr.constant(int(o))
                     for o in factor.operands)
    if op == "floordiv":
        return operands[0].floordiv(operands[1])
    if op == "mod":
        return operands[0].mod(operands[1])
    if op == "max":
        return SymbolicExpr.max_of(*operands)
    if op == "min":
        return SymbolicExpr.min_of(*operands)
    # Unknown operation: opaque but evaluable only via jax itself -> treat as
    # a fresh named atom keyed by its repr (sound, loses comparability).
    return SymbolicExpr.var(f"opaque<{factor}>")


def shape_to_exprs(shape: Tuple[Any, ...]) -> Tuple[SymbolicExpr, ...]:
    return tuple(dim_to_expr(d) for d in shape)


def refine_dim(d: Any, env: Mapping[str, int]) -> int:
    """Evaluate a (possibly symbolic) dim to a concrete int given an env."""
    if isinstance(d, int):
        return d
    return dim_to_expr(d).evaluate(env)


# -- declared dim ranges (bounded dynamic shapes) -----------------------------


def parse_range_spec(spec: Any) -> Tuple[Any, Any]:
    """Parse a user-facing dim-range spec into ``(lo, hi)``.

    Accepted forms (``None`` = unbounded on that side):

    - ``(lo, hi)`` tuple/list — either entry may be ``None``;
    - a bare ``int`` N — torch_xla-style ``<=N`` upper bound, lo defaults 1;
    - strings ``"lo..hi"``, ``"..hi"``, ``"lo.."``, ``"<=hi"``, ``">=lo"``.
    """
    if isinstance(spec, int):
        return 1, int(spec)
    if isinstance(spec, (tuple, list)):
        if len(spec) != 2:
            raise ValueError(f"range spec must be (lo, hi), got {spec!r}")
        lo, hi = spec
        return (None if lo is None else int(lo),
                None if hi is None else int(hi))
    if isinstance(spec, str):
        s = spec.replace(" ", "")
        if s.startswith("<="):
            return 1, int(s[2:])
        if s.startswith(">="):
            return int(s[2:]), None
        if ".." in s:
            lo_s, hi_s = s.split("..", 1)
            return (int(lo_s) if lo_s else None), (int(hi_s) if hi_s else None)
        raise ValueError(f"unrecognized range spec {spec!r}")
    raise TypeError(f"unrecognized range spec {spec!r}")


def declare_dim_ranges(shape_graph: Any, specs: Optional[Mapping[str, Any]]) -> None:
    """Record ``optimize(..., dynamic_dims=...)`` range specs on a ShapeGraph.

    ``specs`` maps symbolic dim names (as written in ``symbolic_dims``) to
    :func:`parse_range_spec`-accepted values.  Dims traced but absent from
    ``specs`` keep the default ``[1, +inf)`` assumption.
    """
    if not specs:
        return
    for name, spec in specs.items():
        lo, hi = parse_range_spec(spec)
        shape_graph.declare_range(name, lo, hi)
