"""Integer interval arithmetic for symbolic-shape bounds analysis.

The paper's polynomial comparison (§2.1–2.2) frequently returns
"incomparable" because a difference polynomial has coefficients of mixed
sign.  Bounded dynamic shapes (torch_xla's ``<=N`` dims, SoD²/Tempo-style
value-range analysis) resolve many of those cases: once every symbolic dim
carries a declared range, every ``SymbolicExpr`` evaluates to a sound
``[lo, hi]`` integer interval, and interval separation decides the
comparison.

``Interval`` is a closed integer interval where ``lo is None`` means −∞ and
``hi is None`` means +∞.  All operations are *conservative*: the result
interval contains every value the operation can produce for operands drawn
from the input intervals.  floordiv / mod / max / min get exact rules (not
just corner products), matching the opaque ``OpAtom``s of ``expr.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Tuple, Union

# Extended-integer helpers: values are int or None standing for an infinity.
# The direction of the infinity is carried by context (lo=None ⇒ −∞,
# hi=None ⇒ +∞), so arithmetic below is written per bound, not generically.


def _add(a: Optional[int], b: Optional[int]) -> Optional[int]:
    if a is None or b is None:
        return None
    return a + b


def _min2(a: Optional[int], b: Optional[int]) -> Optional[int]:
    """min for *lower* bounds (None = −∞ absorbs)."""
    if a is None or b is None:
        return None
    return min(a, b)


def _max2(a: Optional[int], b: Optional[int]) -> Optional[int]:
    """max for *upper* bounds (None = +∞ absorbs)."""
    if a is None or b is None:
        return None
    return max(a, b)


@dataclass(frozen=True)
class Interval:
    """Closed integer interval [lo, hi]; ``None`` = unbounded on that side."""

    lo: Optional[int] = None
    hi: Optional[int] = None

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def point(v: int) -> "Interval":
        return Interval(v, v)

    @staticmethod
    def top() -> "Interval":
        return Interval(None, None)

    @staticmethod
    def nonneg() -> "Interval":
        return Interval(0, None)

    # -- predicates -----------------------------------------------------------
    def is_point(self) -> bool:
        return self.lo is not None and self.lo == self.hi

    def contains(self, v: int) -> bool:
        if self.lo is not None and v < self.lo:
            return False
        if self.hi is not None and v > self.hi:
            return False
        return True

    def is_empty(self) -> bool:
        return self.lo is not None and self.hi is not None and self.lo > self.hi

    # -- lattice --------------------------------------------------------------
    def hull(self, other: "Interval") -> "Interval":
        """Smallest interval containing both (interval union hull)."""
        return Interval(_min2(self.lo, other.lo), _max2(self.hi, other.hi))

    def meet(self, other: "Interval") -> "Interval":
        """Intersection (may be empty)."""
        lo = other.lo if self.lo is None else (self.lo if other.lo is None else max(self.lo, other.lo))
        hi = other.hi if self.hi is None else (self.hi if other.hi is None else min(self.hi, other.hi))
        return Interval(lo, hi)

    # -- arithmetic -----------------------------------------------------------
    def __add__(self, other: "IntervalLike") -> "Interval":
        other = as_interval(other)
        return Interval(_add(self.lo, other.lo), _add(self.hi, other.hi))

    def __neg__(self) -> "Interval":
        return Interval(None if self.hi is None else -self.hi,
                        None if self.lo is None else -self.lo)

    def __sub__(self, other: "IntervalLike") -> "Interval":
        return self + (-as_interval(other))

    def __mul__(self, other: "IntervalLike") -> "Interval":
        other = as_interval(other)
        # Corner products with infinity bookkeeping.  Each corner is a pair
        # (bound, sign-of-infinity); we fold them into (lo, hi) manually.
        corners = []
        for a, a_inf in ((self.lo, -1), (self.hi, +1)):
            for b, b_inf in ((other.lo, -1), (other.hi, +1)):
                if a is None and b is None:
                    corners.append((None, a_inf * b_inf))
                elif a is None:
                    if b == 0:
                        corners.append((0, 0))
                    else:
                        corners.append((None, a_inf * (1 if b > 0 else -1)))
                elif b is None:
                    if a == 0:
                        corners.append((0, 0))
                    else:
                        corners.append((None, b_inf * (1 if a > 0 else -1)))
                else:
                    corners.append((a * b, 0))
        lo: Optional[int] = None if any(v is None and s < 0 for v, s in corners) else \
            min(v for v, s in corners if v is not None)
        hi: Optional[int] = None if any(v is None and s > 0 for v, s in corners) else \
            max(v for v, s in corners if v is not None)
        # all-corners-infinite edge cases degrade to unbounded sides only
        finite = [v for v, _ in corners if v is not None]
        if not finite:
            return Interval(None, None)
        return Interval(lo, hi)

    def power(self, exp: int) -> "Interval":
        """Exact ``{x**exp : x in self}`` hull for a nonnegative int exponent.

        Computed from monotonicity (not repeated interval multiplication,
        which would treat the factors as independent and widen the result):
        odd powers are monotone; even powers are monotone in |x|.
        """
        if exp == 0:
            return Interval.point(1)
        if exp == 1:
            return self
        if exp % 2 == 1:
            return Interval(None if self.lo is None else self.lo ** exp,
                            None if self.hi is None else self.hi ** exp)
        # even: unbounded on either side means |x| is unbounded
        hi = None if (self.lo is None or self.hi is None) else \
            max(abs(self.lo), abs(self.hi)) ** exp
        if self.contains(0):
            lo = 0
        elif self.lo is not None and self.lo > 0:
            lo = self.lo ** exp
        else:  # entirely negative: nearest-to-zero corner is hi
            lo = self.hi ** exp
        return Interval(lo, hi)

    # -- the non-polynomial ops (exact rules for OpAtom) ----------------------
    def floordiv(self, other: "IntervalLike") -> "Interval":
        """Python floor division; exact over sign-constant denominator parts."""
        other = as_interval(other)
        pieces = []
        # positive denominator part [max(lo,1), hi]
        plo = 1 if other.lo is None else max(other.lo, 1)
        phi = other.hi
        if phi is None or phi >= plo:
            pieces.append(self._floordiv_signconst(Interval(plo, phi)))
        # negative denominator part [lo, min(hi,-1)]
        nhi = -1 if other.hi is None else min(other.hi, -1)
        nlo = other.lo
        if (nlo is None) or nlo <= nhi:
            pieces.append(self._floordiv_signconst(Interval(nlo, nhi)))
        if not pieces:  # denominator can only be 0 — undefined, stay sound
            return Interval.top()
        out = pieces[0]
        for p in pieces[1:]:
            out = out.hull(p)
        return out

    def _floordiv_signconst(self, d: "Interval") -> "Interval":
        """n // d where d's interval does not contain 0.

        x//d is monotone in the numerator and, for a fixed numerator,
        monotone in the denominator over a sign-constant range — so corner
        evaluation is exact.
        """
        corners = []
        unbounded_lo = unbounded_hi = False
        n_corners = [(self.lo, -1), (self.hi, +1)]
        d_corners = [(d.lo, -1), (d.hi, +1)]
        for n, n_inf in n_corners:
            for dd, d_inf in d_corners:
                if dd is not None and dd == 0:
                    continue
                if n is None and dd is None:
                    s = n_inf * d_inf
                    unbounded_lo |= s < 0
                    unbounded_hi |= s > 0
                elif n is None:
                    s = n_inf * (1 if dd > 0 else -1)
                    unbounded_lo |= s < 0
                    unbounded_hi |= s > 0
                elif dd is None:
                    # d at an infinite end: the quotient tends to 0 from
                    # above when n and d share a sign (floor 0), from below
                    # otherwise (floor −1).  d_inf > 0 iff this is the
                    # positive-denominator part's +∞ end.
                    if n == 0 or (n > 0) == (d_inf > 0):
                        corners.append(0)
                    else:
                        corners.append(-1)
                else:
                    corners.append(n // dd)
        lo = None if unbounded_lo else (min(corners) if corners else None)
        hi = None if unbounded_hi else (max(corners) if corners else None)
        return Interval(lo, hi)

    def mod(self, other: "IntervalLike") -> "Interval":
        """Python modulo (sign follows the denominator)."""
        other = as_interval(other)
        pieces = []
        # positive denominators: result in [0, d_hi - 1]
        plo = 1 if other.lo is None else max(other.lo, 1)
        phi = other.hi
        if phi is None or phi >= plo:
            if (phi is not None and plo == phi and self.lo is not None
                    and self.hi is not None and self.hi - self.lo < phi
                    and self.lo % phi <= self.hi % phi):
                # constant denominator + numerator within one residue window
                pieces.append(Interval(self.lo % phi, self.hi % phi))
            else:
                pieces.append(Interval(0, None if phi is None else phi - 1))
        # negative denominators: result in (d_lo, 0]
        nhi = -1 if other.hi is None else min(other.hi, -1)
        nlo = other.lo
        if (nlo is None) or nlo <= nhi:
            pieces.append(Interval(None if nlo is None else nlo + 1, 0))
        if not pieces:
            return Interval.top()
        out = pieces[0]
        for p in pieces[1:]:
            out = out.hull(p)
        return out

    def max_(self, other: "IntervalLike") -> "Interval":
        other = as_interval(other)
        lo = None if (self.lo is None and other.lo is None) else \
            max(x for x in (self.lo, other.lo) if x is not None)
        hi = _max2(self.hi, other.hi)
        return Interval(lo, hi)

    def min_(self, other: "IntervalLike") -> "Interval":
        other = as_interval(other)
        lo = _min2(self.lo, other.lo)
        hi = None if (self.hi is None and other.hi is None) else \
            min(x for x in (self.hi, other.hi) if x is not None)
        return Interval(lo, hi)

    # -- ordering between intervals (the Cmp fallback) ------------------------
    def definitely_lt(self, other: "IntervalLike") -> bool:
        other = as_interval(other)
        return self.hi is not None and other.lo is not None and self.hi < other.lo

    def definitely_le(self, other: "IntervalLike") -> bool:
        other = as_interval(other)
        return self.hi is not None and other.lo is not None and self.hi <= other.lo

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        lo = "-inf" if self.lo is None else str(self.lo)
        hi = "+inf" if self.hi is None else str(self.hi)
        return f"[{lo}, {hi}]"


IntervalLike = Union[int, Interval]


def as_interval(x: IntervalLike) -> Interval:
    if isinstance(x, Interval):
        return x
    if isinstance(x, int):
        return Interval.point(x)
    raise TypeError(f"cannot treat {type(x)} as Interval")


RangeLike = Union[Interval, Tuple[Optional[int], Optional[int]], int]


def _coerce_range(r: RangeLike) -> Interval:
    """Accept (lo, hi) tuples, Intervals, or a bare int upper bound."""
    if isinstance(r, Interval):
        return r
    if isinstance(r, int):
        # torch_xla-style "<=N": a bare int declares only the upper bound
        return Interval(1, r)
    lo, hi = r
    return Interval(None if lo is None else int(lo),
                    None if hi is None else int(hi))


class BoundEnv:
    """Per-dimension declared ranges backing ``SymbolicExpr.bounds``.

    Maps dim *names* to :class:`Interval`.  Unknown dims fall back to
    ``[default_lo, +inf)`` — tensor dims are at least ``default_lo``
    (1 by default: dynamic dims come from data).
    """

    def __init__(self, ranges: Optional[Mapping[str, RangeLike]] = None,
                 *, default_lo: int = 1):
        self._ranges: Dict[str, Interval] = {}
        self.default_lo = default_lo
        if ranges:
            for name, r in ranges.items():
                self.declare(name, _coerce_range(r))

    def declare(self, name: str, r: RangeLike) -> None:
        iv = _coerce_range(r)
        if iv.is_empty():
            raise ValueError(f"empty declared range for {name!r}: {iv}")
        self._ranges[name] = iv

    def lookup(self, name: str) -> Interval:
        iv = self._ranges.get(name)
        if iv is not None:
            return iv
        return Interval(self.default_lo, None)

    def declared(self) -> Mapping[str, Interval]:
        return dict(self._ranges)

    def __contains__(self, name: str) -> bool:
        return name in self._ranges

    def __repr__(self) -> str:  # pragma: no cover
        body = ", ".join(f"{k}={v!r}" for k, v in sorted(self._ranges.items()))
        return f"BoundEnv({body})"
