"""The global symbolic shape graph (paper §2.1) + declared dim ranges.

Collects algebraic relationships between symbolic dimensions — e.g.
``@S0 = 12 * @S1`` derived from a ``DynamicReshapeOp`` — and uses them to
*canonicalize* ``SymbolicExpr``s so that expressions written over different
symbol sets become comparable.  Comparison is layered:

1. canonicalize the difference polynomial and decide by its constant value
   when it is constant;
2. otherwise fall back to **interval bounds**: every symbolic dim carries a
   declared range (``declare_range``; default ``[1, +inf)``), the difference
   is evaluated in interval arithmetic, and interval separation decides.

Layer 2 is what bounded dynamic shapes buy us (torch_xla-style ``<=N``
dims): with ranges declared, many previously "incomparable" scheduling and
remat decisions resolve at compile time, and peak memory gets a guaranteed
worst-case bound.  ``cmp_stats`` records which layer resolved each query so
benchmarks can report the interval layer's contribution.

Every query is **memoized**.  Interned expression ``uid``s key three memo
tables (canonicalize / compare / interval_of); each entry records which
dim ranges its answer depended on and at what *range generation*, so a
later ``declare_range`` invalidates exactly the entries it can affect.
``specialized()`` (bucketed compilation) hands the child graph every
parent verdict that *narrowing cannot flip*: constant-layer verdicts are
range-independent, strict interval verdicts (LT/GT) only get more
separated as intervals shrink, and any verdict whose dims were not
narrowed is untouched.  ``cmp_stats`` carries ``cache_hit``/``cache_miss``
counters (and ``inherited``, the verdict count carried over at
specialization) next to the per-layer resolution counts.
"""
from __future__ import annotations

import enum
from contextlib import contextmanager
from typing import Dict, Iterable, Mapping, Optional, Set, Tuple

from .expr import Atom, AtomT, ExprLike, SymbolicExpr
from .intervals import BoundEnv, Interval, RangeLike, as_interval


class Cmp(enum.Enum):
    LT = "LT"
    LE = "LE"
    EQ = "EQ"
    GE = "GE"
    GT = "GT"
    UNKNOWN = "UNKNOWN"


# verdicts that remain exact under any narrowing of the declared ranges:
# constant-layer verdicts never consult ranges, and strict interval
# separation (lo > 0 / hi < 0) only strengthens as intervals shrink.
_STRICT = (Cmp.LT, Cmp.GT)

CmpKey = Tuple[int, int]           # (lhs uid, rhs uid) of a compare query


class _CmpEntry:
    """Memoized compare verdict + what it depended on.

    ``operands`` pins the queried expressions: memo keys are interned
    ``uid``s, and holding the exprs keeps the interned instances (and so
    the uid ↔ structure binding) alive for as long as the entry is."""

    __slots__ = ("verdict", "layer", "diff", "deps", "dep_gens", "subst_gen",
                 "gen_total", "operands")

    def __init__(self, verdict: Cmp, layer: str, diff: SymbolicExpr,
                 deps: frozenset, dep_gens: Tuple[int, ...], subst_gen: int,
                 gen_total: int = 0,
                 operands: Tuple[SymbolicExpr, ...] = ()):
        self.verdict = verdict
        self.layer = layer          # 'const' | 'interval' | 'unknown'
        self.diff = diff            # canonical difference polynomial
        self.deps = deps            # dim names the verdict consulted
        self.dep_gens = dep_gens    # their range generations at compute time
        self.subst_gen = subst_gen
        self.gen_total = gen_total  # global range gen at compute time
        self.operands = operands


class _IvlEntry:
    __slots__ = ("interval", "deps", "dep_gens", "subst_gen", "gen_total",
                 "expr")

    def __init__(self, interval: Interval, deps: frozenset,
                 dep_gens: Tuple[int, ...], subst_gen: int,
                 gen_total: int = 0, expr: Optional[SymbolicExpr] = None):
        self.interval = interval
        self.deps = deps
        self.dep_gens = dep_gens
        self.subst_gen = subst_gen
        self.gen_total = gen_total  # global range gen at compute time
        self.expr = expr            # pins the keyed interned expression


class ShapeGraph:
    """Equalities between symbolic dims + declared ranges, with rewriting.

    ``add_equality(sym, expr)`` records ``sym == expr`` (the paper's
    ``@S0 = Mul @C12, @S1``).  Internally we keep a substitution map toward
    "root" symbols and apply it to fixpoint during canonicalization.
    ``declare_range(sym, lo, hi)`` records ``lo <= sym <= hi`` for the
    interval fallback.
    """

    def __init__(self) -> None:
        self._subst: Dict[AtomT, SymbolicExpr] = {}
        self._bounds = BoundEnv(default_lo=1)  # dynamic dims come from data
        # value-dependent bounded symbols: name -> symbolic cap expression
        # (insertion-ordered; chained caps may reference earlier entries)
        self._bound_caps: Dict[str, SymbolicExpr] = {}
        # how comparisons were resolved: constant difference, interval
        # separation, or not at all — consumed by benchmarks/symbolic_coverage
        # — plus the memo table's hit/miss counters and the number of
        # verdicts inherited from a parent graph at specialization time
        self.cmp_stats: Dict[str, int] = {
            "const": 0, "interval": 0, "unknown": 0,
            "cache_hit": 0, "cache_miss": 0, "inherited": 0,
        }
        # -- memo state -------------------------------------------------------
        self._subst_gen = 0                       # bumped by add_equality
        self._range_gen: Dict[str, int] = {}      # bumped by declare_range
        self._range_gen_total = 0                 # bumped by any declare_range
        # uid -> (original, canonical); the original pins the interned key
        self._canon_memo: Dict[int, Tuple[SymbolicExpr, SymbolicExpr]] = {}
        self._cmp_memo: Dict[CmpKey, _CmpEntry] = {}
        self._ivl_memo: Dict[int, _IvlEntry] = {}
        self._record: Optional[Set[CmpKey]] = None

    # -- building -------------------------------------------------------------
    def add_equality(self, sym: "AtomT | str", expr: ExprLike) -> None:
        if isinstance(sym, str):
            sym = Atom(sym)
        expr = SymbolicExpr.wrap(expr)
        # avoid trivial/cyclic rules
        if expr.atoms() == frozenset({sym}):
            return
        if sym in self._subst and self._subst[sym] == expr:
            return
        # normalize the rhs through existing rules before storing
        expr = self._apply(expr)
        if SymbolicExpr.from_atom(sym) == expr:
            return
        self._subst[sym] = expr
        # the rewrite system changed: every canonical form is suspect —
        # drop the memo *before* re-normalizing (which calls _apply)
        self._subst_gen += 1
        self._canon_memo.clear()
        # re-normalize existing rules so chains collapse eagerly
        for k in list(self._subst):
            if k != sym:
                self._subst[k] = self._apply(self._subst[k])
        self._canon_memo.clear()   # entries cached mid-renormalization

    def declare_range(self, sym: "Atom | str", lo: Optional[int] = None,
                      hi: Optional[int] = None) -> None:
        """Declare ``lo <= sym <= hi`` (either side may stay unbounded)."""
        name = sym.name if isinstance(sym, Atom) else str(sym)
        prev = self._bounds.lookup(name)
        lo = prev.lo if lo is None else int(lo)
        hi = prev.hi if hi is None else int(hi)
        if lo is not None and lo < 0:
            raise ValueError(f"dim {name!r} cannot be negative (lo={lo})")
        self._bounds.declare(name, Interval(lo, hi))
        # lazily invalidate memo entries that consulted this dim's range
        self._range_gen[name] = self._range_gen.get(name, 0) + 1
        self._range_gen_total += 1

    # backwards-compatible alias used by earlier code/tests
    def set_bounds(self, sym: "Atom | str", lo: Optional[int] = None,
                   hi: Optional[int] = None) -> None:
        self.declare_range(sym, lo, hi)

    def declare_bound(self, sym: "Atom | str", cap: ExprLike) -> None:
        """Declare a value-dependent bounded symbol: ``0 <= sym <= cap``.

        ``cap`` is a symbolic expression over input dims (or earlier
        bounded symbols).  The symbol's range is derived *through* the
        cap's interval under the current declared ranges, so
        ``compare``/``interval_of``/``bounds_of`` answer without any
        user-declared range for the symbol itself.  Re-declaring (e.g.
        under a narrowed ``specialized`` graph) only tightens: the upper
        end meets the previous declaration.  ``lo`` is 0, not the
        ``BoundEnv`` default of 1 — a measured extent can be empty.
        """
        name = sym.name if isinstance(sym, Atom) else str(sym)
        cap = SymbolicExpr.wrap(cap)
        self._bound_caps[name] = cap
        hi = self.interval_of(cap).hi
        prev = self._bounds.lookup(name)
        if prev.hi is not None and (hi is None or prev.hi < hi):
            hi = prev.hi
        self._bounds.declare(name, Interval(0, hi))
        self._range_gen[name] = self._range_gen.get(name, 0) + 1
        self._range_gen_total += 1

    @property
    def bound_caps(self) -> Mapping[str, SymbolicExpr]:
        return dict(self._bound_caps)

    @property
    def declared_ranges(self) -> Mapping[str, Interval]:
        return self._bounds.declared()

    def bound_env(self) -> BoundEnv:
        return self._bounds

    # -- memo plumbing ---------------------------------------------------------
    def _gens_of(self, deps: frozenset) -> Tuple[int, ...]:
        return tuple(self._range_gen.get(n, 0) for n in sorted(deps))

    def _entry_valid(self, ent) -> bool:
        if ent.subst_gen != self._subst_gen:
            return False
        # fast path: no declare_range at all since the entry was stored
        if ent.gen_total == self._range_gen_total:
            return True
        return ent.dep_gens == self._gens_of(ent.deps)

    @contextmanager
    def record_cmp_keys(self):
        """Record the ``(lhs uid, rhs uid)`` key of every ``compare`` inside
        the block (memo hits included).  The compile pipeline wraps its
        scheduling + remat phases in this to learn which verdicts those
        decisions stood on — the incremental-reuse check re-validates
        exactly that set under a narrowed graph.  Nests: an inner block
        records its own set and merges it into the outer one on exit (the
        remat search records per-candidate inside the pipeline's span)."""
        prev, keys = self._record, set()
        self._record = keys
        try:
            yield keys
        finally:
            self._record = prev
            if prev is not None:
                prev |= keys

    def note_cmp_keys(self, keys: Iterable[CmpKey]) -> None:
        """Merge ``keys`` into the active recording (no-op otherwise).

        Callers that answer a comparison-derived decision from their own
        memo (e.g. the remat search's pick memo) replay the compare keys
        the original computation consulted, so dependency recording stays
        complete even when the underlying ``compare`` calls are skipped."""
        if self._record is not None:
            self._record |= set(keys)

    # -- canonicalization -------------------------------------------------------
    def _apply(self, e: SymbolicExpr, max_iter: int = 16) -> SymbolicExpr:
        if not self._subst:
            return e
        hit = self._canon_memo.get(e.uid)
        if hit is not None:
            return hit[1]
        orig = e
        for _ in range(max_iter):
            new = e.substitute(self._subst)
            if new is e or new == e:
                break
            e = new
        self._canon_memo[orig.uid] = (orig, e)
        return e

    def canonicalize(self, e: ExprLike) -> SymbolicExpr:
        return self._apply(SymbolicExpr.wrap(e))

    # -- bounds ------------------------------------------------------------------
    def interval_of(self, e: ExprLike) -> Interval:
        """Sound integer interval of ``e`` under equalities + declared ranges."""
        e = SymbolicExpr.wrap(e)
        ent = self._ivl_memo.get(e.uid)
        if ent is not None and self._entry_valid(ent):
            return ent.interval
        c = self.canonicalize(e)
        iv = c.interval(self._bounds)
        deps = c.free_vars()
        self._ivl_memo[e.uid] = _IvlEntry(iv, deps, self._gens_of(deps),
                                          self._subst_gen,
                                          gen_total=self._range_gen_total,
                                          expr=e)
        return iv

    def bounds_of(self, e: ExprLike) -> Tuple[Optional[int], Optional[int]]:
        iv = self.interval_of(e)
        return iv.lo, iv.hi

    # -- comparison ---------------------------------------------------------------
    def _decide(self, d: SymbolicExpr) -> Tuple[Cmp, str, frozenset]:
        """(verdict, layer, range deps) of a canonical difference ``d``."""
        c = d.constant_value()
        if c is not None:
            if c == 0:
                return Cmp.EQ, "const", frozenset()
            return (Cmp.GT if c > 0 else Cmp.LT), "const", frozenset()
        deps = d.free_vars()
        iv = d.interval(self._bounds)
        lo, hi = iv.lo, iv.hi
        if lo is not None and lo > 0:
            return Cmp.GT, "interval", deps
        if hi is not None and hi < 0:
            return Cmp.LT, "interval", deps
        if lo is not None and lo >= 0:
            return Cmp.GE, "interval", deps
        if hi is not None and hi <= 0:
            return Cmp.LE, "interval", deps
        return Cmp.UNKNOWN, "unknown", deps

    def compare(self, e1: ExprLike, e2: ExprLike) -> Cmp:
        """Best-effort comparison of two SymbolicExprs (paper §2.1/2.2)."""
        a, b = SymbolicExpr.wrap(e1), SymbolicExpr.wrap(e2)
        key = (a.uid, b.uid)
        if self._record is not None:
            self._record.add(key)
        ent = self._cmp_memo.get(key)
        if ent is not None and self._entry_valid(ent):
            self.cmp_stats["cache_hit"] += 1
            self.cmp_stats[ent.layer] += 1
            return ent.verdict
        self.cmp_stats["cache_miss"] += 1
        d = self.canonicalize(a - b)
        verdict, layer, deps = self._decide(d)
        self.cmp_stats[layer] += 1
        self._cmp_memo[key] = _CmpEntry(verdict, layer, d, deps,
                                        self._gens_of(deps), self._subst_gen,
                                        gen_total=self._range_gen_total,
                                        operands=(a, b))
        return verdict

    def definitely_le(self, e1: ExprLike, e2: ExprLike) -> bool:
        return self.compare(e1, e2) in (Cmp.LT, Cmp.LE, Cmp.EQ)

    def definitely_lt(self, e1: ExprLike, e2: ExprLike) -> bool:
        return self.compare(e1, e2) is Cmp.LT

    def definitely_nonpositive(self, e: ExprLike) -> bool:
        return self.compare(e, 0) in (Cmp.LT, Cmp.LE, Cmp.EQ)

    def definitely_negative(self, e: ExprLike) -> bool:
        return self.compare(e, 0) is Cmp.LT

    # -- specialization ---------------------------------------------------------
    def specialized(self, ranges: Mapping[str, RangeLike]) -> "ShapeGraph":
        """A copy with ``ranges`` *narrowing* the declared dim ranges.

        Equalities and all declared ranges carry over; each dim named in
        ``ranges`` is met (intersected) with its existing declaration, so
        the result never widens what the original graph promised.  This is
        what bucketed plan specialization runs the compile-time pipeline
        under: a tighter ``BoundEnv`` resolves interval comparisons the
        whole-range graph could not.  ``cmp_stats`` start fresh so the
        specialized compile's resolution split is measurable on its own.

        The child inherits every memoized verdict that the narrowing
        provably cannot flip — constant-layer verdicts, strict interval
        verdicts (LT/GT), and anything whose dims were not narrowed —
        counted in the child's ``cmp_stats['inherited']``.
        """
        sub = ShapeGraph()
        sub._subst = dict(self._subst)
        for name, iv in self.declared_ranges.items():
            sub._bounds.declare(name, iv)
        narrowed: Set[str] = set()
        for name, r in ranges.items():
            iv = as_interval(r) if isinstance(r, (Interval, int)) else \
                Interval(*r)
            prev = self._bounds.lookup(name)
            met = prev.meet(iv)
            if met.is_empty():
                raise ValueError(
                    f"specialized range {iv!r} for dim {name!r} does not "
                    f"intersect its declared range "
                    f"{self._bounds.lookup(name)!r}")
            sub._bounds.declare(name, met)
            if met != prev:
                narrowed.add(name)
        # re-derive bounded symbols through their caps under the narrowed
        # ranges (insertion order: chained caps reference earlier ones).
        # declare_bound only tightens, so a bound dim whose cap got
        # narrower joins the ``narrowed`` set for memo-inheritance checks.
        for name, cap in self._bound_caps.items():
            sub.declare_bound(name, cap)
            if sub._bounds.lookup(name) != self._bounds.lookup(name):
                narrowed.add(name)
        # canonical forms share the substitution map verbatim
        sub._canon_memo = dict(self._canon_memo)
        inherited = 0
        for key, ent in self._cmp_memo.items():
            if not self._entry_valid(ent):
                continue
            stable = ent.layer == "const" or \
                (ent.layer == "interval" and ent.verdict in _STRICT) or \
                not (ent.deps & narrowed)
            if stable:
                sub._cmp_memo[key] = _CmpEntry(
                    ent.verdict, ent.layer, ent.diff, ent.deps,
                    sub._gens_of(ent.deps), sub._subst_gen,
                    gen_total=sub._range_gen_total,
                    operands=ent.operands)
                inherited += 1
        for uid, ient in self._ivl_memo.items():
            if self._entry_valid(ient) and not (ient.deps & narrowed):
                sub._ivl_memo[uid] = _IvlEntry(
                    ient.interval, ient.deps, sub._gens_of(ient.deps),
                    sub._subst_gen, gen_total=sub._range_gen_total,
                    expr=ient.expr)
        sub.cmp_stats["inherited"] = inherited
        return sub

    def verdicts_match(self, parent: "ShapeGraph",
                       keys: Iterable[CmpKey]) -> bool:
        """Re-validate the parent's verdicts for ``keys`` under *this*
        (narrowed) graph: ``True`` iff every one is unchanged.

        The incremental compile path calls this on a ``specialized()``
        child with the compare keys the parent's scheduling + remat phases
        consulted — when nothing flipped, those phases would reproduce the
        same decisions verbatim, so their results can be reused.  Each key
        is answered through this graph's memo (inherited-stable verdicts
        are hits; flippable ones recompute from the stored canonical
        difference and are cached for the rest of the bucket's compile),
        with ``cmp_stats`` counted exactly as the equivalent fresh queries
        would be.  Returns on the first flipped verdict — keys after it are
        left for whichever phase actually queries them."""
        for key in keys:
            ent = parent._cmp_memo.get(key)
            if ent is None or not parent._entry_valid(ent):
                return False              # parent can't vouch: recompile
            mine = self._cmp_memo.get(key)
            if mine is not None and self._entry_valid(mine):
                self.cmp_stats["cache_hit"] += 1
                self.cmp_stats[mine.layer] += 1
                verdict = mine.verdict
            else:
                self.cmp_stats["cache_miss"] += 1
                verdict, layer, deps = self._decide(ent.diff)
                self.cmp_stats[layer] += 1
                self._cmp_memo[key] = _CmpEntry(
                    verdict, layer, ent.diff, deps, self._gens_of(deps),
                    self._subst_gen, gen_total=self._range_gen_total,
                    operands=ent.operands)
            if verdict is not ent.verdict:
                # first flip decides: later keys are (lazily) re-decided by
                # whichever phase actually queries them
                return False
        return True

    # -- introspection ---------------------------------------------------------
    @property
    def equalities(self) -> Mapping[AtomT, SymbolicExpr]:
        return dict(self._subst)

    def memo_sizes(self) -> Dict[str, int]:
        """Entry counts of the three memo tables (observability)."""
        return {"canon": len(self._canon_memo), "cmp": len(self._cmp_memo),
                "interval": len(self._ivl_memo)}

    def __repr__(self) -> str:  # pragma: no cover
        rules = ", ".join(f"{k!r}={v!r}" for k, v in self._subst.items())
        ranges = ", ".join(f"{k}∈{v!r}" for k, v in sorted(self.declared_ranges.items()))
        body = "; ".join(x for x in (rules, ranges) if x)
        return f"ShapeGraph({body})"
