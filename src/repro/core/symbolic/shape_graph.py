"""The global symbolic shape graph (paper §2.1).

Collects algebraic relationships between symbolic dimensions — e.g.
``@S0 = 12 * @S1`` derived from a ``DynamicReshapeOp`` — and uses them to
*canonicalize* ``SymbolicExpr``s so that expressions written over different
symbol sets become comparable.  Comparison is best-effort (the paper's
wording): decide by the sign of the canonicalized difference polynomial,
using per-symbol lower/upper bounds when the sign is not uniform.
"""
from __future__ import annotations

import enum
from typing import Dict, Mapping, Optional, Tuple

from .expr import Atom, AtomT, ExprLike, OpAtom, SymbolicExpr


class Cmp(enum.Enum):
    LT = "LT"
    LE = "LE"
    EQ = "EQ"
    GE = "GE"
    GT = "GT"
    UNKNOWN = "UNKNOWN"


class ShapeGraph:
    """Equalities between symbolic dims + bound info, with rewriting.

    ``add_equality(sym, expr)`` records ``sym == expr`` (the paper's
    ``@S0 = Mul @C12, @S1``).  Internally we keep a substitution map toward
    "root" symbols and apply it to fixpoint during canonicalization.
    """

    def __init__(self) -> None:
        self._subst: Dict[AtomT, SymbolicExpr] = {}
        self._lo: Dict[AtomT, int] = {}
        self._hi: Dict[AtomT, int] = {}
        self.default_lo = 1  # dynamic dims come from data; assume >= 1

    # -- building -------------------------------------------------------------
    def add_equality(self, sym: "AtomT | str", expr: ExprLike) -> None:
        if isinstance(sym, str):
            sym = Atom(sym)
        expr = SymbolicExpr.wrap(expr)
        # avoid trivial/cyclic rules
        if expr.atoms() == frozenset({sym}):
            return
        if sym in self._subst and self._subst[sym] == expr:
            return
        # normalize the rhs through existing rules before storing
        expr = self._apply(expr)
        if SymbolicExpr.from_atom(sym) == expr:
            return
        self._subst[sym] = expr
        # re-normalize existing rules so chains collapse eagerly
        for k in list(self._subst):
            if k != sym:
                self._subst[k] = self._apply(self._subst[k])

    def set_bounds(self, sym: "AtomT | str", lo: Optional[int] = None, hi: Optional[int] = None) -> None:
        if isinstance(sym, str):
            sym = Atom(sym)
        if lo is not None:
            self._lo[sym] = int(lo)
        if hi is not None:
            self._hi[sym] = int(hi)

    # -- canonicalization -------------------------------------------------------
    def _apply(self, e: SymbolicExpr, max_iter: int = 16) -> SymbolicExpr:
        if not self._subst:
            return e
        for _ in range(max_iter):
            new = e.substitute(self._subst)
            if new == e:
                return e
            e = new
        return e

    def canonicalize(self, e: ExprLike) -> SymbolicExpr:
        return self._apply(SymbolicExpr.wrap(e))

    # -- comparison ---------------------------------------------------------------
    def _lo_env(self, a: AtomT) -> Optional[int]:
        return self._lo.get(a, self.default_lo if isinstance(a, Atom) else None)

    def _hi_env(self, a: AtomT) -> Optional[int]:
        return self._hi.get(a)

    def compare(self, e1: ExprLike, e2: ExprLike) -> Cmp:
        """Best-effort comparison of two SymbolicExprs (paper §2.1/2.2)."""
        d = self.canonicalize(SymbolicExpr.wrap(e1) - SymbolicExpr.wrap(e2))
        c = d.constant_value()
        if c is not None:
            if c == 0:
                return Cmp.EQ
            return Cmp.GT if c > 0 else Cmp.LT
        lo, hi = d.bounds(self._lo_env, self._hi_env)
        if lo is not None and lo > 0:
            return Cmp.GT
        if lo is not None and lo >= 0:
            return Cmp.GE
        if hi is not None and hi < 0:
            return Cmp.LT
        if hi is not None and hi <= 0:
            return Cmp.LE
        return Cmp.UNKNOWN

    def definitely_le(self, e1: ExprLike, e2: ExprLike) -> bool:
        return self.compare(e1, e2) in (Cmp.LT, Cmp.LE, Cmp.EQ)

    def definitely_lt(self, e1: ExprLike, e2: ExprLike) -> bool:
        return self.compare(e1, e2) is Cmp.LT

    def definitely_nonpositive(self, e: ExprLike) -> bool:
        return self.compare(e, 0) in (Cmp.LT, Cmp.LE, Cmp.EQ)

    def definitely_negative(self, e: ExprLike) -> bool:
        return self.compare(e, 0) is Cmp.LT

    # -- introspection ---------------------------------------------------------
    @property
    def equalities(self) -> Mapping[AtomT, SymbolicExpr]:
        return dict(self._subst)

    def __repr__(self) -> str:  # pragma: no cover
        rules = ", ".join(f"{k!r}={v!r}" for k, v in self._subst.items())
        return f"ShapeGraph({rules})"
