"""The global symbolic shape graph (paper §2.1) + declared dim ranges.

Collects algebraic relationships between symbolic dimensions — e.g.
``@S0 = 12 * @S1`` derived from a ``DynamicReshapeOp`` — and uses them to
*canonicalize* ``SymbolicExpr``s so that expressions written over different
symbol sets become comparable.  Comparison is layered:

1. canonicalize the difference polynomial and decide by its constant value
   when it is constant;
2. otherwise fall back to **interval bounds**: every symbolic dim carries a
   declared range (``declare_range``; default ``[1, +inf)``), the difference
   is evaluated in interval arithmetic, and interval separation decides.

Layer 2 is what bounded dynamic shapes buy us (torch_xla-style ``<=N``
dims): with ranges declared, many previously "incomparable" scheduling and
remat decisions resolve at compile time, and peak memory gets a guaranteed
worst-case bound.  ``cmp_stats`` records which layer resolved each query so
benchmarks can report the interval layer's contribution.
"""
from __future__ import annotations

import enum
from typing import Dict, Mapping, Optional, Tuple

from .expr import Atom, AtomT, ExprLike, OpAtom, SymbolicExpr
from .intervals import BoundEnv, Interval, RangeLike, as_interval


class Cmp(enum.Enum):
    LT = "LT"
    LE = "LE"
    EQ = "EQ"
    GE = "GE"
    GT = "GT"
    UNKNOWN = "UNKNOWN"


class ShapeGraph:
    """Equalities between symbolic dims + declared ranges, with rewriting.

    ``add_equality(sym, expr)`` records ``sym == expr`` (the paper's
    ``@S0 = Mul @C12, @S1``).  Internally we keep a substitution map toward
    "root" symbols and apply it to fixpoint during canonicalization.
    ``declare_range(sym, lo, hi)`` records ``lo <= sym <= hi`` for the
    interval fallback.
    """

    def __init__(self) -> None:
        self._subst: Dict[AtomT, SymbolicExpr] = {}
        self._bounds = BoundEnv(default_lo=1)  # dynamic dims come from data
        # how comparisons were resolved: constant difference, interval
        # separation, or not at all — consumed by benchmarks/symbolic_coverage
        self.cmp_stats: Dict[str, int] = {"const": 0, "interval": 0, "unknown": 0}

    # -- building -------------------------------------------------------------
    def add_equality(self, sym: "AtomT | str", expr: ExprLike) -> None:
        if isinstance(sym, str):
            sym = Atom(sym)
        expr = SymbolicExpr.wrap(expr)
        # avoid trivial/cyclic rules
        if expr.atoms() == frozenset({sym}):
            return
        if sym in self._subst and self._subst[sym] == expr:
            return
        # normalize the rhs through existing rules before storing
        expr = self._apply(expr)
        if SymbolicExpr.from_atom(sym) == expr:
            return
        self._subst[sym] = expr
        # re-normalize existing rules so chains collapse eagerly
        for k in list(self._subst):
            if k != sym:
                self._subst[k] = self._apply(self._subst[k])

    def declare_range(self, sym: "Atom | str", lo: Optional[int] = None,
                      hi: Optional[int] = None) -> None:
        """Declare ``lo <= sym <= hi`` (either side may stay unbounded)."""
        name = sym.name if isinstance(sym, Atom) else str(sym)
        prev = self._bounds.lookup(name)
        lo = prev.lo if lo is None else int(lo)
        hi = prev.hi if hi is None else int(hi)
        if lo is not None and lo < 0:
            raise ValueError(f"dim {name!r} cannot be negative (lo={lo})")
        self._bounds.declare(name, Interval(lo, hi))

    # backwards-compatible alias used by earlier code/tests
    def set_bounds(self, sym: "Atom | str", lo: Optional[int] = None,
                   hi: Optional[int] = None) -> None:
        self.declare_range(sym, lo, hi)

    @property
    def declared_ranges(self) -> Mapping[str, Interval]:
        return self._bounds.declared()

    def bound_env(self) -> BoundEnv:
        return self._bounds

    # -- canonicalization -------------------------------------------------------
    def _apply(self, e: SymbolicExpr, max_iter: int = 16) -> SymbolicExpr:
        if not self._subst:
            return e
        for _ in range(max_iter):
            new = e.substitute(self._subst)
            if new == e:
                return e
            e = new
        return e

    def canonicalize(self, e: ExprLike) -> SymbolicExpr:
        return self._apply(SymbolicExpr.wrap(e))

    # -- bounds ------------------------------------------------------------------
    def interval_of(self, e: ExprLike) -> Interval:
        """Sound integer interval of ``e`` under equalities + declared ranges."""
        return self.canonicalize(e).interval(self._bounds)

    def bounds_of(self, e: ExprLike) -> Tuple[Optional[int], Optional[int]]:
        iv = self.interval_of(e)
        return iv.lo, iv.hi

    # -- comparison ---------------------------------------------------------------
    def compare(self, e1: ExprLike, e2: ExprLike) -> Cmp:
        """Best-effort comparison of two SymbolicExprs (paper §2.1/2.2)."""
        d = self.canonicalize(SymbolicExpr.wrap(e1) - SymbolicExpr.wrap(e2))
        c = d.constant_value()
        if c is not None:
            self.cmp_stats["const"] += 1
            if c == 0:
                return Cmp.EQ
            return Cmp.GT if c > 0 else Cmp.LT
        iv = d.interval(self._bounds)
        lo, hi = iv.lo, iv.hi
        if lo is not None and lo > 0:
            self.cmp_stats["interval"] += 1
            return Cmp.GT
        if hi is not None and hi < 0:
            self.cmp_stats["interval"] += 1
            return Cmp.LT
        if lo is not None and lo >= 0:
            self.cmp_stats["interval"] += 1
            return Cmp.GE
        if hi is not None and hi <= 0:
            self.cmp_stats["interval"] += 1
            return Cmp.LE
        self.cmp_stats["unknown"] += 1
        return Cmp.UNKNOWN

    def definitely_le(self, e1: ExprLike, e2: ExprLike) -> bool:
        return self.compare(e1, e2) in (Cmp.LT, Cmp.LE, Cmp.EQ)

    def definitely_lt(self, e1: ExprLike, e2: ExprLike) -> bool:
        return self.compare(e1, e2) is Cmp.LT

    def definitely_nonpositive(self, e: ExprLike) -> bool:
        return self.compare(e, 0) in (Cmp.LT, Cmp.LE, Cmp.EQ)

    def definitely_negative(self, e: ExprLike) -> bool:
        return self.compare(e, 0) is Cmp.LT

    # -- specialization ---------------------------------------------------------
    def specialized(self, ranges: Mapping[str, RangeLike]) -> "ShapeGraph":
        """A copy with ``ranges`` *narrowing* the declared dim ranges.

        Equalities and all declared ranges carry over; each dim named in
        ``ranges`` is met (intersected) with its existing declaration, so
        the result never widens what the original graph promised.  This is
        what bucketed plan specialization runs the compile-time pipeline
        under: a tighter ``BoundEnv`` resolves interval comparisons the
        whole-range graph could not.  ``cmp_stats`` start fresh so the
        specialized compile's resolution split is measurable on its own.
        """
        sub = ShapeGraph()
        sub._subst = dict(self._subst)
        for name, iv in self.declared_ranges.items():
            sub._bounds.declare(name, iv)
        for name, r in ranges.items():
            iv = as_interval(r) if isinstance(r, (Interval, int)) else \
                Interval(*r)
            met = self._bounds.lookup(name).meet(iv)
            if met.is_empty():
                raise ValueError(
                    f"specialized range {iv!r} for dim {name!r} does not "
                    f"intersect its declared range "
                    f"{self._bounds.lookup(name)!r}")
            sub._bounds.declare(name, met)
        return sub

    # -- introspection ---------------------------------------------------------
    @property
    def equalities(self) -> Mapping[AtomT, SymbolicExpr]:
        return dict(self._subst)

    def __repr__(self) -> str:  # pragma: no cover
        rules = ", ".join(f"{k!r}={v!r}" for k, v in self._subst.items())
        ranges = ", ".join(f"{k}∈{v!r}" for k, v in sorted(self.declared_ranges.items()))
        body = "; ".join(x for x in (rules, ranges) if x)
        return f"ShapeGraph({body})"
