from .graph import Graph, Node, Value
from .trace import graph_from_closed_jaxpr, refine_params, solve_env, trace_to_graph

__all__ = [
    "Graph", "Node", "Value",
    "graph_from_closed_jaxpr", "refine_params", "solve_env", "trace_to_graph",
]
