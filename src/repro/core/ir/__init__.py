from .graph import Graph, Node, Value
from .loop import (LOOP_PARAM, LoopBody, LoopPlanInfo, is_loop_node,
                   loop_body_of, rollable_body)
from .trace import (check_declared_ranges, graph_from_closed_jaxpr,
                    refine_params, solve_checked_env, solve_env,
                    trace_to_graph)

__all__ = [
    "Graph", "Node", "Value",
    "LOOP_PARAM", "LoopBody", "LoopPlanInfo", "is_loop_node",
    "loop_body_of", "rollable_body",
    "check_declared_ranges", "graph_from_closed_jaxpr", "refine_params",
    "solve_checked_env", "solve_env", "trace_to_graph",
]
