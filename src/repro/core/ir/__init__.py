from .graph import Graph, Node, Value
from .trace import (check_declared_ranges, graph_from_closed_jaxpr,
                    refine_params, solve_checked_env, solve_env,
                    trace_to_graph)

__all__ = [
    "Graph", "Node", "Value",
    "check_declared_ranges", "graph_from_closed_jaxpr", "refine_params",
    "solve_checked_env", "solve_env", "trace_to_graph",
]
