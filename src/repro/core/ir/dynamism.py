"""Value-dependent bounded dynamism: the introduce/propagate split.

SoD² classifies dynamic-shape ops into those that *introduce* dynamism
(``nonzero``, ``masked_select``, top-k with a data-dependent k, …) and
those that merely *propagate* it.  This module is the registry for the
introducing side: a primitive registered here produces, alongside its
padded-to-bound payload, an ``i32`` count scalar, and the payload's
output dim ``axis`` is rewritten at trace time to a fresh *bounded
symbol* ``__b<k>`` with a symbolic cap ``f(input dims)``.

The memory contract is XLA's bounded dynamic-shape model: the planner
reserves the cap (``f(input dims)`` is known at ``BindArg`` time), while
the runtime measures the actual extent right after the introducing
compute (the ``BindDim`` step) and publishes it into the call env, so
every *later* allocation, free and checked reuse of a bound-dependent
value uses the tight size.

``complete_bound_env`` is the single source of truth for turning a
declared env (input dims only) into a fully-evaluable env: missing bound
dims are filled with their cap, *in introduction order* so chained caps
(a bounded op feeding another) resolve.  It is deterministic in the
declared env, which is what keeps the shared resolve/size caches of
PR 4/5 sound: cache keys stay declared-env-keyed, cached sizes are cap
sizes, and measured values live only in per-call overlays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Mapping, Optional

from ..symbolic.expr import SymbolicExpr

if TYPE_CHECKING:  # pragma: no cover
    from .graph import Graph, Node


@dataclass(frozen=True)
class DimIntroSpec:
    """How a registered primitive introduces a bounded dim.

    ``padded_out``/``count_out`` index the primitive's outputs (payload
    padded to the cap, and the i32 measured-extent scalar); ``axis`` is
    the payload dim that becomes bounded; the cap expression is read off
    input ``cap_arg``'s dim ``cap_axis`` (the padded shape equals the
    input shape, so the cap is always a plain function of input dims).
    """
    padded_out: int = 0
    count_out: int = 1
    axis: int = 0
    cap_arg: int = 0
    cap_axis: int = 0


# primitive name -> spec.  kernels/ops.py registers its primitives here
# when imported; the trace consults it per eqn.
INTRODUCES_DIM: Dict[str, DimIntroSpec] = {}


def register_introduces_dim(prim_name: str,
                            spec: Optional[DimIntroSpec] = None) -> None:
    INTRODUCES_DIM[prim_name] = spec or DimIntroSpec()


def introduces_dim(prim_name: str) -> Optional[DimIntroSpec]:
    return INTRODUCES_DIM.get(prim_name)


@dataclass(frozen=True)
class BoundIntro:
    """One bounded dim introduced by one graph node (trace-time record)."""
    name: str                  # the fresh bounded symbol, e.g. "__b0"
    cap: SymbolicExpr          # symbolic upper bound f(input dims)
    node_id: int               # the introducing node
    padded_out: int            # node output index of the padded payload
    count_out: int             # node output index of the i32 count
    axis: int                  # payload dim rewritten to the bound symbol


def complete_bound_env(graph: "Graph", env: Mapping[str, int],
                       ) -> Dict[str, int]:
    """Fill missing bounded dims of ``graph`` with their cap values.

    Caller-provided values (e.g. measured extents from a previous run's
    report env) are kept; only absent bound dims are completed, in
    introduction order so chained caps resolve.  Deterministic in the
    declared env — safe to use behind declared-env-keyed caches.
    """
    bound = getattr(graph, "bound_dims", None)
    if not bound:
        return dict(env)
    out = dict(env)
    for name, cap in bound.items():
        if name not in out:
            out[name] = max(0, int(cap.evaluate(out)))
    return out
