"""Computation-graph IR over which the paper's analyses run.

A ``Graph`` is a flat list of ``Node``s (one per primitive application)
connected by ``Value``s (tensors).  Every Value carries its shape as a tuple
of ``SymbolicExpr`` dims and its byte count as a ``SymbolicExpr`` — this is
the "dynamic shape graph" of the paper, with the symbolic shape information
attached (§2.1).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..symbolic import SymbolicExpr, size_of


class Value:
    """A tensor edge in the graph."""

    __slots__ = (
        "id", "dims", "dtype", "aval_shape", "producer", "out_index",
        "consumers", "kind", "const_val", "name", "_nbytes_expr",
    )

    def __init__(
        self,
        vid: int,
        dims: Tuple[SymbolicExpr, ...],
        dtype: Any,
        aval_shape: Tuple[Any, ...],
        kind: str = "intermediate",  # 'input' | 'const' | 'intermediate'
        const_val: Any = None,
        name: str = "",
    ):
        self.id = vid
        self.dims = dims
        self.dtype = np.dtype(dtype)
        self.aval_shape = aval_shape  # raw dims (ints / jax _DimExpr), for refinement
        self.producer: Optional["Node"] = None
        self.out_index: int = -1
        self.consumers: List["Node"] = []
        self.kind = kind
        self.const_val = const_val
        self.name = name
        self._nbytes_expr = None

    @property
    def size_expr(self) -> SymbolicExpr:
        return size_of(self.dims)

    @property
    def nbytes_expr(self) -> SymbolicExpr:
        if self._nbytes_expr is None:
            self._nbytes_expr = self.size_expr * int(self.dtype.itemsize)
        return self._nbytes_expr

    def nbytes_concrete(self, env: Dict[str, int]) -> int:
        return self.nbytes_expr.evaluate(env)

    def is_materialized_input(self) -> bool:
        return self.kind in ("input", "const")

    def __repr__(self) -> str:  # pragma: no cover
        dims = "x".join(str(d) for d in self.dims) or "scalar"
        return f"%{self.id}:{self.dtype.name}[{dims}]"


class Node:
    """One primitive application."""

    __slots__ = ("id", "prim", "prim_name", "invals", "outvals", "params", "source_eqn")

    def __init__(self, nid: int, prim: Any, invals: List[Value], outvals: List[Value], params: Dict[str, Any]):
        self.id = nid
        self.prim = prim
        self.prim_name = prim.name if prim is not None else "<none>"
        self.invals = invals
        self.outvals = outvals
        self.params = params

    def __repr__(self) -> str:  # pragma: no cover
        return f"({self.id}:{self.prim_name} {self.invals} -> {self.outvals})"


# process-wide graph identity counter: interpreter/VM per-env caches are
# namespaced by it, so a size/params cache shared across executors can
# never alias two different graphs' entries for the same node/value id
# (ids restart at 0 per graph)
_GRAPH_UIDS = itertools.count()


@dataclass
class Graph:
    nodes: List[Node] = field(default_factory=list)
    inputs: List[Value] = field(default_factory=list)
    consts: List[Value] = field(default_factory=list)
    outputs: List[Value] = field(default_factory=list)
    # flat list of all values, id-indexed
    values: List[Value] = field(default_factory=list)
    in_tree: Any = None
    out_tree: Any = None
    uid: int = field(default_factory=lambda: next(_GRAPH_UIDS))
    # value-dependent bounded dims (ir.dynamism): insertion-ordered
    # bound-symbol name -> symbolic cap, and introducing node id ->
    # BoundIntro record.  Empty for purely range-dynamic graphs.
    bound_dims: Dict[str, SymbolicExpr] = field(default_factory=dict)
    bound_intros: Dict[int, Any] = field(default_factory=dict)

    _vid: itertools.count = field(default_factory=lambda: itertools.count())
    _nid: itertools.count = field(default_factory=lambda: itertools.count())

    # -- construction helpers -------------------------------------------------
    def new_value(self, dims, dtype, aval_shape, kind="intermediate", const_val=None, name="") -> Value:
        v = Value(next(self._vid), tuple(dims), dtype, tuple(aval_shape), kind, const_val, name)
        self.values.append(v)
        return v

    def add_node(self, prim, invals: Sequence[Value], outvals: Sequence[Value], params) -> Node:
        n = Node(next(self._nid), prim, list(invals), list(outvals), dict(params))
        for i, ov in enumerate(outvals):
            ov.producer = n
            ov.out_index = i
        for iv in invals:
            iv.consumers.append(n)
        self.nodes.append(n)
        return n

    # -- queries ---------------------------------------------------------------
    def last_consumer_map(self, order: Optional[Sequence[Node]] = None) -> Dict[int, Node]:
        """value id -> the node (in `order`) that consumes it last."""
        order = order if order is not None else self.nodes
        pos = {n.id: i for i, n in enumerate(order)}
        out: Dict[int, Node] = {}
        for v in self.values:
            cons = [c for c in v.consumers if c.id in pos]
            if cons:
                out[v.id] = max(cons, key=lambda n: pos[n.id])
        return out

    def validate_order(self, order: Sequence[Node]) -> None:
        """Assert `order` is a valid topological order of the graph."""
        seen = set()
        ids = [n.id for n in order]
        assert len(ids) == len(self.nodes) and set(ids) == {n.id for n in self.nodes}, \
            "order must be a permutation of graph nodes"
        for n in order:
            for iv in n.invals:
                if iv.producer is not None:
                    assert iv.producer.id in seen, (
                        f"node {n.id}({n.prim_name}) scheduled before producer "
                        f"{iv.producer.id}({iv.producer.prim_name})"
                    )
            seen.add(n.id)

    def free_symbols(self) -> frozenset:
        out: frozenset = frozenset()
        for v in self.values:
            out |= v.nbytes_expr.free_vars()
        return out

    def stats(self) -> Dict[str, int]:
        return {
            "nodes": len(self.nodes),
            "values": len(self.values),
            "inputs": len(self.inputs),
            "consts": len(self.consts),
            "outputs": len(self.outputs),
            "bound_dims": len(self.bound_dims),
        }
