"""jaxpr -> Graph tracing (the paper's "dynamic shape computation graph").

We trace the target function once with ``jax.make_jaxpr`` over
ShapeDtypeStructs whose dynamic dims are ``jax.export.symbolic_shape``
variables, then convert to our IR.  Call-like primitives (jit, remat,
custom_jvp/vjp) are inlined so the analyses see a flat op graph, matching
the paper's post-fusion HLO-level view.  Control-flow primitives are kept
opaque with one exception: a top-level ``scan`` with a *symbolic* trip
count becomes a rolled loop node (see ``ir.loop``) — its body traced once
as a sub-graph so the downstream plan is O(body), not O(t·body).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import numpy as np
from jax import tree_util
from jax._src import core as jcore

from ..symbolic import dim_to_expr
from ..symbolic.expr import SymbolicExpr
from .dynamism import BoundIntro, introduces_dim
from .graph import Graph, Node, Value
from .loop import LOOP_PARAM, LoopBody, rollable_body

# primitive name -> params key holding the sub-jaxpr to inline
_INLINE_CLOSED = {"pjit": "jaxpr", "jit": "jaxpr", "closed_call": "call_jaxpr",
                  "custom_jvp_call": "call_jaxpr", "custom_vjp_call": "call_jaxpr"}
_INLINE_OPEN = {"remat2": "jaxpr", "checkpoint": "jaxpr", "remat": "jaxpr"}


def _dims_of_aval(aval) -> Tuple[SymbolicExpr, ...]:
    return tuple(dim_to_expr(d) for d in aval.shape)


def _try_roll_scan(eqn, *, name: str) -> "LoopBody | None":
    """Convert a scan eqn to a :class:`LoopBody` when it is rollable.

    Rolled form requires a *symbolic* trip count (a static length gains
    nothing and some analyses — flops scaling, grad accumulation — rely
    on the opaque primitive), forward iteration order, no manual
    unrolling, and a body whose carry outputs satisfy
    :func:`rollable_body`.  Nested scans stay opaque: the body is traced
    with ``roll_loops=False``.
    """
    from ..symbolic import is_symbolic_dim

    params = eqn.params
    length = params.get("length")
    if not is_symbolic_dim(length):
        return None
    if params.get("reverse") or params.get("unroll", 1) not in (1, False):
        return None
    nc, nk = params["num_consts"], params["num_carry"]
    nx = len(eqn.invars) - nc - nk
    bg = graph_from_closed_jaxpr(params["jaxpr"], name=f"{name}.body",
                                 roll_loops=False)
    if bg.bound_dims:
        # a value-dependent op inside the body would need a BindDim per
        # iteration; rolled accounting has no per-step env, so the scan
        # stays opaque (the padded-to-cap semantics remain correct)
        return None
    if not rollable_body(bg, nc, nk):
        return None
    return LoopBody(graph=bg, num_consts=nc, num_carry=nk, num_xs=nx,
                    length_expr=dim_to_expr(length))


def graph_from_closed_jaxpr(closed, *, name: str = "",
                            roll_loops: bool = True) -> Graph:
    g = Graph()
    env: Dict[Any, Value] = {}
    # bound symbol -> the cap-shaped dim expr it replaced at introduction.
    # Consumers propagate the refinement forward per dim position (the
    # SoD² propagate half) so downstream allocations are accounted at the
    # bound symbol, not the cap.
    orig_expr_of: Dict[str, SymbolicExpr] = {}

    def _introduce(node: Node) -> None:
        spec = introduces_dim(node.prim_name)
        if spec is None:
            _propagate(node)
            return
        pv = node.outvals[spec.padded_out]
        cap_val = node.invals[spec.cap_arg]
        if spec.axis >= len(pv.dims) or spec.cap_axis >= len(cap_val.dims):
            return
        cap = cap_val.dims[spec.cap_axis]
        bname = f"__b{len(g.bound_dims)}"
        orig = tuple(pv.dims)
        dims = list(orig)
        dims[spec.axis] = SymbolicExpr.var(bname)
        pv.dims = tuple(dims)
        pv._nbytes_expr = None
        g.bound_dims[bname] = cap
        g.bound_intros[node.id] = BoundIntro(
            name=bname, cap=cap, node_id=node.id,
            padded_out=spec.padded_out, count_out=spec.count_out,
            axis=spec.axis)
        orig_expr_of[bname] = orig[spec.axis]

    def _propagate(node: Node) -> None:
        """Per-dim dataflow refinement of a consumer's cap-shaped output.

        An output dim expression ``e`` rewrites to a bound symbol ``b``
        iff exactly one refined operand *carries* ``b`` in its dims with
        ``e`` as the expression it replaced (so the extent provably flows
        from the bounded operand — elementwise chains, gathers, matmuls
        whose result dim is the bounded one), and no operand still holds
        ``e`` unrefined (a full-extent operand — e.g. the rhs of a padded
        add — forces the output back to the cap, which is sound).
        Anything ambiguous or synthesized from params stays at the cap.
        """
        if not orig_expr_of:
            return
        bset = frozenset(g.bound_dims)
        carried: Dict[SymbolicExpr, set] = {}
        blocked: set = set()
        for iv in node.invals:
            for d in iv.dims:
                fv = d.free_vars() & bset
                if fv:
                    for bname in fv:
                        carried.setdefault(orig_expr_of[bname],
                                           set()).add(bname)
                else:
                    blocked.add(d)
        if not carried:
            return
        for ov in node.outvals:
            if not ov.dims:
                continue
            dims = list(ov.dims)
            changed = False
            for a, e in enumerate(dims):
                if e.free_vars() & bset or e in blocked:
                    continue
                cands = carried.get(e, ())
                if len(cands) == 1:
                    dims[a] = SymbolicExpr.var(next(iter(cands)))
                    changed = True
            if changed:
                ov.dims = tuple(dims)
                ov._nbytes_expr = None

    def read(var) -> Value:
        if isinstance(var, jcore.Literal):
            aval = var.aval
            v = g.new_value(_dims_of_aval(aval), aval.dtype, aval.shape,
                            kind="const", const_val=np.asarray(var.val))
            g.consts.append(v)
            return v
        return env[var]

    def write(var, value: Value) -> None:
        env[var] = value

    jaxpr = closed.jaxpr
    # graph inputs
    for i, var in enumerate(jaxpr.invars):
        aval = var.aval
        v = g.new_value(_dims_of_aval(aval), aval.dtype, aval.shape, kind="input",
                        name=f"in{i}")
        g.inputs.append(v)
        write(var, v)
    # top-level consts
    for var, cval in zip(jaxpr.constvars, closed.consts):
        aval = var.aval
        v = g.new_value(_dims_of_aval(aval), aval.dtype, aval.shape, kind="const",
                        const_val=cval)
        g.consts.append(v)
        write(var, v)

    def process(jaxpr, read_local, write_local):
        for eqn in jaxpr.eqns:
            pname = eqn.primitive.name
            if pname in _INLINE_CLOSED or pname in _INLINE_OPEN:
                _inline(eqn, read_local, write_local)
                continue
            if pname == "scan" and roll_loops:
                body = _try_roll_scan(eqn, name=name)
                if body is not None:
                    invals = [read_local(v) for v in eqn.invars]
                    outvals = []
                    for ov in eqn.outvars:
                        aval = ov.aval
                        val = g.new_value(_dims_of_aval(aval), aval.dtype,
                                          aval.shape)
                        outvals.append(val)
                        if not isinstance(ov, jcore.DropVar):
                            write_local(ov, val)
                    g.add_node(eqn.primitive, invals, outvals,
                               {LOOP_PARAM: body})
                    continue
            invals = [read_local(v) for v in eqn.invars]
            outvals = []
            for ov in eqn.outvars:
                aval = ov.aval
                val = g.new_value(_dims_of_aval(aval), aval.dtype, aval.shape)
                outvals.append(val)
                if not isinstance(ov, jcore.DropVar):
                    write_local(ov, val)
            _introduce(g.add_node(eqn.primitive, invals, outvals, eqn.params))

    def _inline(eqn, read_outer, write_outer):
        pname = eqn.primitive.name
        local_env: Dict[Any, Value] = {}

        def read_inner(var):
            if isinstance(var, jcore.Literal):
                return read(var)
            return local_env[var]

        def write_inner(var, value):
            local_env[var] = value

        if pname in _INLINE_CLOSED:
            sub = eqn.params[_INLINE_CLOSED[pname]]
            inner, consts = sub.jaxpr, sub.consts
            n_skip = eqn.params.get("num_consts", 0)
            # custom_jvp_call passes jvp consts first in some versions; the
            # closed call_jaxpr invars match eqn invars[n_skip:] if lengths differ
            outer_invals = [read_outer(v) for v in eqn.invars]
            if len(inner.invars) != len(outer_invals):
                outer_invals = outer_invals[len(outer_invals) - len(inner.invars):]
            for var, cval in zip(inner.constvars, consts):
                aval = var.aval
                cv = g.new_value(_dims_of_aval(aval), aval.dtype, aval.shape,
                                 kind="const", const_val=cval)
                g.consts.append(cv)
                write_inner(var, cv)
        else:  # open jaxpr (remat): constvars empty, invars match eqn invars
            inner = eqn.params[_INLINE_OPEN[pname]]
            outer_invals = [read_outer(v) for v in eqn.invars]
            assert not inner.constvars, f"{pname} with constvars unsupported"
        for var, val in zip(inner.invars, outer_invals):
            write_inner(var, val)
        process(inner, read_inner, write_inner)
        for outer_var, inner_var in zip(eqn.outvars, inner.outvars):
            if isinstance(outer_var, jcore.DropVar):
                continue
            write_outer(outer_var, read_inner(inner_var))

    process(jaxpr, read, write)

    for var in jaxpr.outvars:
        g.outputs.append(read(var))
    return g


def trace_to_graph(fn: Callable, *args, **kwargs) -> Tuple[Graph, Any]:
    """Trace ``fn`` over (possibly symbolic) ShapeDtypeStruct args.

    Returns (graph, out_shape_pytree).  The graph's ``in_tree``/``out_tree``
    record the pytree structure so the interpreter can offer the original
    calling convention.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    flat_args, in_tree = tree_util.tree_flatten((args, kwargs))
    g = graph_from_closed_jaxpr(closed)
    g.in_tree = in_tree
    out_shapes = jax.eval_shape(fn, *args, **kwargs)
    _, out_tree = tree_util.tree_flatten(out_shapes)
    g.out_tree = out_tree
    return g, out_shapes


# ---------------------------------------------------------------------------
# Runtime param refinement: evaluate symbolic dims inside eqn params
# ---------------------------------------------------------------------------


def _contains_symbolic(x) -> bool:
    from ..symbolic import is_symbolic_dim
    if is_symbolic_dim(x):
        return True
    if isinstance(x, (tuple, list)):
        return any(_contains_symbolic(e) for e in x)
    if isinstance(x, dict):
        return any(_contains_symbolic(v) for v in x.values())
    return False


def refine_params(params: Dict[str, Any], env: Dict[str, int]) -> Dict[str, Any]:
    """Replace jax symbolic dims inside eqn params with concrete ints."""
    from ..symbolic import is_symbolic_dim

    def go(x):
        if is_symbolic_dim(x):
            return dim_to_expr(x).evaluate(env)
        if isinstance(x, tuple):
            rebuilt = tuple(go(e) for e in x)
            if hasattr(x, "_fields"):  # namedtuple (e.g. GatherDimensionNumbers)
                return type(x)(*rebuilt)
            return rebuilt
        if isinstance(x, list):
            return [go(e) for e in x]
        if isinstance(x, dict):
            return {k: go(v) for k, v in x.items()}
        return x

    return {k: go(v) for k, v in params.items()}


def solve_env(graph: Graph, concrete_args: Sequence[Any]) -> Dict[str, int]:
    """Bind symbolic dim variables from the concrete shapes of flat inputs."""
    env: Dict[str, int] = {}
    deferred: List[Tuple[SymbolicExpr, int]] = []
    assert len(concrete_args) == len(graph.inputs), (
        f"expected {len(graph.inputs)} flat inputs, got {len(concrete_args)}")
    for val, arr in zip(graph.inputs, concrete_args):
        shape = np.shape(arr)
        assert len(shape) == len(val.dims), f"rank mismatch for {val}: {shape}"
        for dim_expr, concrete in zip(val.dims, shape):
            fv = dim_expr.free_vars()
            if not fv:
                expected = dim_expr.evaluate({})
                assert expected == concrete, (
                    f"static dim mismatch: expected {expected}, got {concrete}")
            elif len(fv) == 1 and dim_expr == SymbolicExpr.var(next(iter(fv))):
                name = next(iter(fv))
                if name in env:
                    assert env[name] == concrete, (
                        f"inconsistent binding for {name}: {env[name]} vs {concrete}")
                env[name] = int(concrete)
            else:
                deferred.append((dim_expr, int(concrete)))
    for expr, concrete in deferred:
        got = expr.evaluate(env)
        assert got == concrete, f"composite dim mismatch: {expr}={got} vs {concrete}"
    return env


def check_declared_ranges(shape_graph, env: Dict[str, int]) -> None:
    """Enforce the declared-range contract on a solved env.

    Compile-time decisions (schedule, static regen methods, guaranteed
    peak/arena bounds, bucket partitions) assume every dim stays inside
    its declared range; a dim outside it must raise before execution.
    Shared by both executors and the bucketed dispatch path — a single
    message, a single check.
    """
    for name, iv in shape_graph.declared_ranges.items():
        v = env.get(name)
        if v is not None and not iv.contains(v):
            raise ValueError(
                f"dim {name!r}={v} outside its declared range {iv}; "
                f"re-optimize with wider dynamic_dims to run this shape")


def solve_checked_env(graph: Graph, shape_graph,
                      concrete_args: Sequence[Any]) -> Dict[str, int]:
    """``solve_env`` + declared-range validation in one step.

    Callers that pass a pre-solved env to an executor (the bucketed
    dispatch hot path) have already been through this and skip both."""
    env = solve_env(graph, concrete_args)
    check_declared_ranges(shape_graph, env)
    return env
