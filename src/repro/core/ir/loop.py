"""Symbolic control flow: the rolled ``scan`` loop node.

A ``jax.lax.scan`` with a *symbolic* trip count ``t`` becomes one
:class:`Node` in the outer graph whose params carry a :class:`LoopBody`:
the body traced once as a sub-``Graph`` (t-free — its inputs hold the
per-iteration slice avals), the carried values declared explicitly by
position, and the trip count attached as a ``SymbolicExpr``.  The whole
pipeline then works on ``O(body)`` structure instead of ``O(t·body)``:
the body is scheduled once, its arena plan is built once, remat decisions
are hoisted out (loop outputs are remat barriers), and lowering emits a
single ``Loop`` instruction running a lowered sub-``Program``.

Memory discipline (the back-edge liveness rules, see
``docs/architecture.md``):

* per-iteration temporaries die at their last in-iteration consumer and
  their buffers are reused across iterations — the steady-state arena
  contribution of the loop is independent of ``t``;
* loop-carried values stay live across the back-edge: iteration ``i``'s
  carry is freed in iteration ``i+1`` after its last consumer there (two
  buffer generations alternate, hence the *parity* in the runtime keys);
* ``xs`` slices live from the iteration preamble to their last consumer;
* stacked ``ys`` and final carries are ordinary outer values, allocated
  on loop entry / exit and owned by the outer plan.

Every executor (reference interpreter, VM dynamic path, and the
resolve-time stats replay behind the VM fast path) accounts the loop
through the single :meth:`LoopPlanInfo.account` event engine, so their
``MemoryStats`` agree by construction.  Buffers inside the loop are keyed
``(node_id, parity, body_value_id)`` — the :class:`MemoryManager` and
:class:`ArenaAllocator` are key-agnostic dicts, so the same machinery
serves both outer values (int vids) and loop-internal generations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..symbolic import SymbolicExpr, ZERO
from .graph import Graph, Node, Value

# params key marking a rolled loop node; the value is the LoopBody
LOOP_PARAM = "_loop_body"


def loop_body_of(node: Node) -> Optional["LoopBody"]:
    body = node.params.get(LOOP_PARAM)
    return body if isinstance(body, LoopBody) else None


def is_loop_node(node: Node) -> bool:
    return isinstance(node.params.get(LOOP_PARAM), LoopBody)


def rollable_body(bg: Graph, num_consts: int, num_carry: int) -> bool:
    """Whether a traced scan body admits the rolled memory discipline.

    Every carry output must be (a) produced by a body node, (b) the
    *same-slot* carry input passed through, or (c) a trace constant.
    Cross-slot pass-through (e.g. a carry swap) would make one array's
    lifetime span an unbounded number of iterations, breaking the
    two-generation (parity) buffer scheme — such scans stay opaque.
    """
    carry_in = bg.inputs[num_consts:num_consts + num_carry]
    for j, ov in enumerate(bg.outputs[:num_carry]):
        if ov.kind == "intermediate":
            if ov.producer is None:
                return False
            continue
        if ov.kind == "const":
            continue
        if ov is not carry_in[j]:       # cross-slot / xs / const-arg reuse
            return False
    return True


@dataclass
class LoopBody:
    """A scan loop's traced body + carry/xs declaration (IR-level)."""

    graph: Graph                 # body sub-graph; inputs = consts+carries+xs
    num_consts: int
    num_carry: int
    num_xs: int
    length_expr: SymbolicExpr    # symbolic trip count t
    # per-shape-graph compile artifacts, memoized (the held sg reference
    # keeps id() valid for the lifetime of the entry)
    _plans: Dict[int, Tuple[Any, "LoopPlanInfo"]] = field(
        default_factory=dict, repr=False, compare=False)

    def plan(self, shape_graph) -> "LoopPlanInfo":
        key = id(shape_graph)
        hit = self._plans.get(key)
        if hit is not None:
            return hit[1]
        if len(self._plans) > 32:
            self._plans.clear()
        lp = _build_plan_info(self, shape_graph)
        self._plans[key] = (shape_graph, lp)
        return lp


class _SymSink:
    """Symbolic alloc/free replay: running live-byte expression + the
    per-event peak candidates (deduped by expr uid)."""

    def __init__(self):
        self._live: Dict[Any, SymbolicExpr] = {}
        self.running: SymbolicExpr = ZERO
        self._cand: Dict[int, SymbolicExpr] = {}

    def alloc(self, key, size) -> None:
        self._live[key] = size
        self.running = self.running + size
        self._cand[self.running.uid] = self.running

    def free(self, key) -> None:
        self.running = self.running - self._live.pop(key)

    def peak(self) -> SymbolicExpr:
        out = ZERO
        for e in self._cand.values():
            out = SymbolicExpr.max_of(out, e)
        return out


@dataclass
class LoopPlanInfo:
    """Per-(body, shape-graph) compile artifacts: the body schedule, the
    body arena plan, and the iteration alloc/free event templates every
    executor replays through :meth:`account`."""

    body: LoopBody
    order: List[Node]                      # body schedule (computed once)
    n_steps: int
    body_arena: Any                        # body-level ArenaPlan
    # role vectors (body value ids / Values)
    carry_in: List[Value]
    carry_out: List[Value]
    y_out: List[Value]
    x_in: List[Value]
    x_used: Tuple[bool, ...]
    passthrough: Tuple[bool, ...]          # per carry slot
    const_ids: Tuple[int, ...]             # body consts with consumers
    carry_member_ids: frozenset            # produced carry vids (parity-doubled)
    # event templates (body value ids)
    iter_allocs: Tuple[Tuple[int, ...], ...]   # per position
    iter_frees: Tuple[Tuple[int, ...], ...]    # per position (same iteration)
    prev_frees: Dict[int, Tuple[int, ...]]     # pos (-1..n_steps) -> prev-iter carries
    boundary_frees: Tuple[int, ...]            # iteration end
    sizes: Dict[int, SymbolicExpr]             # bvid -> nbytes expr (event vids)
    _peak_memo: Dict[Tuple, Dict[int, SymbolicExpr]] = field(
        default_factory=dict, repr=False)

    # ------------------------------------------------------------- accounting
    def account(self, sink, nid: int, trip: int,
                size_of: Callable[[int], Any],
                outer_y: Sequence[Tuple[Any, Any]],
                outer_carry: Sequence[Optional[Tuple[Any, Any]]]) -> None:
        """Replay the loop's full alloc/free event sequence against ``sink``
        (``.alloc(key, size)`` / ``.free(key)`` — a ``MemoryManager``, a
        plain counter, or the symbolic :class:`_SymSink`).

        ``size_of(bvid)`` sizes body values; ``outer_y`` are the kept
        stacked outputs as ``(outer key, size)`` pairs, ``outer_carry`` one
        entry per carry slot (``None`` when the outer value is unkept).
        Internal buffers are keyed ``(nid, parity, bvid)``; parity 2 holds
        loop-entry constants.
        """
        for key, size in outer_y:
            sink.alloc(key, size)
        for cid in self.const_ids:
            sink.alloc((nid, 2, cid), size_of(cid))
        n_steps = self.n_steps
        for i in range(trip):
            par = i & 1
            prev = par ^ 1
            if i > 0:
                for vid in self.prev_frees.get(-1, ()):
                    sink.free((nid, prev, vid))
            for j, x in enumerate(self.x_in):
                if self.x_used[j]:
                    sink.alloc((nid, par, x.id), size_of(x.id))
            for p in range(n_steps):
                for vid in self.iter_allocs[p]:
                    sink.alloc((nid, par, vid), size_of(vid))
                for vid in self.iter_frees[p]:
                    sink.free((nid, par, vid))
                if i > 0:
                    for vid in self.prev_frees.get(p, ()):
                        sink.free((nid, prev, vid))
            for vid in self.boundary_frees:
                sink.free((nid, par, vid))
            if i > 0:
                for vid in self.prev_frees.get(n_steps, ()):
                    sink.free((nid, prev, vid))
        # exit: transfer final carries to their outer values, drop consts
        last = (trip - 1) & 1
        freed = set()
        for j, out_pair in enumerate(outer_carry):
            ov = self.carry_out[j]
            if trip > 0 and not self.passthrough[j] and ov.id not in freed:
                sink.free((nid, last, ov.id))
                freed.add(ov.id)
            if out_pair is not None:
                sink.alloc(out_pair[0], out_pair[1])
        for cid in self.const_ids:
            sink.free((nid, 2, cid))

    def peak_exprs(self, node: Node, kept: Sequence[bool]) -> Dict[int, SymbolicExpr]:
        """Symbolic internal-peak expressions, keyed by a trip-count model.

        The event profile of every iteration past the first is identical
        (same sizes, zero net change), so the exact peak of a ``T``-trip
        run is the ``min(T, 2)``-trip replay's peak — three expressions
        cover every trip count, each exact once evaluated at the env
        (the stacked-``ys`` entry allocation keeps its ``t`` factor).
        """
        key = (node.id, tuple(bool(k) for k in kept))
        out = self._peak_memo.get(key)
        if out is not None:
            return out
        nk = self.body.num_carry
        outer_y = [(ov.id, ov.nbytes_expr)
                   for ov, k in zip(node.outvals[nk:], kept[nk:]) if k]
        outer_carry = [(ov.id, ov.nbytes_expr) if k else None
                       for ov, k in zip(node.outvals[:nk], kept[:nk])]
        out = {}
        for t_model in (0, 1, 2):
            sink = _SymSink()
            self.account(sink, node.id, t_model,
                         lambda vid: self.sizes[vid], outer_y, outer_carry)
            out[t_model] = sink.peak()
        self._peak_memo[key] = out
        return out

    def peak_expr_for(self, node: Node, kept: Sequence[bool],
                      trip: int) -> SymbolicExpr:
        return self.peak_exprs(node, kept)[min(trip, 2)]

    def peak_bound_expr(self, node: Node, kept: Sequence[bool],
                        shape_graph) -> SymbolicExpr:
        """Sound symbolic peak over every in-range trip count: the max of
        the trip-model expressions the declared range of ``t`` admits."""
        t_iv = self.body.length_expr.interval(shape_graph.bound_env())
        lo = 0 if t_iv.lo is None else t_iv.lo
        hi = t_iv.hi
        exprs = self.peak_exprs(node, kept)
        out = None
        for t_model in (0, 1, 2):
            if t_model < 2:       # model covers exactly trip == t_model
                feasible = lo <= t_model and (hi is None or hi >= t_model)
            else:                 # model 2 covers every trip >= 2
                feasible = hi is None or hi >= 2
            if feasible:
                e = exprs[t_model]
                out = e if out is None else SymbolicExpr.max_of(out, e)
        return out if out is not None else ZERO

    # -------------------------------------------------------------- execution
    def execute(self, ins: Sequence[Any], trip: int, env: Dict[str, int],
                params_of: Callable[[Node], Dict[str, Any]],
                bind: Callable[[Node, Sequence[Any], Dict[str, Any]], List[Any]],
                ) -> List[Any]:
        """Run the body ``trip`` times op-by-op (reference semantics).

        Pure execution — accounting is :meth:`account`'s job.  Returns the
        outer output arrays: final carries then stacked ``ys``.
        """
        import jax.numpy as jnp
        from jax import lax

        body = self.body
        bg = body.graph
        nc, nk = body.num_consts, body.num_carry
        benv: Dict[int, Any] = {}
        for v, a in zip(bg.inputs[:nc], ins[:nc]):
            benv[v.id] = a
        for c in bg.consts:
            benv[c.id] = c.const_val
        carries = list(ins[nc:nc + nk])
        # one unstack dispatch per used xs, not one slice per iteration
        xs = [list(x) if self.x_used[j] else None
              for j, x in enumerate(ins[nc + nk:])]
        ys: List[List[Any]] = [[] for _ in self.y_out]
        for i in range(trip):
            for v, a in zip(self.carry_in, carries):
                benv[v.id] = a
            for j, v in enumerate(self.x_in):
                if self.x_used[j]:
                    benv[v.id] = xs[j][i]
            for n in self.order:
                outs = bind(n, [benv[iv.id] for iv in n.invals], params_of(n))
                for ov, oa in zip(n.outvals, outs):
                    benv[ov.id] = oa
            carries = [benv[v.id] for v in self.carry_out]
            for j, v in enumerate(self.y_out):
                ys[j].append(benv[v.id])
        if trip > 0:
            # lax.concatenate over expanded slices: bitwise-identical to
            # jnp.stack at a fraction of its dispatch cost
            stacked = [
                lax.concatenate([lax.expand_dims(y, (0,)) for y in col], 0)
                for col in ys]
        else:
            stacked = [jnp.zeros((0,) + tuple(int(d.evaluate(env))
                                              for d in v.dims), v.dtype)
                       for v in self.y_out]
        return carries + stacked


def _build_plan_info(body: LoopBody, sg) -> LoopPlanInfo:
    # local imports: scheduling/memplan import ir.graph; keeping these out
    # of module scope avoids the package-level cycle
    from ..memplan.assign import build_arena_plan
    from ..scheduling.scheduler import schedule_graph

    bg = body.graph
    nc, nk = body.num_consts, body.num_carry
    carry_in = bg.inputs[nc:nc + nk]
    x_in = bg.inputs[nc + nk:]
    carry_out = bg.outputs[:nk]
    y_out = bg.outputs[nk:]
    out_ids = {v.id for v in bg.outputs}
    y_ids = {v.id for v in y_out}

    sched = schedule_graph(bg, sg)
    order = list(sched.order)
    n_steps = len(order)
    pos = {n.id: i for i, n in enumerate(order)}
    body_arena = build_arena_plan(bg, order, sg)

    last_use: Dict[int, int] = {}
    for i, n in enumerate(order):
        for iv in n.invals:
            last_use[iv.id] = i

    produced_carries: List[Value] = []
    seen_pc = set()
    for ov in carry_out:
        if ov.kind == "intermediate" and ov.id not in seen_pc:
            produced_carries.append(ov)
            seen_pc.add(ov.id)
    passthrough = tuple(ov.kind != "intermediate" for ov in carry_out)
    x_used = tuple(bool(v.consumers) or v.id in y_ids for v in x_in)
    const_ids = tuple(c.id for c in bg.consts
                      if c.consumers or c.id in out_ids)

    sizes: Dict[int, SymbolicExpr] = {}
    for v in bg.values:
        sizes[v.id] = v.nbytes_expr

    def kept(v: Value) -> bool:
        return bool(v.consumers) or v.id in out_ids

    # per-value in-iteration death position (temps and used xs slices only;
    # carries and ys follow the back-edge / boundary rules below)
    death: Dict[int, int] = {}
    for j, v in enumerate(x_in):
        if not x_used[j]:
            continue
        death[v.id] = n_steps if v.id in y_ids else last_use.get(v.id, -1)
    for v in bg.values:
        if v.kind != "intermediate" or v.producer is None \
                or v.producer.id not in pos or not kept(v):
            continue
        if v.id in seen_pc or v.id in y_ids:
            continue
        death[v.id] = last_use[v.id]

    iter_allocs = tuple(
        tuple(ov.id for ov in n.outvals if kept(ov)) for n in order)
    iter_frees_l: List[Tuple[int, ...]] = []
    for p, n in enumerate(order):
        frees = []
        seen = set()
        for iv in n.invals:
            if iv.id in seen:
                continue
            seen.add(iv.id)
            if death.get(iv.id, -2) == p:
                frees.append(iv.id)
        iter_frees_l.append(tuple(frees))
    iter_frees = tuple(iter_frees_l)

    boundary = [v.id for j, v in enumerate(x_in)
                if x_used[j] and death.get(v.id) == n_steps]
    for v in y_out:
        if v.kind == "intermediate" and v.id not in seen_pc \
                and v.id not in boundary and v.producer is not None \
                and v.producer.id in pos:
            boundary.append(v.id)
    boundary_frees = tuple(dict.fromkeys(boundary))

    # back-edge liveness: iteration i's carry is freed in iteration i+1
    # after the last consumer of the slot(s) it feeds (-1 = preamble,
    # n_steps = iteration end when the carry is also a y / unused)
    prev_frees: Dict[int, List[int]] = {}
    for v in produced_carries:
        deaths = []
        for j in range(nk):
            if carry_out[j].id != v.id:
                continue
            cin = carry_in[j]
            d = n_steps if cin.id in y_ids else last_use.get(cin.id, -1)
            deaths.append(d)
        prev_frees.setdefault(max(deaths), []).append(v.id)

    return LoopPlanInfo(
        body=body, order=order, n_steps=n_steps, body_arena=body_arena,
        carry_in=list(carry_in), carry_out=list(carry_out),
        y_out=list(y_out), x_in=list(x_in), x_used=x_used,
        passthrough=passthrough, const_ids=const_ids,
        carry_member_ids=frozenset(seen_pc),
        iter_allocs=iter_allocs, iter_frees=iter_frees,
        prev_frees={k: tuple(v) for k, v in prev_frees.items()},
        boundary_frees=boundary_frees, sizes=sizes)
