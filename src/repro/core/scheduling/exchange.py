"""Pairwise-exchange post-pass over a schedule (beyond-paper refinement).

The greedy list scheduler (§2.2) is myopic; a classic strengthening is
bubble-style adjacent exchange: swap two neighbouring, *independent* ops
when doing so lowers the local memory peak.  Locality makes the test
exact and O(1): for [n1, n2] from usage U,

    peak = max(U + a1, U + a1 - f1 + a2)

and after the swap ``max(U + a2, U + a2 - f2 + a1)``; frees are
order-invariant when the pair shares no operands.  We require improvement
at every probe env (several dim bindings), so the exchange, like the rest
of the pipeline, is decided once and holds for all shapes.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from ..ir.graph import Graph, Node


def _node_effects(g: Graph, order: Sequence[Node], env: Dict[str, int],
                  nbytes: Dict[int, int] = None):
    """Per-node (alloc_bytes, freed_bytes) under `order` at `env`."""
    output_ids = {v.id for v in g.outputs}
    pos = {n.id: i for i, n in enumerate(order)}
    remaining = {v.id: sum(1 for c in v.consumers if c.id in pos)
                 for v in g.values}
    if nbytes is None:
        nbytes = {v.id: v.nbytes_expr.evaluate(env) for v in g.values}
    alloc, freed = [], []
    for n in order:
        a = sum(nbytes[ov.id] for ov in n.outvals
                if ov.consumers or ov.id in output_ids)
        f = 0
        seen = set()
        for iv in n.invals:
            if iv.id in seen:
                continue
            seen.add(iv.id)
            mult = sum(1 for x in n.invals if x.id == iv.id)
            remaining[iv.id] -= mult
            if remaining[iv.id] == 0 and not iv.is_materialized_input() \
                    and iv.id not in output_ids:
                f += nbytes[iv.id]
        alloc.append(a)
        freed.append(f)
    return alloc, freed


def _independent(n1: Node, n2: Node) -> bool:
    """True if swapping n1,n2 is legal and their frees are order-invariant."""
    out1 = {ov.id for ov in n1.outvals}
    in1 = {iv.id for iv in n1.invals}
    in2 = {iv.id for iv in n2.invals}
    if out1 & in2:            # n2 consumes n1's output: dependency
        return False
    if in1 & in2:             # shared operand: last-consumer flips on swap
        return False
    return True


def exchange_pass(g: Graph, order: List[Node], envs: Sequence[Dict[str, int]],
                  *, max_sweeps: int = 4, decisions=None) -> List[Node]:
    """Bubble adjacent independent pairs while the local peak improves at
    every probe env.  Returns a (possibly) improved valid order.

    ``decisions`` (an ``obs.DecisionLog``) records each accepted swap with
    its local-peak justification at the first probe env."""
    order = list(order)
    n = len(order)
    # concrete byte sizes are order-invariant: evaluate once per probe env,
    # not once per sweep
    nbytes_per_env = [{v.id: v.nbytes_expr.evaluate(env) for v in g.values}
                      for env in envs]
    for _ in range(max_sweeps):
        effects = [_node_effects(g, order, env, nbytes)
                   for env, nbytes in zip(envs, nbytes_per_env)]
        swapped = False
        i = 0
        while i < n - 1:
            n1, n2 = order[i], order[i + 1]
            if _independent(n1, n2):
                better_all = True
                strictly = False
                for alloc, freed in effects:
                    a1, f1 = alloc[i], freed[i]
                    a2, f2 = alloc[i + 1], freed[i + 1]
                    cur = max(a1, a1 - f1 + a2)
                    swp = max(a2, a2 - f2 + a1)
                    if swp > cur:
                        better_all = False
                        break
                    if swp < cur:
                        strictly = True
                if better_all and strictly:
                    if decisions is not None:
                        a1, f1 = effects[0][0][i], effects[0][1][i]
                        a2, f2 = effects[0][0][i + 1], effects[0][1][i + 1]
                        decisions.add(
                            "exchange-swap",
                            f"{n1.prim_name}#{n1.id} <-> {n2.prim_name}#{n2.id}",
                            "swap",
                            "local peak lower at every probe env",
                            position=i,
                            peak_before=max(a1, a1 - f1 + a2),
                            peak_after=max(a2, a2 - f2 + a1))
                    order[i], order[i + 1] = n2, n1
                    for alloc, freed in effects:
                        alloc[i], alloc[i + 1] = alloc[i + 1], alloc[i]
                        freed[i], freed[i + 1] = freed[i + 1], freed[i]
                    swapped = True
                    i = max(i - 1, 0)  # bubble further left
                    continue
            i += 1
        if not swapped:
            break
    g.validate_order(order)
    return order
