"""Operation scheduling based on symbolic shapes (paper §2.2).

List scheduling: repeatedly pick from the ReadySet the op with the most
favourable *memory impact*, where

    impact(op) = Σ bytes(outputs) − Σ bytes(inputs this op frees)

expressed as a ``SymbolicExpr`` and compared through the symbolic shape
graph.  When two impacts are incomparable we fall back to the paper's
lifetime-based topology heuristic.

A node's impact depends only on the *remaining-use counts* of its inputs,
and scheduling one op changes those counts for just the ops sharing an
operand with it.  The main loop therefore caches each ready op's impact
expression and invalidates only the sharers when a pick lands —
incremental maintenance instead of the former every-step recomputation,
which made the loop O(steps × ready-set × op-arity).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..ir.graph import Graph, Node, Value
from ..symbolic import Cmp, ShapeGraph, SymbolicExpr, ZERO


@dataclass
class ScheduleResult:
    order: List[Node]
    # how many ReadySet decisions were resolved symbolically vs by tie-break
    symbolic_decisions: int
    tiebreak_decisions: int

    @property
    def decision_symbolic_fraction(self) -> float:
        total = self.symbolic_decisions + self.tiebreak_decisions
        return self.symbolic_decisions / total if total else 1.0


class OpScheduler:
    """Paper §2.2 ``OpScheduler`` main loop."""

    def __init__(self, graph: Graph, shape_graph: Optional[ShapeGraph] = None,
                 *, count_input_frees: bool = False,
                 incremental_impact: bool = True,
                 impact_expr_cache: Optional[Dict] = None):
        self.g = graph
        self.sg = shape_graph if shape_graph is not None else ShapeGraph()
        self.count_input_frees = count_input_frees
        # False recomputes every ready impact each step (the pre-cache
        # behaviour) — kept for differential testing and benchmarking
        self.incremental_impact = incremental_impact
        self._cmp_cache: Dict[Tuple[SymbolicExpr, SymbolicExpr], Cmp] = {}
        self._output_ids = {v.id for v in graph.outputs}
        # (node id, frozenset of freed value ids) -> impact expr.  The
        # expression is pure graph structure, so bucketed specialization
        # shares one cache across every per-bucket schedule: re-runs
        # re-decide verdicts under their narrowed ranges but never rebuild
        # an impact polynomial
        self._expr_cache: Dict = impact_expr_cache \
            if impact_expr_cache is not None else {}
        # node id -> deduped [(input value, multiplicity)]: _impact and the
        # tiebreak both need "is n the last remaining consumer of iv", and
        # recounting multiplicities per query made them quadratic in arity
        self._in_mult: Dict[int, list] = {}
        for n in graph.nodes:
            seen: Dict[int, list] = {}
            for iv in n.invals:
                e = seen.get(iv.id)
                if e is None:
                    seen[iv.id] = [iv, 1]
                else:
                    e[1] += 1
            self._in_mult[n.id] = [(iv, m) for iv, m in seen.values()]

    # -- symbolic comparison with memoization ---------------------------------
    def _compare(self, a: SymbolicExpr, b: SymbolicExpr) -> Cmp:
        key = (a, b)
        hit = self._cmp_cache.get(key)
        if hit is None:
            hit = self.sg.compare(a, b)
            self._cmp_cache[key] = hit
        return hit

    # -- memory impact ----------------------------------------------------------
    def _impact(self, n: Node, remaining: Dict[int, int]) -> SymbolicExpr:
        # the cheap half: which inputs would scheduling n free right now?
        # (n frees iv when it is iv's only remaining consumer — multiplicity
        # counted, n may consume iv several times)
        freed: Dict[int, Value] = {}
        for iv, mult in self._in_mult[n.id]:
            if not self.count_input_frees and iv.is_materialized_input():
                continue
            if iv.id in self._output_ids:
                continue
            if remaining[iv.id] == mult:
                freed[iv.id] = iv
        # the expensive half — assembling the polynomial — is memoized on
        # (node, freed set); identical across schedules and shape graphs
        key = (n.id, frozenset(freed))
        imp = self._expr_cache.get(key)
        if imp is None:
            imp = ZERO
            for ov in n.outvals:
                if ov.consumers or ov.id in self._output_ids:
                    imp = imp + ov.nbytes_expr
            for iv in freed.values():
                imp = imp - iv.nbytes_expr
            self._expr_cache[key] = imp
        return imp

    # -- tie-break: smaller overall tensor lifetimes (paper fallback) ----------
    def _tiebreak_key(self, n: Node, orig_pos: Dict[int, int],
                      remaining: Dict[int, int]) -> Tuple:
        frees = 0
        for iv, mult in self._in_mult[n.id]:
            if remaining.get(iv.id, 0) == mult and not iv.is_materialized_input():
                frees += 1
        # prefer ops that free tensors, then ops whose results are consumed
        # soon (small distance to first consumer in original order), then
        # original program order for stability.
        next_use = min(
            (orig_pos[c.id] for ov in n.outvals for c in ov.consumers),
            default=orig_pos[n.id],
        )
        return (-frees, next_use, orig_pos[n.id])

    # -- main loop ----------------------------------------------------------------
    def schedule(self) -> ScheduleResult:
        g = self.g
        orig_pos = {n.id: i for i, n in enumerate(g.nodes)}
        # dependency counts
        deps: Dict[int, int] = {}
        for n in g.nodes:
            cnt = 0
            seen = set()
            for iv in n.invals:
                p = iv.producer
                if p is not None and p.id not in seen:
                    seen.add(p.id)
                    cnt += 1
            deps[n.id] = cnt
        remaining: Dict[int, int] = {}
        for v in g.values:
            remaining[v.id] = len(v.consumers)
        ready: List[Node] = sorted(
            (n for n in g.nodes if deps[n.id] == 0), key=lambda n: orig_pos[n.id])
        order: List[Node] = []
        sym_dec = tie_dec = 0
        node_by_id = {n.id: n for n in g.nodes}
        # children map: node -> nodes depending on it
        children: Dict[int, List[Node]] = {n.id: [] for n in g.nodes}
        for n in g.nodes:
            seen = set()
            for iv in n.invals:
                p = iv.producer
                if p is not None and p.id not in seen:
                    seen.add(p.id)
                    children[p.id].append(n)

        # consumers-by-value: whose impact a remaining-count change touches
        consumers_of = {}
        for n in g.nodes:
            for iv in n.invals:
                consumers_of.setdefault(iv.id, []).append(n)
        # node id -> cached impact expr, dropped when an operand's remaining
        # count changes (only then can the freed-set, hence impact, change)
        impact_cache: Dict[int, SymbolicExpr] = {}

        def impact_of(n: Node) -> SymbolicExpr:
            if not self.incremental_impact:
                return self._impact(n, remaining)
            imp = impact_cache.get(n.id)
            if imp is None:
                imp = self._impact(n, remaining)
                impact_cache[n.id] = imp
            return imp

        tb_memo: Dict[int, Tuple] = {}   # per-step tiebreak keys

        def tb_key(n: Node) -> Tuple:
            k = tb_memo.get(n.id)
            if k is None:
                k = self._tiebreak_key(n, orig_pos, remaining)
                tb_memo[n.id] = k
            return k

        while ready:
            # pick best by symbolic impact, tie-break by lifetime heuristic
            best = ready[0]
            best_imp = impact_of(best)
            for i in range(1, len(ready)):
                cand = ready[i]
                ci = impact_of(cand)
                c = self._compare(ci, best_imp)
                if c in (Cmp.LT, Cmp.LE):
                    # cand's impact is no worse everywhere (strictly better
                    # for LT); switching is symbolically justified — with
                    # declared dim ranges the interval fallback turns many
                    # previously UNKNOWN pairs into LT/LE/GE/GT here.
                    best, best_imp = cand, ci
                    sym_dec += 1
                elif c in (Cmp.GT, Cmp.GE):
                    # keeping the incumbent is symbolically justified
                    sym_dec += 1
                else:  # EQ (memory-neutral) / UNKNOWN -> lifetime tie-break
                    tie_dec += 1
                    if tb_key(cand) < tb_key(best):
                        best, best_imp = cand, ci
            ready.remove(best)
            order.append(best)
            impact_cache.pop(best.id, None)
            tb_memo.clear()
            # update refcounts; any op sharing a decremented operand may now
            # free it (or no longer), so its cached impact is stale
            for iv in best.invals:
                remaining[iv.id] -= 1
            for iv in {iv.id: iv for iv in best.invals}.values():
                for sharer in consumers_of.get(iv.id, ()):
                    impact_cache.pop(sharer.id, None)
            for ov in best.outvals:
                remaining[ov.id] = len(ov.consumers)
            # new ready nodes enter in original-program-order position
            # (insort keeps the list sorted; no full re-sort per step)
            for ch in children[best.id]:
                deps[ch.id] -= 1
                if deps[ch.id] == 0:
                    bisect.insort(ready, ch, key=lambda n: orig_pos[n.id])

        g.validate_order(order)
        return ScheduleResult(order, sym_dec, tie_dec)


def schedule_graph(graph: Graph, shape_graph: Optional[ShapeGraph] = None,
                   **kw) -> ScheduleResult:
    return OpScheduler(graph, shape_graph, **kw).schedule()
