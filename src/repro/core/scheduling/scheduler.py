"""Operation scheduling based on symbolic shapes (paper §2.2).

List scheduling: repeatedly pick from the ReadySet the op with the most
favourable *memory impact*, where

    impact(op) = Σ bytes(outputs) − Σ bytes(inputs this op frees)

expressed as a ``SymbolicExpr`` and compared through the symbolic shape
graph.  When two impacts are incomparable we fall back to the paper's
lifetime-based topology heuristic.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..ir.graph import Graph, Node, Value
from ..symbolic import Cmp, ShapeGraph, SymbolicExpr, ZERO


@dataclass
class ScheduleResult:
    order: List[Node]
    # how many ReadySet decisions were resolved symbolically vs by tie-break
    symbolic_decisions: int
    tiebreak_decisions: int

    @property
    def decision_symbolic_fraction(self) -> float:
        total = self.symbolic_decisions + self.tiebreak_decisions
        return self.symbolic_decisions / total if total else 1.0


class OpScheduler:
    """Paper §2.2 ``OpScheduler`` main loop."""

    def __init__(self, graph: Graph, shape_graph: Optional[ShapeGraph] = None,
                 *, count_input_frees: bool = False):
        self.g = graph
        self.sg = shape_graph if shape_graph is not None else ShapeGraph()
        self.count_input_frees = count_input_frees
        self._cmp_cache: Dict[Tuple[SymbolicExpr, SymbolicExpr], Cmp] = {}
        self._output_ids = {v.id for v in graph.outputs}

    # -- symbolic comparison with memoization ---------------------------------
    def _compare(self, a: SymbolicExpr, b: SymbolicExpr) -> Cmp:
        key = (a, b)
        hit = self._cmp_cache.get(key)
        if hit is None:
            hit = self.sg.compare(a, b)
            self._cmp_cache[key] = hit
        return hit

    # -- memory impact ----------------------------------------------------------
    def _impact(self, n: Node, remaining: Dict[int, int]) -> SymbolicExpr:
        imp = ZERO
        for ov in n.outvals:
            if ov.consumers or ov.id in self._output_ids:
                imp = imp + ov.nbytes_expr
        freed: Set[int] = set()
        for iv in n.invals:
            if iv.id in freed:
                continue
            if not self.count_input_frees and iv.is_materialized_input():
                continue
            if iv.id in self._output_ids:
                continue
            # does scheduling n free iv?  (n is its only remaining consumer —
            # count multiplicity: n may consume iv several times)
            mult = sum(1 for x in n.invals if x.id == iv.id)
            if remaining[iv.id] == mult:
                imp = imp - iv.nbytes_expr
                freed.add(iv.id)
        return imp

    # -- tie-break: smaller overall tensor lifetimes (paper fallback) ----------
    def _tiebreak_key(self, n: Node, orig_pos: Dict[int, int],
                      remaining: Dict[int, int]) -> Tuple:
        frees = 0
        seen_ids = set()
        for iv in n.invals:
            if iv.id in seen_ids:
                continue
            seen_ids.add(iv.id)
            mult = sum(1 for x in n.invals if x.id == iv.id)
            if remaining.get(iv.id, 0) == mult and not iv.is_materialized_input():
                frees += 1
        # prefer ops that free tensors, then ops whose results are consumed
        # soon (small distance to first consumer in original order), then
        # original program order for stability.
        next_use = min(
            (orig_pos[c.id] for ov in n.outvals for c in ov.consumers),
            default=orig_pos[n.id],
        )
        return (-frees, next_use, orig_pos[n.id])

    # -- main loop ----------------------------------------------------------------
    def schedule(self) -> ScheduleResult:
        g = self.g
        orig_pos = {n.id: i for i, n in enumerate(g.nodes)}
        # dependency counts
        deps: Dict[int, int] = {}
        for n in g.nodes:
            cnt = 0
            seen = set()
            for iv in n.invals:
                p = iv.producer
                if p is not None and p.id not in seen:
                    seen.add(p.id)
                    cnt += 1
            deps[n.id] = cnt
        consumers_of: Dict[int, List[Node]] = {}
        remaining: Dict[int, int] = {}
        for v in g.values:
            remaining[v.id] = len(v.consumers)
        ready: List[Node] = sorted(
            (n for n in g.nodes if deps[n.id] == 0), key=lambda n: orig_pos[n.id])
        order: List[Node] = []
        sym_dec = tie_dec = 0
        node_by_id = {n.id: n for n in g.nodes}
        # children map: node -> nodes depending on it
        children: Dict[int, List[Node]] = {n.id: [] for n in g.nodes}
        for n in g.nodes:
            seen = set()
            for iv in n.invals:
                p = iv.producer
                if p is not None and p.id not in seen:
                    seen.add(p.id)
                    children[p.id].append(n)

        while ready:
            # pick best by symbolic impact, tie-break by lifetime heuristic
            best = ready[0]
            best_imp = self._impact(best, remaining)
            for cand in ready[1:]:
                ci = self._impact(cand, remaining)
                c = self._compare(ci, best_imp)
                if c in (Cmp.LT, Cmp.LE):
                    # cand's impact is no worse everywhere (strictly better
                    # for LT); switching is symbolically justified — with
                    # declared dim ranges the interval fallback turns many
                    # previously UNKNOWN pairs into LT/LE/GE/GT here.
                    best, best_imp = cand, ci
                    sym_dec += 1
                elif c in (Cmp.GT, Cmp.GE):
                    # keeping the incumbent is symbolically justified
                    sym_dec += 1
                else:  # EQ (memory-neutral) / UNKNOWN -> lifetime tie-break
                    tie_dec += 1
                    if self._tiebreak_key(cand, orig_pos, remaining) < \
                       self._tiebreak_key(best, orig_pos, remaining):
                        best, best_imp = cand, ci
            ready.remove(best)
            order.append(best)
            # update refcounts
            for iv in best.invals:
                remaining[iv.id] -= 1
            for ov in best.outvals:
                remaining[ov.id] = len(ov.consumers)
            # new ready nodes
            for ch in children[best.id]:
                deps[ch.id] -= 1
                if deps[ch.id] == 0:
                    ready.append(ch)
            ready.sort(key=lambda n: orig_pos[n.id])

        g.validate_order(order)
        return ScheduleResult(order, sym_dec, tie_dec)


def schedule_graph(graph: Graph, shape_graph: Optional[ShapeGraph] = None,
                   **kw) -> ScheduleResult:
    return OpScheduler(graph, shape_graph, **kw).schedule()
