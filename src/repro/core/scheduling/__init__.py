from .memsim import MemTimeline, simulate_peak, simulate_peak_bound
from .scheduler import OpScheduler, ScheduleResult, schedule_graph

__all__ = ["MemTimeline", "simulate_peak", "simulate_peak_bound",
           "OpScheduler", "ScheduleResult", "schedule_graph"]
