from .memsim import MemTimeline, simulate_peak
from .scheduler import OpScheduler, ScheduleResult, schedule_graph

__all__ = ["MemTimeline", "simulate_peak", "OpScheduler", "ScheduleResult", "schedule_graph"]
