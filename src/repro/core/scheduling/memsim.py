"""Peak-memory simulation of a schedule: exact (concrete env) and bounded.

``simulate_peak`` replays a schedule under a concrete dim binding and
reports exact peak bytes — used to *verify* that the symbolic scheduling
decisions actually reduce peak memory (the paper validates against
precise-shape optimization results), and by benchmarks to report peak bytes
without executing anything.

``simulate_peak_bound`` replays the same liveness discipline *symbolically*:
the live set's byte count stays a ``SymbolicExpr``, and each step is bounded
with interval arithmetic over the shape graph's declared dim ranges.  The
returned ``hi`` is a **guaranteed worst-case peak** — for every env within
the declared ranges, ``simulate_peak(...).peak_bytes <= hi`` — which is what
lets a bounded-dynamic-shape deployment (TPU-style static allocation) size
its arena at compile time.  When a ``shape_graph`` is passed to
``simulate_peak`` the bound is attached to the timeline as
``peak_bound_bytes`` / ``peak_bound_lo``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..ir.graph import Graph, Node
from ..ir.loop import loop_body_of
from ..symbolic import ShapeGraph, SymbolicExpr, ZERO


@dataclass
class MemTimeline:
    peak_bytes: int
    steps: List[int] = field(default_factory=list)  # usage after each node
    base_bytes: int = 0  # inputs + consts held for the whole run
    # guaranteed bounds on peak over all envs within declared dim ranges
    # (None when no shape graph was supplied or a dim is unbounded above)
    peak_bound_bytes: Optional[int] = None
    peak_bound_lo: Optional[int] = None

    def __repr__(self) -> str:  # pragma: no cover
        bound = "" if self.peak_bound_bytes is None else \
            f", bound<={self.peak_bound_bytes}"
        return (f"MemTimeline(peak={self.peak_bytes}, base={self.base_bytes}, "
                f"n={len(self.steps)}{bound})")


def simulate_peak(graph: Graph, order: Sequence[Node], env: Dict[str, int],
                  *, count_inputs: bool = True,
                  donate_inputs: bool = False,
                  shape_graph: Optional[ShapeGraph] = None) -> MemTimeline:
    """Simulate exact memory usage of executing ``order``.

    - outputs of a node allocate at execution;
    - a value frees right after its last consumer executes (unless it is a
      graph output, which stays live to the end);
    - inputs/consts are live from the start; with ``donate_inputs`` they free
      after their last use (buffer donation).

    With ``shape_graph`` given, additionally computes the guaranteed
    worst-case peak bound over its declared dim ranges (see
    :func:`simulate_peak_bound`).
    """
    nbytes: Dict[int, int] = {}
    for v in graph.values:
        nbytes[v.id] = v.nbytes_expr.evaluate(env)

    output_ids = {v.id for v in graph.outputs}
    remaining: Dict[int, int] = {}
    pos = {n.id: i for i, n in enumerate(order)}
    for v in graph.values:
        remaining[v.id] = sum(1 for c in v.consumers if c.id in pos)

    usage = 0
    base = 0
    if count_inputs:
        for v in list(graph.inputs) + list(graph.consts):
            usage += nbytes[v.id]
            base += nbytes[v.id]

    peak = usage
    steps: List[int] = []
    live_intermediate: Dict[int, int] = {}
    # rolled loops plan against a shape graph; a throwaway default suffices
    # for exact simulation (peak exprs are evaluated at the concrete env)
    sg_loops = shape_graph if shape_graph is not None else ShapeGraph()

    for n in order:
        body = loop_body_of(n)
        if body is not None:
            # rolled loop: internal peak comes from the loop plan's event
            # replay (covers temps, both carry generations, and the kept
            # output allocations at their in-loop alloc points)
            lp = body.plan(sg_loops)
            trip = body.length_expr.evaluate(env)
            kept = [bool(ov.consumers) or ov.id in output_ids
                    for ov in n.outvals]
            extra = lp.peak_expr_for(n, kept, trip).evaluate(env)
            peak = max(peak, usage + extra)
            for ov, k in zip(n.outvals, kept):
                if k:
                    usage += nbytes[ov.id]
                    live_intermediate[ov.id] = nbytes[ov.id]
        else:
            # allocate outputs (dead outputs are transient: alloc + free
            # same step)
            transient = 0
            for ov in n.outvals:
                b = nbytes[ov.id]
                if ov.consumers or ov.id in output_ids:
                    usage += b
                    live_intermediate[ov.id] = b
                else:
                    transient += b
            peak = max(peak, usage + transient)
        # free inputs whose last consumer just ran
        seen = set()
        for iv in n.invals:
            if iv.id in seen:
                continue
            seen.add(iv.id)
            remaining[iv.id] -= sum(1 for x in n.invals if x.id == iv.id)
            if remaining[iv.id] == 0 and iv.id not in output_ids:
                if iv.is_materialized_input():
                    if donate_inputs:
                        usage -= nbytes[iv.id]
                else:
                    if iv.id in live_intermediate:
                        usage -= live_intermediate.pop(iv.id)
        steps.append(usage)

    tl = MemTimeline(peak_bytes=peak, steps=steps, base_bytes=base)
    if shape_graph is not None:
        tl.peak_bound_lo, tl.peak_bound_bytes = simulate_peak_bound(
            graph, order, shape_graph,
            count_inputs=count_inputs, donate_inputs=donate_inputs)
    return tl


def simulate_peak_bound(graph: Graph, order: Sequence[Node],
                        shape_graph: ShapeGraph,
                        *, count_inputs: bool = True,
                        donate_inputs: bool = False,
                        ) -> Tuple[Optional[int], Optional[int]]:
    """Guaranteed ``(lo, hi)`` bounds on the peak of executing ``order``.

    Mirrors :func:`simulate_peak`'s liveness discipline with a symbolic
    running-usage expression, bounding each step with interval arithmetic
    over ``shape_graph``'s declared dim ranges.  Sound both ways: for every
    env within the ranges, ``lo <= simulate_peak(...).peak_bytes <= hi``
    (``hi`` is ``None`` when some live dim has no declared upper bound).
    """
    output_ids = {v.id for v in graph.outputs}
    pos = {n.id: i for i, n in enumerate(order)}
    remaining = {v.id: sum(1 for c in v.consumers if c.id in pos)
                 for v in graph.values}
    bounds_env = shape_graph.bound_env()
    # canonicalize each value's byte expression once through the equalities
    nbytes_expr = {v.id: shape_graph.canonicalize(v.nbytes_expr)
                   for v in graph.values}

    usage = ZERO
    if count_inputs:
        for v in list(graph.inputs) + list(graph.consts):
            usage = usage + nbytes_expr[v.id]

    iv0 = usage.interval(bounds_env)
    peak_lo, peak_hi = iv0.lo, iv0.hi
    live: Dict[int, SymbolicExpr] = {}

    for n in order:
        body = loop_body_of(n)
        if body is not None:
            # rolled loop: bound the internal peak by the max of the
            # trip-count models the declared range of t admits
            lp = body.plan(shape_graph)
            kept = [bool(ov.consumers) or ov.id in output_ids
                    for ov in n.outvals]
            transient = lp.peak_bound_expr(n, kept, shape_graph)
            iv_step = (usage + transient).interval(bounds_env)
            for ov, k in zip(n.outvals, kept):
                if k:
                    usage = usage + nbytes_expr[ov.id]
                    live[ov.id] = nbytes_expr[ov.id]
        else:
            transient = ZERO
            for ov in n.outvals:
                e = nbytes_expr[ov.id]
                if ov.consumers or ov.id in output_ids:
                    usage = usage + e
                    live[ov.id] = e
                else:
                    transient = transient + e
            iv_step = (usage + transient).interval(bounds_env)
        # peak = max over steps, bounded per side (None = unbounded above;
        # a None step lower bound cannot happen for sums of dims >= 0)
        if iv_step.lo is not None and (peak_lo is None or iv_step.lo > peak_lo):
            peak_lo = iv_step.lo
        if peak_hi is not None:
            peak_hi = None if iv_step.hi is None else max(peak_hi, iv_step.hi)
        seen = set()
        for ivv in n.invals:
            if ivv.id in seen:
                continue
            seen.add(ivv.id)
            remaining[ivv.id] -= sum(1 for x in n.invals if x.id == ivv.id)
            if remaining[ivv.id] == 0 and ivv.id not in output_ids:
                if ivv.is_materialized_input():
                    if donate_inputs:
                        usage = usage - nbytes_expr[ivv.id]
                else:
                    if ivv.id in live:
                        usage = usage - live.pop(ivv.id)

    return peak_lo, peak_hi
