"""Exact peak-memory simulation of a schedule under a concrete dim binding.

Used to *verify* that the symbolic scheduling decisions actually reduce peak
memory (the paper validates against precise-shape optimization results), and
by benchmarks to report peak bytes without executing anything.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from ..ir.graph import Graph, Node


@dataclass
class MemTimeline:
    peak_bytes: int
    steps: List[int] = field(default_factory=list)  # usage after each node
    base_bytes: int = 0  # inputs + consts held for the whole run

    def __repr__(self) -> str:  # pragma: no cover
        return f"MemTimeline(peak={self.peak_bytes}, base={self.base_bytes}, n={len(self.steps)})"


def simulate_peak(graph: Graph, order: Sequence[Node], env: Dict[str, int],
                  *, count_inputs: bool = True,
                  donate_inputs: bool = False) -> MemTimeline:
    """Simulate exact memory usage of executing ``order``.

    - outputs of a node allocate at execution;
    - a value frees right after its last consumer executes (unless it is a
      graph output, which stays live to the end);
    - inputs/consts are live from the start; with ``donate_inputs`` they free
      after their last use (buffer donation).
    """
    nbytes: Dict[int, int] = {}
    for v in graph.values:
        nbytes[v.id] = v.nbytes_expr.evaluate(env)

    output_ids = {v.id for v in graph.outputs}
    remaining: Dict[int, int] = {}
    pos = {n.id: i for i, n in enumerate(order)}
    for v in graph.values:
        remaining[v.id] = sum(1 for c in v.consumers if c.id in pos)

    usage = 0
    base = 0
    if count_inputs:
        for v in list(graph.inputs) + list(graph.consts):
            usage += nbytes[v.id]
            base += nbytes[v.id]

    peak = usage
    steps: List[int] = []
    live_intermediate: Dict[int, int] = {}

    for n in order:
        # allocate outputs (dead outputs are transient: alloc + free same step)
        transient = 0
        for ov in n.outvals:
            b = nbytes[ov.id]
            if ov.consumers or ov.id in output_ids:
                usage += b
                live_intermediate[ov.id] = b
            else:
                transient += b
        peak = max(peak, usage + transient)
        # free inputs whose last consumer just ran
        seen = set()
        for iv in n.invals:
            if iv.id in seen:
                continue
            seen.add(iv.id)
            remaining[iv.id] -= sum(1 for x in n.invals if x.id == iv.id)
            if remaining[iv.id] == 0 and iv.id not in output_ids:
                if iv.is_materialized_input():
                    if donate_inputs:
                        usage -= nbytes[iv.id]
                else:
                    if iv.id in live_intermediate:
                        usage -= live_intermediate.pop(iv.id)
        steps.append(usage)

    return MemTimeline(peak_bytes=peak, steps=steps, base_bytes=base)
