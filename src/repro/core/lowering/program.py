"""The lowered executable artifact: a flat ``Program`` of typed instructions.

``lower_plan`` (see :mod:`.lower`) compiles each (schedule, remat plan,
arena plan) triple into a :class:`Program` — the runtime analogue of
Relax's VM executable and SoD²'s pre-derived dynamic decisions: every
decision the compile half *can* fix is burned into the instruction
stream, so the per-call work left is binding primitives.

* value ids are renumbered to **dense registers** (list indices, not
  dict probes);
* buffer frees happen at statically-known death points
  (:class:`FreeSlot` / :class:`Donate` instructions) instead of runtime
  refcounting;
* the evict check and regeneration guards exist only as explicit
  :class:`MaybeEvict` / :class:`Regen` instructions, emitted solely when
  the compile-time interval bounds cannot rule eviction out;
* regeneration subgraphs are lowered inline as register-addressed
  sub-programs (:class:`RegenProgram`, exported by
  ``repro.core.remat.export.export_regen_programs``);
* every symbolic quantity (buffer sizes, evict thresholds, recompute
  FLOPs, arena slot sizes/offsets) is attached as a precompiled
  expression, and :meth:`Program.resolve` evaluates them all for one dim
  binding in a single pass — including a replay of the static alloc/free
  sequence that precomputes the call's entire :class:`MemoryStats` when
  eviction is provably off the table for that env.

The instruction set:

========== =================================================================
BindArg     place a caller input / trace constant into its register
Compute     bind one primitive: gather input registers, store outputs
MaybeEvict  the paper's ``Remat::EvictOp`` — ensure the op's output bytes
            fit the limit, evicting victims chosen by the runtime policy
Regen       the paper's ``Remat::RegenerateOp`` guard — rematerialize the
            listed registers (reload or sub-program recompute) if evicted
FreeSlot    release a dead intermediate's buffer (statically placed)
Donate      release a dead caller buffer (only under ``donate_inputs``)
Return      gather the output registers
========== =================================================================
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

from ..executor.memory import MemoryManager, MemoryStats
from ..ir.graph import Graph, Node
from ..ir.trace import refine_params
from ..memplan.arena import ArenaAllocator
from ..remat.planner import ExecutionPlan
from ..symbolic.expr import SymbolicExpr

# instruction opcodes (small ints: the VM dispatches on them)
OP_BIND_ARG = 0
OP_COMPUTE = 1
OP_MAYBE_EVICT = 2
OP_REGEN = 3
OP_FREE_SLOT = 4
OP_DONATE = 5
OP_RETURN = 6
OP_LOOP = 7
OP_BIND_DIM = 8


@dataclass(frozen=True)
class BindArg:
    """Place flat input ``index`` (or a trace constant) into ``reg``."""
    reg: int
    index: int                 # flat-input position; -1 for consts
    kind: str                  # 'input' | 'const'
    const: Any                 # the constant array (kind='const' only)
    vid: int                   # original value id (memory accounting key)
    op: int = OP_BIND_ARG


@dataclass(frozen=True)
class Compute:
    """Bind one primitive over input registers, store selected outputs."""
    cidx: int                  # index into resolved params / ensure tables
    node: Node
    prim: Any
    multi: bool                # prim.multiple_results
    dim_as_value: bool         # shape-poly helper: emit params['dim'] directly
    in_regs: Tuple[int, ...]
    # (output position, destination register) for outputs that are kept
    # (consumed later or returned); unkept outputs are simply dropped
    store: Tuple[Tuple[int, int], ...]
    step: int                  # schedule position (victim scoring distance)
    # value-dependent bounded ops only: registers in ``store`` whose
    # accounting alloc is deferred to the following BindDim (the padded
    # payload — its tight size is only known after measuring), and
    # outputs stored for the BindDim to read but never accounted (the
    # i32 count scalar when nothing downstream consumes it)
    defer_regs: Tuple[int, ...] = ()
    extra_store: Tuple[Tuple[int, int], ...] = ()
    op: int = OP_COMPUTE


@dataclass(frozen=True)
class MaybeEvict:
    """Ensure the next Compute's output bytes fit the memory limit.

    Emitted only when lowering cannot prove eviction impossible (no
    limit, or guaranteed peak <= limit).  ``pinned`` are the value ids
    the in-flight op needs live (its inputs + outputs)."""
    cidx: int
    step: int
    pinned: frozenset
    op: int = OP_MAYBE_EVICT


@dataclass(frozen=True)
class Regen:
    """Rematerialize ``regs`` (reload or recompute) if they were evicted.

    Emitted before a Compute only for inputs that are remat candidates —
    the only values an eviction can ever drop."""
    regs: Tuple[int, ...]
    step: int
    pinned: frozenset
    op: int = OP_REGEN


@dataclass(frozen=True)
class FreeSlot:
    """Release a dead intermediate at its statically-known death point."""
    reg: int
    vid: int
    op: int = OP_FREE_SLOT


@dataclass(frozen=True)
class Donate:
    """Release a dead caller buffer (input/const) under ``donate_inputs``.

    ``counted`` mirrors ``count_inputs``: counted buffers leave through
    the memory manager, uncounted ones only release their arena slot."""
    reg: int
    vid: int
    counted: bool
    op: int = OP_DONATE


@dataclass(frozen=True)
class Return:
    """Gather the output registers (rematerializing evicted ones)."""
    regs: Tuple[int, ...]
    op: int = OP_RETURN


@dataclass(frozen=True)
class BindDim:
    """Publish a just-measured bounded dim into the call env (mid-call).

    Emitted immediately after the Compute that *introduces* bounded dim
    ``name`` (``ir.dynamism``): read the i32 count from ``count_reg``,
    clamp it to the cap evaluated at the current env (chained introducers
    can match padding rows, so the raw count may exceed a chained cap),
    rebind ``name`` in the per-call env, refresh the byte sizes of every
    bound-dependent register, and only *then* run the deferred accounting
    alloc of the padded payload (``alloc_store``) — so the arena records
    the tight size and every later fit/free/peak sees it.  With
    ``drop_count`` the count scalar's register is nulled after reading
    (nothing downstream consumes it)."""
    name: str
    cap_expr: SymbolicExpr
    count_reg: int
    alloc_store: Tuple[Tuple[int, int], ...]   # deferred (out pos, reg)
    drop_count: bool
    step: int
    op: int = OP_BIND_DIM


@dataclass(frozen=True)
class Loop:
    """Run a rolled ``scan`` loop: one instruction for the whole trip.

    The body is a lowered sub-:class:`Program` executed once per
    iteration with registers rebound (carries from the previous
    iteration's outputs, ``xs`` slices by index); ``lidx`` indexes the
    owning Program's ``loops`` table.  ``store`` routes the loop's kept
    outer outputs (final carries, stacked ``ys``); ``pinned`` mirrors
    ``MaybeEvict.pinned`` for the hoisted evict check."""
    lidx: int
    in_regs: Tuple[int, ...]
    store: Tuple[Tuple[int, int], ...]
    step: int
    pinned: frozenset
    op: int = OP_LOOP


@dataclass
class LoopInfo:
    """Compile-time half of one rolled loop inside a Program."""
    node: Node                 # the outer loop node
    body: Any                  # ir.loop.LoopBody
    lp: Any                    # ir.loop.LoopPlanInfo (schedule + events)
    body_program: "Program"    # the body lowered once (O(body) size)
    kept: Tuple[bool, ...]     # per outer output: consumed or returned


@dataclass
class ResolvedLoop:
    """One rolled loop realized for a concrete env."""
    trip: int                               # trip count t at this env
    rbody: ResolvedProgram                  # body program resolve (cached)
    extra_bytes: int                        # exact internal peak delta
    sizes: Dict[int, int]                   # body value id -> bytes
    outer_y: List[Tuple[int, int]]          # kept stacked ys: (vid, bytes)
    outer_carry: List[Optional[Tuple[int, int]]]   # per carry slot


@dataclass(frozen=True)
class RegenStep:
    """One lowered node of a regeneration sub-program.

    ``in_refs`` entries are ``(is_temp, index)``: a sub-program temp
    produced by an earlier step, or a main-program register (materialized
    recursively).  ``writes`` routes outputs into temp slots."""
    node: Node
    prim: Any
    multi: bool
    dim_as_value: bool
    params_cidx: int           # the node's main-program params entry
    in_refs: Tuple[Tuple[bool, int], ...]
    writes: Tuple[Tuple[int, int], ...]


@dataclass(frozen=True)
class RegenProgram:
    """A remat candidate's recompute subgraph, lowered over registers."""
    target_reg: int
    target_vid: int
    source_regs: Tuple[int, ...]
    n_temps: int
    steps: Tuple[RegenStep, ...]
    target_temp: int
    flops_expr: SymbolicExpr


@dataclass
class ResolvedProgram:
    """A :class:`Program` realized for one concrete dim binding.

    Everything symbolic is now a plain int or dict: per-register byte
    sizes, per-Compute ensure thresholds and refined params, per-regen
    FLOPs, the resolved arena (with concrete per-value offsets), and —
    when ``fast_ok`` — the complete :class:`MemoryStats` of a run, so
    the hot path copies a template instead of accounting per op."""

    env: Dict[str, int]
    nbytes: List[int]                       # per register
    ensure_bytes: List[int]                 # per Compute (cidx)
    params: List[Dict[str, Any]]            # per Compute (cidx)
    regen_flops: Dict[int, int]             # target reg -> FLOPs at env
    arena: Optional[Any] = None             # memplan ResolvedArena
    value_offsets: Dict[int, int] = field(default_factory=dict)
    # replay results: the exact free-run stats of this env's call
    stats_template: Optional[MemoryStats] = None
    peak_bytes: int = 0
    # True when no MaybeEvict can fire at this env (no limit, or the
    # replayed peak fits it): the VM may run the fast stream
    fast_ok: bool = True
    # per rolled loop (index = Loop.lidx): trip count, body resolve,
    # exact internal peak delta, and the accounting size tables
    loops: List[ResolvedLoop] = field(default_factory=list)


@dataclass
class Program:
    """Flat lowered executable for one ExecutionPlan (see module doc)."""

    plan: ExecutionPlan
    graph: Graph
    n_regs: int
    reg_of: Dict[int, int]                  # value id -> register
    vid_of: List[int]                       # register -> value id
    nbytes_exprs: List[SymbolicExpr]        # per register
    instructions: List[Any]                 # full stream (evict path included)
    fast_instructions: List[Any]            # stream without MaybeEvict/Regen
    computes: List[Compute]
    # per Compute: the node's params when they contain nothing symbolic
    # (used as-is), else None -> refined per env in resolve()
    static_params: List[Optional[Dict[str, Any]]]
    regen: Dict[int, RegenProgram]          # target reg -> sub-program
    out_regs: Tuple[int, ...]
    death_step: List[int]                   # per register; -1 = never freed
    candidate_regs: Tuple[int, ...]         # remat candidates, producer order
    has_evict_path: bool
    memory_limit: Optional[int]
    donate_inputs: bool
    count_inputs: bool
    # rolled loops (index = Loop.lidx); each body is itself a Program,
    # lowered once — the stream stays O(body), not O(t·body)
    loops: List[LoopInfo] = field(default_factory=list)
    # bounded dim name -> registers whose byte size mentions it (refreshed
    # by the BindDim that publishes the measured value)
    bound_dep_regs: Dict[str, Tuple[int, ...]] = field(default_factory=dict)

    def __post_init__(self):
        self._resolve_cache: Dict[Tuple, ResolvedProgram] = {}

    @property
    def has_bound_dims(self) -> bool:
        return bool(self.graph.bound_dims)

    @property
    def n_instructions(self) -> int:
        return len(self.instructions)

    def counts(self) -> Dict[str, int]:
        """Instruction histogram (docs/tests introspection)."""
        names = {OP_BIND_ARG: "BindArg", OP_COMPUTE: "Compute",
                 OP_MAYBE_EVICT: "MaybeEvict", OP_REGEN: "Regen",
                 OP_FREE_SLOT: "FreeSlot", OP_DONATE: "Donate",
                 OP_RETURN: "Return", OP_LOOP: "Loop",
                 OP_BIND_DIM: "BindDim"}
        out = {name: 0 for name in names.values()}
        for inst in self.instructions:
            out[names[inst.op]] += 1
        return out

    # ---------------------------------------------------------------- resolve
    def resolve(self, env: Dict[str, int],
                size_cache: Optional[Dict[Tuple, Dict[int, int]]] = None,
                params_cache: Optional[
                    Dict[Tuple, Dict[int, Dict[str, Any]]]] = None,
                ) -> ResolvedProgram:
        """Evaluate every attached expression for ``env`` in one pass.

        Cached per env (training repeats shapes).  ``size_cache`` /
        ``params_cache`` are the same shared per-env dicts the reference
        interpreter uses (keyed by graph uid + env, then value/node id),
        so bucketed dispatch re-derives nothing when plans swap."""
        key = (self.graph.uid,) + tuple(sorted(env.items()))
        out = self._resolve_cache.get(key)
        if out is not None:
            return out
        if len(self._resolve_cache) > 64:
            self._resolve_cache.clear()

        # value-dependent bounded dims absent from the env evaluate at
        # their cap.  Completion is deterministic in the declared env, so
        # the declared-env cache key stays sound: cached sizes are cap
        # sizes, and measured values live only in per-call overlays (the
        # VM's nbytes_run / the interpreter's fresh evaluations) — two
        # calls with equal declared dims but different measured bounds
        # can never alias each other's resolve.
        if self.graph.bound_dims:
            from ..ir.dynamism import complete_bound_env
            env = complete_bound_env(self.graph, env)

        sizes: Dict[int, int] = {}
        if size_cache is not None:
            if len(size_cache) > 64:
                size_cache.clear()
            sizes = size_cache.setdefault(key, {})
        nbytes = [0] * self.n_regs
        for reg, expr in enumerate(self.nbytes_exprs):
            vid = self.vid_of[reg]
            b = sizes.get(vid)
            if b is None:
                b = expr.evaluate(env)
                sizes[vid] = b
            nbytes[reg] = b

        refined: Dict[int, Dict[str, Any]] = {}
        if params_cache is not None:
            if len(params_cache) > 64:
                params_cache.clear()
            refined = params_cache.setdefault(key, {})
        overrides = self.plan.kernel_overrides if self.plan is not None else {}
        params: List[Dict[str, Any]] = []
        for comp, static in zip(self.computes, self.static_params):
            if static is not None:
                params.append(static)
                continue
            ov = overrides.get(comp.node.id)
            if ov is not None:
                # kernel-variant override on symbolic params: resolve
                # outside the shared cache — other buckets' programs key
                # the same (graph uid, env) but merge different choices
                params.append({**refine_params(comp.node.params, env), **ov})
                continue
            p = refined.get(comp.node.id)
            if p is None:
                p = refine_params(comp.node.params, env)
                refined[comp.node.id] = p
            params.append(p)

        ensure = [sum(nbytes[r] for _oi, r in comp.store)
                  for comp in self.computes]
        regen_flops = {reg: max(1, rp.flops_expr.evaluate(env))
                       for reg, rp in self.regen.items()}

        arena = offsets = None
        if self.plan.arena_plan is not None:
            arena = self.plan.arena_plan.resolve(env)
            offsets = arena.offsets

        # rolled loops: resolve each body sub-program (its own cache entry,
        # keyed by the body graph's uid) and evaluate the loop's trip count,
        # exact internal peak delta, and accounting size tables
        rloops: List[ResolvedLoop] = []
        for info in self.loops:
            trip = info.body.length_expr.evaluate(env)
            rbody = info.body_program.resolve(env, size_cache, params_cache)
            bsizes = {bvid: e.evaluate(env)
                      for bvid, e in info.lp.sizes.items()}
            nk = info.body.num_carry
            node = info.node
            outer_y = [(ov.id, nbytes[self.reg_of[ov.id]])
                       for ov, k in zip(node.outvals[nk:], info.kept[nk:])
                       if k]
            outer_carry = [(ov.id, nbytes[self.reg_of[ov.id]]) if k else None
                           for ov, k in zip(node.outvals[:nk],
                                            info.kept[:nk])]
            extra = info.lp.peak_expr_for(node, info.kept,
                                          trip).evaluate(env)
            rloops.append(ResolvedLoop(trip=trip, rbody=rbody,
                                       extra_bytes=extra, sizes=bsizes,
                                       outer_y=outer_y,
                                       outer_carry=outer_carry))

        out = ResolvedProgram(env=dict(env), nbytes=nbytes,
                              ensure_bytes=ensure, params=params,
                              regen_flops=regen_flops, arena=arena,
                              value_offsets=offsets or {}, loops=rloops)
        out.stats_template, out.peak_bytes = self._replay_stats(
            nbytes, arena, rloops)
        # bound programs measure sizes mid-call, so the precomputed stats
        # template (cap sizes) is not this call's truth: force the
        # dynamic path
        out.fast_ok = ((self.memory_limit is None
                        or out.peak_bytes <= self.memory_limit)
                       and not self.graph.bound_dims)
        self._resolve_cache[key] = out
        return out

    def _replay_stats(self, nbytes: List[int], arena_resolved,
                      rloops: List[ResolvedLoop] = ()) -> Tuple[MemoryStats, int]:
        """Replay the static alloc/free sequence once for this env.

        The fast stream's memory traffic is fully determined by the env
        (no eviction can reorder it), so the whole run's MemoryStats —
        device peak, arena size, reuse ratio, fragmentation — is a
        compile-side fact the hot path copies instead of recomputing."""
        arena = None
        if arena_resolved is not None:
            arena = ArenaAllocator(self.plan.arena_plan, arena_resolved)
        mm = MemoryManager(None, arena=arena)
        vid_of = self.vid_of
        for inst in self.fast_instructions:
            op = inst.op
            if op == OP_COMPUTE:
                for _oi, r in inst.store:
                    if r not in inst.defer_regs:
                        mm.alloc(vid_of[r], nbytes[r])
            elif op == OP_BIND_DIM:
                # the replay has no measurement: the deferred payload
                # alloc lands at whatever the resolving env said (cap for
                # a declared env, measured for a report env)
                for _oi, r in inst.alloc_store:
                    mm.alloc(vid_of[r], nbytes[r])
            elif op == OP_BIND_ARG:
                if arena is not None:
                    arena.place_external(inst.vid, nbytes[inst.reg])
                if self.count_inputs:
                    mm.alloc(inst.vid, nbytes[inst.reg])
            elif op == OP_FREE_SLOT:
                mm.free(inst.vid)
            elif op == OP_DONATE:
                if inst.counted:
                    mm.free(inst.vid)
                else:
                    mm.arena_release(inst.vid)
            elif op == OP_LOOP:
                # the shared event engine replays the loop's alloc/free
                # sequence — identical to what the interpreter and the
                # VM dynamic path drive through their MemoryManagers
                rl = rloops[inst.lidx]
                info = self.loops[inst.lidx]
                info.lp.account(mm, info.node.id, rl.trip,
                                rl.sizes.__getitem__, rl.outer_y,
                                rl.outer_carry)
        if arena is not None:
            arena.write_stats(mm.stats)
        return mm.stats, mm.stats.device_peak

    def stats_for(self, resolved: ResolvedProgram) -> MemoryStats:
        """A fresh per-call copy of the precomputed stats template."""
        return replace(resolved.stats_template,
                       measured_dims=dict(
                           resolved.stats_template.measured_dims))
