"""ExecutionPlan -> Program compilation (the lowering pass).

One linear walk over the scheduled order turns every per-call decision
the ``PlanInterpreter`` re-derives op-by-op into static instruction
structure:

* **registers** — value ids renumbered densely in first-store order
  (inputs, consts, then scheduled outputs), so the VM indexes lists;
* **death points** — each value's last consumer position is known from
  the schedule, so frees become ``FreeSlot``/``Donate`` instructions
  instead of per-op refcount bookkeeping;
* **evict/regen guards** — ``MaybeEvict``/``Regen`` instructions are
  emitted only when eviction is actually possible: there is a memory
  limit, and the guaranteed worst-case peak (interval bounds over the
  declared dim ranges) does not already prove every in-range env fits
  under it.  With no limit — or a proven-safe one — the stream contains
  no runtime remat machinery at all;
* **regen sub-programs** — candidates' recompute subgraphs are lowered
  inline by ``repro.core.remat.export.export_regen_programs``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from ..ir.graph import Value
from ..ir.loop import loop_body_of
from ..ir.trace import _contains_symbolic
from ..remat.export import export_regen_programs
from ..remat.planner import ExecutionPlan
from .program import (BindArg, BindDim, Compute, Donate, FreeSlot, Loop,
                      LoopInfo, MaybeEvict, Program, Regen, Return)


def lower_plan(plan: ExecutionPlan, *,
               memory_limit: Optional[int] = None,
               donate_inputs: bool = False,
               count_inputs: bool = True,
               peak_bound_bytes: Optional[int] = None) -> Program:
    """Compile ``plan`` into a flat :class:`Program`.

    ``peak_bound_bytes`` is the guaranteed worst-case free-run peak over
    the declared dim ranges (from ``simulate_peak_bound``); when it is
    known and ``<= memory_limit``, eviction is provably impossible and
    the evict path is not emitted.
    """
    g = plan.graph
    output_ids = {v.id for v in g.outputs}

    reg_of: Dict[int, int] = {}
    vid_of: List[int] = []
    nbytes_exprs = []

    def new_reg(v: Value) -> int:
        r = reg_of.get(v.id)
        if r is None:
            r = len(vid_of)
            reg_of[v.id] = r
            vid_of.append(v.id)
            nbytes_exprs.append(v.nbytes_expr)
        return r

    # eviction is possible only under a limit the bounds cannot clear
    has_evict_path = memory_limit is not None and (
        peak_bound_bytes is None or peak_bound_bytes > memory_limit)

    instructions: List[Any] = []
    for i, v in enumerate(g.inputs):
        instructions.append(BindArg(reg=new_reg(v), index=i, kind="input",
                                    const=None, vid=v.id))
    for v in g.consts:
        instructions.append(BindArg(reg=new_reg(v), index=-1, kind="const",
                                    const=v.const_val, vid=v.id))

    # death point = last consumer position in the scheduled order
    death_pos: Dict[int, int] = {
        vid: uses[-1] for vid, uses in plan.use_positions.items() if uses}

    computes: List[Compute] = []
    static_params: List[Optional[Dict[str, Any]]] = []
    params_cidx_of: Dict[int, int] = {}
    loops: List[LoopInfo] = []
    for step, node in enumerate(plan.order):
        body = loop_body_of(node)
        pinned = frozenset(
            [iv.id for iv in node.invals] + [ov.id for ov in node.outvals])
        if has_evict_path:
            cand_in = tuple(dict.fromkeys(
                reg_of[iv.id] for iv in node.invals
                if iv.id in plan.candidates))
            if cand_in:
                instructions.append(Regen(regs=cand_in, step=step,
                                          pinned=pinned))
            if body is None:
                # a rolled loop does its own hoisted ensure (the resolved
                # internal peak delta) inside the Loop handler; plain
                # computes get the MaybeEvict guard here
                instructions.append(MaybeEvict(cidx=len(computes), step=step,
                                               pinned=pinned))
        store = tuple((oi, new_reg(ov)) for oi, ov in enumerate(node.outvals)
                      if ov.consumers or ov.id in output_ids)
        if body is not None:
            # rolled loop: lower the traced body ONCE as a sub-Program —
            # the outer stream stays O(body) regardless of the trip count
            lp = body.plan(plan.shape_graph)
            body_plan = ExecutionPlan(graph=body.graph, order=list(lp.order),
                                      shape_graph=plan.shape_graph,
                                      candidates={})
            body_program = lower_plan(body_plan, memory_limit=None,
                                      donate_inputs=False, count_inputs=True)
            kept = tuple(bool(ov.consumers) or ov.id in output_ids
                         for ov in node.outvals)
            instructions.append(Loop(
                lidx=len(loops),
                in_regs=tuple(reg_of[iv.id] for iv in node.invals),
                store=store, step=step, pinned=pinned))
            loops.append(LoopInfo(node=node, body=body, lp=lp,
                                  body_program=body_program, kept=kept))
        else:
            cidx = len(computes)
            intro = g.bound_intros.get(node.id)
            defer_regs: Tuple[int, ...] = ()
            extra_store: Tuple[Tuple[int, int], ...] = ()
            if intro is not None:
                # the padded payload's accounting alloc moves to the
                # BindDim below (its tight size needs the measured count);
                # the count scalar must reach a register either way
                defer_regs = tuple(r for oi, r in store
                                   if oi == intro.padded_out)
                count_reg = new_reg(node.outvals[intro.count_out])
                count_kept = any(oi == intro.count_out for oi, _r in store)
                if not count_kept:
                    extra_store = ((intro.count_out, count_reg),)
            comp = Compute(cidx=cidx, node=node, prim=node.prim,
                           multi=bool(node.prim is not None
                                      and node.prim.multiple_results),
                           dim_as_value=node.prim_name == "dim_as_value",
                           in_regs=tuple(reg_of[iv.id] for iv in node.invals),
                           store=store, step=step, defer_regs=defer_regs,
                           extra_store=extra_store)
            instructions.append(comp)
            computes.append(comp)
            # kernel-variant selection bakes its param overrides here, so
            # the VM hot path replays the chosen configuration with no
            # shape branch; the shared ``node.params`` stay untouched
            # (other buckets' plans merge their own choices)
            ov = plan.kernel_overrides.get(node.id)
            params = node.params if ov is None else {**node.params, **ov}
            static_params.append(
                None if _contains_symbolic(params) else params)
            params_cidx_of[node.id] = cidx
            if intro is not None:
                instructions.append(BindDim(
                    name=intro.name, cap_expr=intro.cap, count_reg=count_reg,
                    alloc_store=tuple((oi, r) for oi, r in store
                                      if r in defer_regs),
                    drop_count=not count_kept, step=step))

        # frees, in the interpreter's first-occurrence order
        seen = set()
        for iv in node.invals:
            if iv.id in seen:
                continue
            seen.add(iv.id)
            if death_pos.get(iv.id) != step or iv.id in output_ids:
                continue
            if iv.is_materialized_input():
                if donate_inputs:
                    instructions.append(Donate(reg=reg_of[iv.id], vid=iv.id,
                                               counted=count_inputs))
            else:
                instructions.append(FreeSlot(reg=reg_of[iv.id], vid=iv.id))

    out_regs = tuple(reg_of[v.id] for v in g.outputs)
    instructions.append(Return(regs=out_regs))

    regen = {}
    candidate_regs: Tuple[int, ...] = ()
    if has_evict_path:
        regen = export_regen_programs(plan, reg_of, params_cidx_of)
        # first-store order (the interpreter iterates its storage dict,
        # whose order additionally mutates on reload/recompute reinsertion
        # — so on *exact* victim-score ties after remat churn the two
        # executors may evict different victims; outputs stay identical,
        # only eviction counters can differ)
        candidate_regs = tuple(sorted(
            (reg_of[vid] for vid in plan.candidates if vid in reg_of)))

    death_step = [-1] * len(vid_of)
    for vid, pos in death_pos.items():
        r = reg_of.get(vid)
        if r is not None:
            death_step[r] = pos

    fast = [inst for inst in instructions
            if inst.op not in (Regen.op, MaybeEvict.op)]

    # bounded dim -> every register whose byte size mentions it; the
    # BindDim publishing that dim refreshes exactly these sizes
    bound_dep_regs: Dict[str, Tuple[int, ...]] = {}
    if g.bound_dims:
        dep_lists: Dict[str, List[int]] = {name: [] for name in g.bound_dims}
        for r, expr in enumerate(nbytes_exprs):
            for name in expr.free_vars() & set(g.bound_dims):
                dep_lists[name].append(r)
        bound_dep_regs = {name: tuple(rs) for name, rs in dep_lists.items()}

    return Program(plan=plan, graph=g, n_regs=len(vid_of), reg_of=reg_of,
                   vid_of=vid_of, nbytes_exprs=nbytes_exprs,
                   instructions=instructions, fast_instructions=fast,
                   computes=computes, static_params=static_params,
                   regen=regen, out_regs=out_regs, death_step=death_step,
                   candidate_regs=candidate_regs,
                   has_evict_path=has_evict_path,
                   memory_limit=memory_limit, donate_inputs=donate_inputs,
                   count_inputs=count_inputs, loops=loops,
                   bound_dep_regs=bound_dep_regs)
