"""Plan -> Program lowering (the compilation-runtime split, finished).

Compiles each (schedule, remat plan, arena plan) triple into a flat
:class:`Program` of typed instructions over dense registers — the
executable artifact the slim :class:`~repro.core.executor.vm.ProgramVM`
runs, in the spirit of Relax's VM executable and SoD²'s pre-derived
dynamic decisions.  ``Program.resolve(env)`` realizes every attached
symbolic expression (sizes, params, slot offsets, FLOPs) for one dim
binding in a single pass.
"""
from .lower import lower_plan
from .program import (BindArg, Compute, Donate, FreeSlot, MaybeEvict,
                      Program, Regen, RegenProgram, RegenStep,
                      ResolvedProgram, Return)

__all__ = [
    "lower_plan", "Program", "ResolvedProgram",
    "BindArg", "Compute", "MaybeEvict", "Regen", "FreeSlot", "Donate",
    "Return", "RegenProgram", "RegenStep",
]
