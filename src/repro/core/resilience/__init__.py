"""Fault-tolerant serving runtime: injection, degradation, quarantine.

Three coordinated layers over the serving path (see ``docs/robustness.md``):

* :mod:`.faults` — deterministic, seeded fault injection.  A
  :class:`FaultPlan` schedules arena-allocation failures at step *k*,
  transient kernel failures, background-specialization compile
  exceptions/timeouts, regen/offload failures, and malformed request
  envs.  Installed via ``optimize(..., fault_plan=...)`` or the
  ``fn.inject_faults(plan)`` context manager; when absent the hot path
  pays exactly one attribute load + ``is None`` test (the same
  discipline as telemetry's disabled path).
* :mod:`.degrade` — the graceful degradation ladder on runtime memory
  pressure: the executor's existing eviction runs first (inside
  ``MemoryManager.ensure``); a call that still fails retries on the
  remat-heavier whole-range fallback plan with bounded retries +
  exponential backoff; exhaustion raises a structured
  :class:`RequestFailed`.  Every rung lands as a
  :class:`DegradationEvent` in the DecisionLog/telemetry and the
  Prometheus export.
* :mod:`.quarantine` — a per-bucket circuit breaker for background
  specialization: compile failures and timeouts open the breaker
  (open → backoff → half-open re-probe) while the whole-range fallback
  keeps serving bitwise-identical results; a successful re-probe closes
  it and the specialized plan swaps back in.
"""
from .degrade import (DegradationEvent, RequestFailed, RequestRejected,
                      ResilienceConfig, ResilienceController, RetryPolicy)
from .faults import (FAULT_KINDS, CompileFault, CompileTimeout, FaultError,
                     FaultPlan, FaultPlanRef, FaultSpec, FiredFault,
                     InjectedAllocFailure, OffloadFailure, RegenFailure,
                     TransientKernelError)
from .quarantine import BreakerConfig, BucketQuarantined, CircuitBreaker

__all__ = [
    "FaultPlan", "FaultSpec", "FaultPlanRef", "FiredFault", "FAULT_KINDS",
    "FaultError", "TransientKernelError", "InjectedAllocFailure",
    "RegenFailure", "OffloadFailure", "CompileFault", "CompileTimeout",
    "RetryPolicy", "ResilienceConfig", "ResilienceController",
    "DegradationEvent", "RequestFailed", "RequestRejected",
    "BreakerConfig", "CircuitBreaker", "BucketQuarantined",
]
