"""Per-bucket circuit breaker for background specialization.

A bucket whose specialization compile fails (or times out) must not be
retried on every miss — that burns a core re-running a deterministic
failure — nor abandoned forever — a transient failure (OOM on the
compile host, a flaky dependency) would permanently cost the bucket its
specialized plan.  The breaker implements the standard three states:

* **closed** — healthy; compiles proceed normally.
* **open** — ``failure_threshold`` consecutive failures tripped it; no
  compile is attempted until the backoff deadline.  The whole-range
  fallback keeps serving the bucket's traffic (bitwise-identical
  results — it is the plan a bucket-less deployment would run).
* **half-open** — the backoff elapsed; exactly one probe compile is
  allowed through.  Success closes the breaker (the specialized plan
  swaps in); failure re-opens it with the backoff doubled (capped).

``allow(key)`` is the single gate: it performs the open → half-open
transition on its own clock and returns whether a compile may start
now.  The clock is injectable so tests drive transitions
deterministically.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

BucketKey = Tuple[int, ...]


class BucketQuarantined(RuntimeError):
    """A synchronous touch hit a quarantined bucket (breaker open)."""

    def __init__(self, key: BucketKey, cause: Optional[BaseException],
                 retry_in_s: float):
        super().__init__(
            f"bucket {key} is quarantined after a specialization failure "
            f"({cause!r}); re-probe in {retry_in_s:.3f}s")
        self.key = key
        self.cause = cause
        self.retry_in_s = retry_in_s


@dataclass
class BreakerConfig:
    failure_threshold: int = 1      # consecutive failures that trip it
    backoff_s: float = 0.05         # first quarantine window
    backoff_factor: float = 2.0     # growth per consecutive re-open
    max_backoff_s: float = 5.0

    def __post_init__(self) -> None:
        if self.failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if self.backoff_s < 0 or self.max_backoff_s < self.backoff_s:
            raise ValueError("need 0 <= backoff_s <= max_backoff_s")


class _Entry:
    __slots__ = ("state", "failures", "opens", "retry_at", "cause",
                 "probing")

    def __init__(self) -> None:
        self.state = "closed"
        self.failures = 0       # consecutive failures while closed
        self.opens = 0          # consecutive open episodes (backoff exponent)
        self.retry_at = 0.0
        self.cause: Optional[BaseException] = None
        self.probing = False    # a half-open probe is in flight


class CircuitBreaker:
    """Thread-safe per-key circuit breaker with exponential backoff."""

    def __init__(self, config: Optional[BreakerConfig] = None, *,
                 clock: Callable[[], float] = time.monotonic):
        self.config = config if config is not None else BreakerConfig()
        self.clock = clock
        self._lock = threading.Lock()
        self._entries: Dict[BucketKey, _Entry] = {}
        # bounded transition log (observability: explain(), tests)
        self.transitions: List[Dict[str, Any]] = []
        self._max_transitions = 256

    def _log(self, key: BucketKey, state: str, **detail: Any) -> None:
        self.transitions.append({"key": key, "state": state,
                                 "t": self.clock(), **detail})
        if len(self.transitions) > self._max_transitions:
            del self.transitions[:len(self.transitions)
                                 - self._max_transitions]

    # -- the gate --------------------------------------------------------------
    def allow(self, key: BucketKey) -> bool:
        """May a compile for ``key`` start now?  Performs the
        open → half-open transition when the backoff has elapsed, and
        admits exactly one probe while half-open."""
        with self._lock:
            e = self._entries.get(key)
            if e is None or e.state == "closed":
                return True
            if e.state == "open":
                if self.clock() < e.retry_at:
                    return False
                e.state = "half-open"
                e.probing = True
                self._log(key, "half-open")
                return True
            # half-open: one probe at a time
            if e.probing:
                return False
            e.probing = True
            return True

    # -- outcomes --------------------------------------------------------------
    def record_failure(self, key: BucketKey, exc: BaseException) -> None:
        with self._lock:
            e = self._entries.setdefault(key, _Entry())
            e.cause = exc
            e.probing = False
            if e.state == "closed":
                e.failures += 1
                if e.failures < self.config.failure_threshold:
                    return
            # trip (or re-trip after a failed probe): backoff grows with
            # every consecutive open episode
            backoff = min(
                self.config.backoff_s
                * (self.config.backoff_factor ** e.opens),
                self.config.max_backoff_s)
            e.opens += 1
            e.failures = 0
            e.state = "open"
            e.retry_at = self.clock() + backoff
            self._log(key, "open", backoff_s=backoff, cause=repr(exc))

    def record_success(self, key: BucketKey) -> None:
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                return
            was = e.state
            e.state = "closed"
            e.failures = 0
            e.opens = 0
            e.probing = False
            e.cause = None
            if was != "closed":
                self._log(key, "closed")

    # -- introspection ---------------------------------------------------------
    def state(self, key: BucketKey) -> str:
        with self._lock:
            e = self._entries.get(key)
            return "closed" if e is None else e.state

    def cause(self, key: BucketKey) -> Optional[BaseException]:
        with self._lock:
            e = self._entries.get(key)
            return None if e is None else e.cause

    def retry_in_s(self, key: BucketKey) -> float:
        with self._lock:
            e = self._entries.get(key)
            if e is None or e.state != "open":
                return 0.0
            return max(0.0, e.retry_at - self.clock())

    def quarantined_keys(self) -> List[BucketKey]:
        with self._lock:
            return [k for k, e in self._entries.items()
                    if e.state != "closed"]

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            by_state: Dict[str, int] = {}
            for e in self._entries.values():
                by_state[e.state] = by_state.get(e.state, 0) + 1
            return {"tracked": len(self._entries),
                    "by_state": by_state,
                    "transitions": len(self.transitions)}
