"""Deterministic fault injection for the serving runtime.

A :class:`FaultPlan` is a seeded schedule of :class:`FaultSpec`\\ s.  Each
spec names a *kind*, where it fires (call ordinal + step within the
call, or a bucket key for compile faults), and how many times.  The plan
is installed through ``optimize(..., fault_plan=...)`` or the
``DynamicShapeFunction.inject_faults`` context manager; executors see it
as a per-call :class:`CallFaults` probe object passed down ``run(...,
faults=)`` — ``None`` (the overwhelmingly common case) keeps every hot
loop on its uninstrumented branch.

Every fault that actually fires is appended to ``FaultPlan.fired`` — the
chaos suite cross-references this record against the structured
degradation events and request errors to prove *no injected fault
disappears silently*.

Fault kinds
-----------

``alloc``            the k-th device allocation of the call raises
                     :class:`InjectedAllocFailure` (a
                     ``MemoryLimitExceeded`` — the ladder treats it as
                     memory pressure the bound did not cover)
``kernel``           the k-th compute of the call raises
                     :class:`TransientKernelError` (retryable in place)
``regen``            the k-th remat restore/reload raises
                     :class:`RegenFailure`
``offload``          the k-th eviction-to-host raises
                     :class:`OffloadFailure`
``malformed-env``    the call is treated as a garbage client request:
                     rejected structurally before dispatch, no retry
``compile``          a bucket specialization raises
                     :class:`CompileFault` (quarantines the bucket)
``compile-timeout``  the compile sleeps ``delay_s`` then raises
                     :class:`CompileTimeout` (a hung compile, detected
                     and quarantined)
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..executor.memory import MemoryLimitExceeded

FAULT_KINDS = ("alloc", "kernel", "regen", "offload", "malformed-env",
               "compile", "compile-timeout")
_RUNTIME_KINDS = ("alloc", "kernel", "regen", "offload", "malformed-env")
_MEMORY_KINDS = ("alloc", "regen", "offload")


# -- exceptions ----------------------------------------------------------------

class FaultError(RuntimeError):
    """Base of every injected failure (carries the spec that fired)."""

    def __init__(self, message: str, spec: Optional["FaultSpec"] = None):
        super().__init__(message)
        self.spec = spec


class TransientKernelError(FaultError):
    """A kernel launch failed transiently; a retry may succeed."""


class InjectedAllocFailure(MemoryLimitExceeded, FaultError):
    """An allocation the guaranteed bound was supposed to cover failed.

    Subclasses :class:`MemoryLimitExceeded` so the degradation ladder
    (and any existing handler) sees exactly the memory-pressure failure
    it would see from a real allocator."""

    def __init__(self, message: str, spec: Optional["FaultSpec"] = None):
        MemoryLimitExceeded.__init__(self, message)
        self.spec = spec


class RegenFailure(FaultError):
    """Rematerialization (recompute restore or host reload) failed."""


class OffloadFailure(FaultError):
    """Eviction-to-host (D2H offload) failed."""


class CompileFault(FaultError):
    """A bucket specialization pipeline raised."""


class CompileTimeout(CompileFault):
    """A bucket specialization exceeded its compile deadline."""


_EXC_BY_KIND = {
    "alloc": InjectedAllocFailure,
    "kernel": TransientKernelError,
    "regen": RegenFailure,
    "offload": OffloadFailure,
    "compile": CompileFault,
    "compile-timeout": CompileTimeout,
}


# -- the schedule --------------------------------------------------------------

@dataclass
class FaultSpec:
    """One scheduled fault.

    ``call`` is the 0-based resilient-call ordinal the fault belongs to
    (``None``: any call).  ``step`` is the ordinal *within* the call —
    the k-th compute for ``kernel``, the k-th matching memory event for
    the memory kinds.  ``bucket`` targets compile faults at one bucket
    key (``None``: the next bucket that compiles).  ``times`` is how
    many firings the spec carries; ``delay_s`` is the injected hang of a
    ``compile-timeout``.
    """

    kind: str
    call: Optional[int] = None
    step: int = 0
    bucket: Optional[Tuple[int, ...]] = None
    times: int = 1
    delay_s: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (known: {FAULT_KINDS})")
        if self.times < 1:
            raise ValueError(f"times must be >= 1, got {self.times}")


@dataclass(frozen=True)
class FiredFault:
    """One fault that actually fired (the injection audit record)."""

    kind: str
    call: Optional[int]                # call ordinal it fired on (None: compile)
    step: int                          # step ordinal it fired at
    bucket: Optional[Tuple[int, ...]]  # bucket key (compile kinds)
    seq: int                           # firing order across the plan


class _Live:
    """A spec plus its remaining firing budget."""

    __slots__ = ("spec", "remaining")

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.remaining = spec.times


class FaultPlan:
    """A seeded, deterministic schedule of faults.

    Thread-safe: compile faults fire from the background specialize
    worker while runtime faults fire on request threads.  ``fired``
    records every firing in order; ``remaining()`` reports the budget
    still unspent (zero means the schedule is exhausted and the system
    should have fully recovered)."""

    def __init__(self, specs: Sequence[FaultSpec] = (),
                 seed: Optional[int] = None):
        self.seed = seed
        self._lock = threading.Lock()
        self._live: List[_Live] = [_Live(s) for s in specs]
        self.fired: List[FiredFault] = []

    @property
    def specs(self) -> List[FaultSpec]:
        return [l.spec for l in self._live]

    @classmethod
    def random(cls, seed: int, *, n_faults: int = 4,
               kinds: Sequence[str] = FAULT_KINDS,
               max_call: int = 8, max_step: int = 4,
               buckets: Optional[Sequence[Tuple[int, ...]]] = None,
               max_times: int = 2,
               timeout_delay_s: float = 0.02) -> "FaultPlan":
        """A reproducible random schedule: same seed, same faults."""
        rng = random.Random(seed)
        specs: List[FaultSpec] = []
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            if kind in ("compile", "compile-timeout"):
                bucket = tuple(rng.choice(list(buckets))) if buckets else None
                specs.append(FaultSpec(
                    kind=kind, bucket=bucket,
                    times=rng.randint(1, max_times),
                    delay_s=timeout_delay_s if kind == "compile-timeout"
                    else 0.0))
            elif kind == "malformed-env":
                specs.append(FaultSpec(kind=kind,
                                       call=rng.randrange(max_call)))
            else:
                specs.append(FaultSpec(kind=kind,
                                       call=rng.randrange(max_call),
                                       step=rng.randrange(max_step),
                                       times=rng.randint(1, max_times)))
        return cls(specs, seed=seed)

    # -- bookkeeping -----------------------------------------------------------
    def remaining(self) -> int:
        """Total unspent firings across every spec."""
        with self._lock:
            return sum(l.remaining for l in self._live)

    def _fire(self, live: _Live, *, call: Optional[int], step: int,
              bucket: Optional[Tuple[int, ...]]) -> FaultSpec:
        """Consume one firing (caller must hold ``self._lock``)."""
        live.remaining -= 1
        self.fired.append(FiredFault(
            kind=live.spec.kind, call=call, step=step, bucket=bucket,
            seq=len(self.fired)))
        return live.spec

    # -- runtime faults --------------------------------------------------------
    def arm_call(self, call_idx: int) -> Optional["CallFaults"]:
        """The live runtime faults matching one call attempt.

        Returns ``None`` when nothing can fire — the executor then runs
        its completely uninstrumented path.  Re-arm per *attempt*: a
        spec spent on attempt 0 no longer matches on the retry, which is
        what lets a bounded-retry ladder actually recover."""
        with self._lock:
            matched = [l for l in self._live
                       if l.remaining > 0 and l.spec.kind in _RUNTIME_KINDS
                       and (l.spec.call is None or l.spec.call == call_idx)]
        if not matched:
            return None
        return CallFaults(self, call_idx, matched)

    # -- compile faults --------------------------------------------------------
    def check_compile(self, key: Optional[Tuple[int, ...]]) -> None:
        """Called at the top of a bucket specialization; raises the
        scheduled compile fault for ``key``, if any."""
        with self._lock:
            live = next(
                (l for l in self._live
                 if l.remaining > 0
                 and l.spec.kind in ("compile", "compile-timeout")
                 and (l.spec.bucket is None
                      or (key is not None
                          and tuple(l.spec.bucket) == tuple(key)))),
                None)
            if live is None:
                return
            spec = self._fire(live, call=None, step=0,
                              bucket=None if key is None else tuple(key))
        if spec.kind == "compile-timeout" and spec.delay_s > 0:
            time.sleep(spec.delay_s)   # a compile that hangs, then dies
        raise _EXC_BY_KIND[spec.kind](
            f"injected {spec.kind} fault for bucket {key}", spec)


class CallFaults:
    """Per-attempt fault probe an executor threads through one call.

    ``before_compute()`` runs ahead of every kernel bind;
    ``on_memory(event, vid, nbytes)`` is the :class:`MemoryManager`
    fault hook (events: ``alloc`` / ``offload`` / ``reload`` /
    ``restore``).  Counting is attempt-local, the firing budget is
    plan-global."""

    __slots__ = ("_plan", "_call", "_kernel", "_mem", "_malformed",
                 "_n_compute", "_mem_counts")

    def __init__(self, plan: FaultPlan, call_idx: int, live: List[_Live]):
        self._plan = plan
        self._call = call_idx
        self._kernel = [l for l in live if l.spec.kind == "kernel"]
        self._mem = [l for l in live if l.spec.kind in _MEMORY_KINDS]
        self._malformed = [l for l in live
                           if l.spec.kind == "malformed-env"]
        self._n_compute = 0
        self._mem_counts: Dict[str, int] = {}

    @property
    def needs_memory(self) -> bool:
        """True when a memory-kind fault is armed: the VM must take the
        dynamic stream (the fast stream performs no allocations)."""
        return bool(self._mem)

    def take_malformed(self) -> bool:
        """Consume an armed malformed-env fault (pre-dispatch)."""
        if not self._malformed:
            return False
        with self._plan._lock:
            for l in self._malformed:
                if l.remaining > 0:
                    self._plan._fire(l, call=self._call, step=0, bucket=None)
                    return True
        return False

    def before_compute(self) -> None:
        k = self._n_compute
        self._n_compute = k + 1
        for l in self._kernel:
            if l.spec.step == k:
                with self._plan._lock:
                    if l.remaining <= 0:
                        continue
                    spec = self._plan._fire(l, call=self._call, step=k,
                                            bucket=None)
                raise TransientKernelError(
                    f"injected kernel fault at call {self._call} "
                    f"compute {k}", spec)

    def on_memory(self, event: str, vid: int, nbytes: int) -> None:
        k = self._mem_counts.get(event, 0)
        self._mem_counts[event] = k + 1
        # restore and reload are both regeneration events
        kind = {"alloc": "alloc", "offload": "offload",
                "reload": "regen", "restore": "regen"}.get(event)
        if kind is None:
            return
        for l in self._mem:
            if l.spec.kind == kind and l.spec.step == k:
                with self._plan._lock:
                    if l.remaining <= 0:
                        continue
                    spec = self._plan._fire(l, call=self._call, step=k,
                                            bucket=None)
                raise _EXC_BY_KIND[kind](
                    f"injected {kind} fault at call {self._call} "
                    f"{event} #{k} (value {vid}, {nbytes} bytes)", spec)


class FaultPlanRef:
    """Shared mutable holder for the installed :class:`FaultPlan`.

    Created once per ``optimize`` and closed over by the bucket compile
    closure, so ``inject_faults`` can swap plans in and out after the
    table factory has already captured the reference."""

    __slots__ = ("plan",)

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan
