"""Graceful degradation ladder: bounded retries, backoff, structured errors.

The ladder the resilient call path walks when a request fails at
runtime (``DynamicShapeFunction._call_resilient``):

1. **evict** — already built in: ``MemoryManager.ensure`` runs the remat
   eviction policy *inside* the failing call before any exception
   escapes.  A ``MemoryLimitExceeded`` reaching the ladder means
   eviction could not free enough.
2. **retry-transient** — transient kernel / regen / offload failures
   retry the call on the *same* plan after an exponential backoff.
3. **retry-fallback** — memory-pressure failures (and quarantined or
   failed bucket compiles) retry on the remat-heavier whole-range
   fallback plan, which trades recompute for a smaller guaranteed
   arena bound and produces bitwise-identical outputs.
4. **reject** — retries exhausted: a structured :class:`RequestFailed`
   carrying the env, bucket, attempt count, final cause, and every
   :class:`DegradationEvent` recorded along the way.

Malformed requests short-circuit to ``reject-malformed`` — a client
error is not retried.

Every rung is recorded by the :class:`ResilienceController`: a bounded
event deque, monotonic counters (exported via Prometheus), and a
DecisionLog entry (kind ``degrade``) so ``explain()`` shows the failure
history next to the compile decisions.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Deque, Dict, Mapping, Optional, Tuple)

from .quarantine import BreakerConfig


@dataclass
class RetryPolicy:
    """Bounded retries with exponential backoff."""

    max_retries: int = 2
    backoff_base_s: float = 0.001
    backoff_factor: float = 2.0

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retry ``attempt + 1`` (0-based failed attempt)."""
        return self.backoff_base_s * (self.backoff_factor ** attempt)


@dataclass
class ResilienceConfig:
    """Knobs of the resilient call path (``optimize(..., resilience=)``).

    ``enforce_arena_bound=True`` turns the plan's guaranteed
    ``arena_bound_bytes`` into a runtime hard cap: an execution whose
    arena would exceed it raises ``ArenaExhausted`` (caught by the
    ladder as memory pressure) instead of silently growing past the
    guarantee.  ``compile_timeout_s`` quarantines bucket compiles that
    run longer than the deadline."""

    retry: RetryPolicy = field(default_factory=RetryPolicy)
    breaker: BreakerConfig = field(default_factory=BreakerConfig)
    enforce_arena_bound: bool = False
    compile_timeout_s: Optional[float] = None
    max_events: int = 256


@dataclass(frozen=True)
class DegradationEvent:
    """One rung of the ladder, as recorded: what degraded, why, and what
    happens next."""

    seq: int                     # resilient-call ordinal
    rung: str                    # retry-transient | retry-fallback |
    #                              reject | reject-malformed
    attempt: int                 # 0-based attempt that failed
    cause: str                   # repr of the triggering exception
    backoff_s: float = 0.0       # sleep before the retry (0 for reject)
    bucket: Optional[Tuple[int, ...]] = None


class RequestFailed(RuntimeError):
    """A request the runtime could not serve after walking the ladder.

    Structured: carries the dim binding, the bucket it dispatched to,
    how many attempts ran, the final cause, and the recorded
    degradation events — everything a serve loop needs to answer the
    client and everything an operator needs to debug."""

    def __init__(self, message: str, *,
                 env: Optional[Mapping[str, int]] = None,
                 bucket: Optional[Tuple[int, ...]] = None,
                 attempts: int = 0,
                 cause: Optional[BaseException] = None,
                 events: Tuple[DegradationEvent, ...] = ()):
        super().__init__(message)
        self.env = dict(env) if env else None
        self.bucket = bucket
        self.attempts = attempts
        self.cause = cause
        self.events = events


class RequestRejected(RequestFailed):
    """A request shed at admission (queue full, deadline passed, group
    aged out) — it never reached an executor."""

    def __init__(self, message: str, *, reason: str = "shed", **kw: Any):
        super().__init__(message, **kw)
        self.reason = reason


class ResilienceController:
    """Per-function resilience state: ladder policy, fault plan, events.

    Attached by ``optimize(..., resilience=/fault_plan=)`` or
    ``fn.enable_resilience()``; the disabled hot path never touches it
    (one attribute load + ``is None`` test, the telemetry discipline).
    Thread-safe: the chaos suite drives one function from many threads.
    """

    def __init__(self, config: Optional[ResilienceConfig] = None, *,
                 fault_ref: Any = None, decisions: Any = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.config = config if config is not None else ResilienceConfig()
        self._fault_ref = fault_ref
        self.decisions = decisions
        self.sleep = sleep
        self._lock = threading.Lock()
        self._seq = 0
        self.events: Deque[DegradationEvent] = deque(
            maxlen=self.config.max_events)
        # monotonic counters (Prometheus)
        self.calls = 0
        self.degraded_calls = 0          # calls that recorded >= 1 rung
        self.retries_transient = 0
        self.retries_fallback = 0
        self.failures = 0                # RequestFailed raised
        self.malformed = 0

    @property
    def fault_plan(self):
        return None if self._fault_ref is None else self._fault_ref.plan

    def begin_call(self) -> int:
        with self._lock:
            seq = self._seq
            self._seq += 1
            self.calls += 1
        return seq

    def record(self, rung: str, *, seq: int, attempt: int,
               cause: BaseException | str, backoff_s: float = 0.0,
               bucket: Optional[Tuple[int, ...]] = None) -> DegradationEvent:
        """Record one ladder rung: event deque + counters + DecisionLog."""
        ev = DegradationEvent(seq=seq, rung=rung, attempt=attempt,
                              cause=cause if isinstance(cause, str)
                              else repr(cause),
                              backoff_s=backoff_s, bucket=bucket)
        with self._lock:
            self.events.append(ev)
            if rung == "retry-transient":
                self.retries_transient += 1
            elif rung == "retry-fallback":
                self.retries_fallback += 1
            elif rung == "reject":
                self.failures += 1
            elif rung == "reject-malformed":
                self.failures += 1
                self.malformed += 1
        if self.decisions is not None:
            self.decisions.add(
                "degrade", f"call {seq}", rung, ev.cause,
                attempt=attempt, backoff_s=backoff_s, bucket=bucket)
        return ev

    def note_degraded_call(self) -> None:
        with self._lock:
            self.degraded_calls += 1

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {"calls": self.calls,
                    "degraded_calls": self.degraded_calls,
                    "retries_transient": self.retries_transient,
                    "retries_fallback": self.retries_fallback,
                    "failures": self.failures,
                    "malformed": self.malformed}
