"""Liveness analysis over a scheduled order (memory-planner stage 1).

Produces one :class:`LiveInterval` per planned value: the step range during
which its buffer must exist, plus its symbolic byte count.  The discipline
mirrors ``scheduling/memsim.py`` and the interpreter exactly:

* an intermediate materializes when its producer executes and dies right
  after its last consumer (graph outputs survive the whole run);
* inputs/consts are caller-provided and live from before step 0; without
  donation they survive the run, with ``donate_inputs`` they die at their
  last consumer like any intermediate;
* a no-consumer non-output value is transient (the interpreter never
  stores it) and gets no interval.

Within one step, a node's outputs allocate *before* its dead inputs free,
so two intervals may share a buffer only when one ends strictly before the
other starts (``end < start``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

from ..ir.graph import Graph, Node
from ..symbolic import SymbolicExpr


@dataclass(frozen=True)
class LiveInterval:
    """Closed step range ``[start, end]`` during which a buffer must exist."""

    vid: int
    start: int            # -1 = caller-provided, exists before step 0
    end: int              # len(order) = survives the run (output / kept input)
    nbytes_expr: SymbolicExpr
    kind: str             # 'input' | 'const' | 'intermediate'
    is_output: bool

    @property
    def external(self) -> bool:
        """Caller-provided buffer (input/const) — not arena-allocated."""
        return self.kind in ("input", "const")

    def overlaps(self, other: "LiveInterval") -> bool:
        return not (self.end < other.start or other.end < self.start)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LiveInterval(%{self.vid} [{self.start}, {self.end}] "
                f"{self.kind}{' out' if self.is_output else ''})")


def analyze_liveness(graph: Graph, order: Sequence[Node], *,
                     donate_inputs: bool = False) -> Dict[int, LiveInterval]:
    """Symbolic live intervals of every planned value under ``order``."""
    pos = {n.id: i for i, n in enumerate(order)}
    horizon = len(order)
    output_ids = {v.id for v in graph.outputs}
    out: Dict[int, LiveInterval] = {}

    def last_use(v) -> int:
        uses = [pos[c.id] for c in v.consumers if c.id in pos]
        return max(uses) if uses else -1

    for v in list(graph.inputs) + list(graph.consts):
        end = horizon
        if donate_inputs and v.id not in output_ids:
            lu = last_use(v)
            # the interpreter only frees at a consumer boundary; an unused
            # donated input is never visited, so it survives the run
            if lu >= 0:
                end = lu
        out[v.id] = LiveInterval(v.id, -1, end, v.nbytes_expr, v.kind,
                                 v.id in output_ids)

    for v in graph.values:
        if v.is_materialized_input() or v.producer is None:
            continue
        if v.producer.id not in pos:
            continue
        if not v.consumers and v.id not in output_ids:
            continue  # transient: the interpreter never stores it
        start = pos[v.producer.id]
        end = horizon if v.id in output_ids else max(last_use(v), start)
        out[v.id] = LiveInterval(v.id, start, end, v.nbytes_expr,
                                 "intermediate", v.id in output_ids)
    return out
