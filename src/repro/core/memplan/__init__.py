"""Symbolic memory planner: compile-time buffer reuse for dynamic shapes.

The pipeline's final stage.  Given the scheduled order, ``liveness``
computes symbolic live intervals per value, ``assign`` greedily packs
values into reusable *slots* — proving fit with the shape graph's symbolic
comparison (interval fallback included) — and emits an :class:`ArenaPlan`
with per-slot symbolic size expressions and, when every dynamic dim is
bounded, a guaranteed worst-case arena size.  ``arena`` is the runtime
half: an :class:`ArenaAllocator` that evaluates the slot sizes once per
dim binding and services the interpreter's alloc/free traffic through the
planned slots.
"""
from .liveness import LiveInterval, analyze_liveness
from .assign import ArenaPlan, SlotAssignment, SlotInfo, build_arena_plan
from .arena import ArenaAllocator

__all__ = [
    "LiveInterval", "analyze_liveness",
    "ArenaPlan", "SlotAssignment", "SlotInfo", "build_arena_plan",
    "ArenaAllocator",
]
