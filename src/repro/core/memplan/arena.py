"""Runtime arena allocator (memory-planner stage 3).

Services the interpreter's alloc/free traffic through the planned slots.
The symbolic plan is realized per dim binding by ``ArenaPlan.resolve``
(slot sizes evaluated once per env, then the exact arena *reserve* for
that env computed by address-packing the planned lifetimes); the resolve
result is cached inside the plan alongside the interpreter's
``_size_cache``, so the whole arena could be reserved in one allocation up
front — TPU-style.  Per run the allocator:

* places caller-provided inputs/consts into their *external* slots (zero
  arena cost; with donation they join the reuse pool once dead, and
  values planned into them ride caller memory instead of the arena);
* puts each value into its assigned slot; when remat eviction has
  shuffled residency (a rematerialized tensor may find its slot taken),
  it falls back to best-fit over free slots or opens a dynamic slot — the
  arena cooperates with eviction and regeneration instead of constraining
  them.  If churn pushes live bytes past the planned reserve, the arena
  grows (``arena_growth_bytes``);
* tracks the stats surfaced on ``MemoryStats``: ``arena_bytes`` (final
  arena size for this env, growth included), ``slots``, ``reuse_ratio``
  (fraction of allocations served by a previously-used buffer),
  ``fragmentation_bytes`` (arena size minus the peak bytes simultaneously
  in use — the planner's waste vs a perfect allocator).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .assign import ArenaPlan, ResolvedArena


class ArenaExhausted(RuntimeError):
    """Arena occupancy crossed a hard cap the caller asked to enforce.

    Raised only under ``resilience.enforce_arena_bound``: the planner's
    ``arena_bound_bytes`` is a guarantee, so crossing it means runtime
    churn (remat realloc into foreign slots) grew the arena past what
    was promised — the degradation ladder treats it as memory pressure
    instead of letting the arena silently exceed the bound."""


class ArenaAllocator:
    def __init__(self, plan: ArenaPlan, resolved: ResolvedArena, *,
                 hard_cap: Optional[int] = None):
        self.plan = plan
        self.hard_cap = hard_cap
        self.capacity: List[int] = list(resolved.caps)
        self.external: List[bool] = list(resolved.external)
        n = len(self.capacity)
        self.occupant: List[Optional[int]] = [None] * n
        self.occupant_bytes: List[int] = [0] * n
        self.used_once: List[bool] = [False] * n
        self.slot_of: Dict[int, int] = {}    # vid -> sid currently holding it
        self.reserve = resolved.arena_bytes  # planned arena size for this env
        self.dynamic_slots = 0
        self.allocs = 0
        self.reuses = 0
        self.donated_reuses = 0
        self._in_use = 0                     # live bytes backed by the arena
        self.peak_in_use = 0

    # -- placement ------------------------------------------------------------
    def place_external(self, vid: int, nbytes: int) -> None:
        """Register a caller-provided buffer in its external slot."""
        if vid in self.slot_of:
            return
        asg = self.plan.assignment.get(vid)
        if asg is None or self.occupant[asg.sid] is not None:
            sid = self._new_slot(nbytes, external=True)
        else:
            sid = asg.sid
            self.capacity[sid] = nbytes   # the actual caller buffer size
        self._occupy(sid, vid, nbytes)

    def alloc(self, vid: int, nbytes: int) -> None:
        """Place a value; called for every device allocation (incl. remat
        restore/reload).  No-op if the value already holds a slot."""
        if vid in self.slot_of:
            return
        self.allocs += 1
        sid = None
        asg = self.plan.assignment.get(vid)
        if asg is not None and self.occupant[asg.sid] is None \
                and (not self.external[asg.sid]
                     or nbytes <= self.capacity[asg.sid]):
            sid = asg.sid
        if sid is None:
            sid = self._fallback_slot(nbytes)
        if sid is None:
            sid = self._new_slot(nbytes, external=False)
        if self.used_once[sid]:
            self.reuses += 1
            if self.external[sid]:
                self.donated_reuses += 1
        self._occupy(sid, vid, nbytes)

    def free(self, vid: int) -> None:
        sid = self.slot_of.pop(vid, None)
        if sid is None:
            return
        b = self.occupant_bytes[sid]
        self.occupant[sid] = None
        self.occupant_bytes[sid] = 0
        if not self.external[sid]:
            self._in_use -= b

    # -- internals -------------------------------------------------------------
    def _occupy(self, sid: int, vid: int, nbytes: int) -> None:
        self.occupant[sid] = vid
        self.occupant_bytes[sid] = nbytes
        self.slot_of[vid] = sid
        self.used_once[sid] = True
        if not self.external[sid]:
            self._in_use += nbytes
            if self.hard_cap is not None and self._in_use > self.hard_cap:
                # roll back before raising: the ladder may retry this call
                self.occupant[sid] = None
                self.occupant_bytes[sid] = 0
                del self.slot_of[vid]
                self._in_use -= nbytes
                raise ArenaExhausted(
                    f"arena occupancy {self._in_use + nbytes} would exceed "
                    f"the enforced bound of {self.hard_cap} bytes "
                    f"(value {vid}, {nbytes} bytes)")
            self.peak_in_use = max(self.peak_in_use, self._in_use)

    def _fallback_slot(self, nbytes: int) -> Optional[int]:
        """Best-fit among free slots: the smallest capacity that holds
        ``nbytes`` (external slots cannot stretch), else the roomiest
        arena slot — the pool serves any size."""
        best = best_cap = None
        roomiest = roomiest_cap = None
        for sid, occ in enumerate(self.occupant):
            if occ is not None:
                continue
            cap = self.capacity[sid]
            if cap >= nbytes and (best is None or cap < best_cap):
                best, best_cap = sid, cap
            if not self.external[sid] and \
                    (roomiest is None or cap > roomiest_cap):
                roomiest, roomiest_cap = sid, cap
        return best if best is not None else roomiest

    def _new_slot(self, nbytes: int, *, external: bool) -> int:
        sid = len(self.capacity)
        self.capacity.append(nbytes)
        self.external.append(external)
        self.occupant.append(None)
        self.occupant_bytes.append(0)
        self.used_once.append(False)
        if not external:
            self.dynamic_slots += 1
        return sid

    # -- reporting -------------------------------------------------------------
    @property
    def in_use_bytes(self) -> int:
        """Live bytes currently backed by the arena (externals excluded)."""
        return self._in_use

    @property
    def arena_bytes(self) -> int:
        """Final arena size: the planned reserve, grown if runtime churn
        (remat realloc into foreign slots) pushed live bytes past it."""
        return max(self.reserve, self.peak_in_use)

    @property
    def growth_bytes(self) -> int:
        return max(0, self.peak_in_use - self.reserve)

    @property
    def n_slots(self) -> int:
        """Arena-backed slots (external/donated buffers excluded)."""
        return sum(1 for ext in self.external if not ext)

    @property
    def reuse_ratio(self) -> float:
        return self.reuses / self.allocs if self.allocs else 0.0

    @property
    def fragmentation_bytes(self) -> int:
        return self.arena_bytes - self.peak_in_use

    def write_stats(self, stats) -> None:
        """Publish the run's arena counters onto a ``MemoryStats``."""
        stats.arena_bytes = self.arena_bytes
        stats.slots = self.n_slots
        stats.reuse_ratio = self.reuse_ratio
        stats.fragmentation_bytes = self.fragmentation_bytes
        stats.arena_growth_bytes = self.growth_bytes
        stats.donated_reuses = self.donated_reuses
