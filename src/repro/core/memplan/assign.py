"""Symbolic buffer assignment (memory-planner stage 2).

Packs live intervals into reusable *slots* — in the style of Relax's
dynamic-shape memory planning and XLA's global-decreasing-size best-fit
heap simulator, but with symbolic sizes throughout:

* values are placed **largest worst-case size first** (the big activations
  found slots; everything smaller fills gaps), so slots are sized by their
  founding member and later members ride free;
* a slot can host ``v`` when no previous member's live interval overlaps
  ``v``'s;
* among hosting slots we first look for a **provable fit** — some slot
  size expression ``e`` with ``ShapeGraph.compare(bytes(v), e) ∈
  {LT, LE, EQ}`` (the interval fallback makes many cross-symbol cases
  decidable once dim ranges are declared).  Provable fit is *hard reuse*:
  for every env the value fits the slot as already sized;
* otherwise a slot is reused **checked**: fit holds at the worst-case env
  but cannot be proven for all envs, so the value's size expression joins
  the slot's candidate set and the runtime sizes the slot to the max over
  the set for the *actual* env — growing the slot beyond its founding size
  exactly when that env needs it (fallback slot growth);
* only when no compatible slot exists does the value open a fresh one.

Inputs/consts occupy *external* slots (caller-provided buffers, zero arena
cost).  With ``donate_inputs`` a dead input's slot joins the reuse pool —
provable fits only, a caller buffer cannot grow — so same-shaped late
values (e.g. updated params) land in donated buffers.

A slot's symbolic size is ``max`` over its candidate size expressions;
``ArenaPlan.arena_bound_bytes`` sums each slot's interval upper bound over
the declared dim ranges — a guaranteed arena size whenever every dynamic
dim is bounded above.

Per concrete env, ``ArenaPlan.resolve`` turns the symbolic plan into an
exact arena *reserve*: slot sizes evaluate to plain bytes and the planned
lifetimes are address-packed first-fit-decreasing (a vacant buffer's bytes
return to the pool between occupancies, as in an arena-backed caching
allocator), capped by Σ slot capacities so the compile-time bound always
dominates.  Slot reuse decides *buffer identity* (what the runtime
allocator services and reports); the resolve height decides *arena size*.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..ir.graph import Graph, Node
from ..symbolic import Cmp, ShapeGraph, SymbolicExpr
from .liveness import LiveInterval, analyze_liveness

# how many candidate slots a single value probes with the full symbolic
# comparison before settling for a checked reuse (exact-expression matches
# are found through a dict first and are not subject to this cap)
_MAX_FIT_PROBES = 24


@dataclass
class SlotInfo:
    """One reusable buffer of the planned arena."""

    sid: int
    external: bool                      # caller-provided (input/const buffer)
    members: List[int] = field(default_factory=list)
    # distinct candidate size expressions; the slot's size for an env is the
    # max over their evaluations (provably-fitting members add nothing)
    size_exprs: List[SymbolicExpr] = field(default_factory=list)
    # member live intervals as (start, end), kept sorted by start
    intervals: List[Tuple[int, int]] = field(default_factory=list)
    # cached bounds of the size over the declared dim ranges + its value at
    # the representative worst-case env (the packing order key)
    size_lo: Optional[int] = 0
    size_hi: Optional[int] = None
    rep_size: int = 0

    @property
    def size_expr(self) -> SymbolicExpr:
        """The slot's symbolic size, ``max`` over the candidate set."""
        out = self.size_exprs[0]
        for e in self.size_exprs[1:]:
            out = SymbolicExpr.max_of(out, e)
        return out

    def capacity(self, env: Dict[str, int]) -> int:
        return max(e.evaluate(env) for e in self.size_exprs)

    def can_host(self, start: int, end: int) -> bool:
        """True when [start, end] overlaps no member interval."""
        i = bisect.bisect_left(self.intervals, (start, -1))
        if i < len(self.intervals) and self.intervals[i][0] <= end:
            return False
        return not (i > 0 and self.intervals[i - 1][1] >= start)

    def add_member(self, vid: int, start: int, end: int) -> None:
        self.members.append(vid)
        bisect.insort(self.intervals, (start, end))


@dataclass(frozen=True)
class SlotAssignment:
    vid: int
    sid: int
    provable: bool      # fit proven at compile time (hard reuse)
    reused: bool        # slot had a previous member
    donated: bool       # landed in a freed donated input/const buffer


@dataclass
class ResolvedArena:
    """The plan realized for one concrete env (sizes are plain bytes).

    ``arena_bytes`` is the reserve the arena needs to service the plan at
    this env: the height of a first-fit-decreasing *address* packing of
    the planned value lifetimes — a vacant buffer's bytes return to the
    pool between occupancies, exactly like an arena-backed caching
    allocator.  Values planned into donated caller buffers stay out of
    the pack (their bytes are the caller's).  ``slot_cap_total`` (Σ slot
    capacities) is the no-address-reuse fallback; ``arena_bytes`` never
    exceeds it."""

    caps: List[int]            # per-slot capacity at this env
    external: List[bool]
    arena_bytes: int
    packed_height: int
    slot_cap_total: int
    # concrete per-value byte offset into the packed arena for this env
    # (arena-served values only — external/donated placements are the
    # caller's memory).  Consumed by the lowered Program's resolve():
    # offsets and sizes land in the executable artifact in one pass.
    offsets: Dict[int, int] = field(default_factory=dict)


@dataclass
class ArenaPlan:
    """Compile-time slot assignment + symbolic arena sizing."""

    slots: List[SlotInfo]
    assignment: Dict[int, SlotAssignment]    # vid -> slot
    liveness: Dict[int, LiveInterval]
    donate_inputs: bool
    horizon: int = 0               # len(order); liveness end for survivors
    n_assigned: int = 0            # arena-planned intermediates
    n_reused: int = 0
    n_provable_reuses: int = 0
    n_checked_reuses: int = 0
    n_donated_reuses: int = 0
    # guaranteed bounds on the arena size over the declared dim ranges:
    # hi = Σ per-slot interval highs (None when some live dim has no
    # declared upper bound); lo = the largest arena-served value at its
    # smallest in-range size (the packed reserve holds its biggest block)
    arena_bound_bytes: Optional[int] = None
    arena_bound_lo: int = 0

    def __post_init__(self):
        self._resolve_cache: Dict[Tuple, ResolvedArena] = {}

    @property
    def n_slots(self) -> int:
        """Arena-allocated slots (external/donated buffers excluded)."""
        return sum(1 for s in self.slots if not s.external)

    @property
    def planned_reuse_ratio(self) -> float:
        return self.n_reused / self.n_assigned if self.n_assigned else 0.0

    def slot_capacities(self, env: Dict[str, int]) -> List[int]:
        """Per-slot byte capacity for a concrete env (index = sid)."""
        return [s.capacity(env) for s in self.slots]

    def resolve(self, env: Dict[str, int]) -> ResolvedArena:
        """Realize the plan for ``env``: evaluate every slot size and carve
        whole slots into hosts whose idle bytes provably cover them at
        every step of the planned timeline (sizes are concrete here, so
        the check is exact).  Cached per env — training repeats shapes."""
        key = tuple(sorted(env.items()))
        out = self._resolve_cache.get(key)
        if out is None:
            if len(self._resolve_cache) > 64:
                self._resolve_cache.clear()
            out = _resolve_arena(self, env)
            self._resolve_cache[key] = out
        return out

    def arena_bytes(self, env: Dict[str, int]) -> int:
        """Planned arena size for ``env``: Σ capacities of the non-external
        root slots (carved slots ride inside their hosts)."""
        return self.resolve(env).arena_bytes


def _resolve_arena(plan: ArenaPlan, env: Dict[str, int]) -> ResolvedArena:
    caps = plan.slot_capacities(env)
    external = [s.external for s in plan.slots]
    slot_total = sum(c for c, ext in zip(caps, external) if not ext)

    # first-fit-decreasing address packing of the planned lifetimes; values
    # planned into donated caller buffers are served outside the arena
    vals = []
    for vid, iv in plan.liveness.items():
        if iv.external:
            continue
        asg = plan.assignment.get(vid)
        if asg is not None and plan.slots[asg.sid].external:
            continue
        vals.append((iv.start, iv.end, iv.nbytes_expr.evaluate(env), vid))
    vals.sort(key=lambda x: (-x[2], x[0]))

    placed: List[Tuple[int, int, int, int]] = []   # (start, end, size, off)
    offsets: Dict[int, int] = {}
    height = 0
    for (st, en, sz, vid) in vals:
        spans = sorted((off, off + s) for (s2, e2, s, off) in placed
                       if not (e2 < st or en < s2))
        off = 0
        for (lo, hi) in spans:
            if off + sz <= lo:
                break
            off = max(off, hi)
        placed.append((st, en, sz, off))
        offsets[vid] = off
        height = max(height, off + sz)

    return ResolvedArena(caps=caps, external=external,
                         arena_bytes=min(height, slot_total),
                         packed_height=height, slot_cap_total=slot_total,
                         offsets=offsets)


def _representative_env(graph: Graph, sg: ShapeGraph) -> Dict[str, int]:
    """Worst-case-leaning env used only to order values for packing:
    every dim at its declared upper bound, defaulting to 64."""
    env = {}
    for name in graph.free_symbols():
        iv = sg.declared_ranges.get(name)
        v = 64 if iv is None or iv.hi is None else iv.hi
        if iv is not None and iv.lo is not None:
            v = max(v, iv.lo)
        env[name] = v
    return env


def build_arena_plan(graph: Graph, order: Sequence[Node],
                     shape_graph: Optional[ShapeGraph] = None, *,
                     donate_inputs: bool = False) -> ArenaPlan:
    sg = shape_graph if shape_graph is not None else ShapeGraph()
    liveness = analyze_liveness(graph, order, donate_inputs=donate_inputs)
    rep_env = _representative_env(graph, sg)
    # many values share interned size exprs: evaluate each once per compile
    _rep_memo: Dict[int, int] = {}

    def rep_eval(e) -> int:
        v = _rep_memo.get(e.uid)
        if v is None:
            v = e.evaluate(rep_env)
            _rep_memo[e.uid] = v
        return v

    slots: List[SlotInfo] = []
    assignment: Dict[int, SlotAssignment] = {}
    # canonical size expr -> sids whose candidate set contains it (the
    # exact-match fast path: identical sizes are an EQ fit by definition)
    by_expr: Dict[SymbolicExpr, List[int]] = {}
    # (rep_size, sid) sorted: placement scans candidate hosts from a value's
    # own representative size upward instead of testing every slot
    size_index: List[Tuple[int, int]] = []

    def new_slot(iv: LiveInterval, external: bool) -> SlotInfo:
        lo, hi = sg.bounds_of(iv.nbytes_expr)
        s = SlotInfo(sid=len(slots), external=external,
                     size_exprs=[iv.nbytes_expr],
                     size_lo=lo, size_hi=hi,
                     rep_size=rep_eval(iv.nbytes_expr))
        s.add_member(iv.vid, iv.start, iv.end)
        slots.append(s)
        bisect.insort(size_index, (s.rep_size, s.sid))
        by_expr.setdefault(sg.canonicalize(iv.nbytes_expr), []).append(s.sid)
        return s

    # caller-provided buffers first: external slots, occupied from step -1
    for v in list(graph.inputs) + list(graph.consts):
        iv = liveness.get(v.id)
        if iv is None:
            continue
        s = new_slot(iv, external=True)
        assignment[v.id] = SlotAssignment(v.id, s.sid, provable=True,
                                          reused=False, donated=False)

    # global decreasing-size best-fit: biggest worst-case values found the
    # slots, smaller ones fill the gaps
    intermediates = sorted(
        (iv for iv in liveness.values() if not iv.external),
        key=lambda iv: (-rep_eval(iv.nbytes_expr), iv.start, iv.vid))

    plan = ArenaPlan(slots=slots, assignment=assignment, liveness=liveness,
                     donate_inputs=donate_inputs, horizon=len(order))

    for iv in intermediates:
        plan.n_assigned += 1
        chosen: Optional[SlotInfo] = None
        provable = False
        v_rep = rep_eval(iv.nbytes_expr)
        v_lo, v_hi = sg.bounds_of(iv.nbytes_expr)

        # 1. exact-expression match (EQ fit, no comparison machinery needed)
        canon = sg.canonicalize(iv.nbytes_expr)
        for sid in by_expr.get(canon, ()):
            if slots[sid].can_host(iv.start, iv.end):
                chosen, provable = slots[sid], True
                break

        if chosen is None:
            # 2. provable fit via symbolic comparison, tightest slot first —
            #    scan the (rep_size, sid) index upward from the value's own
            #    representative size; liveness overlap is checked lazily so
            #    slots below v_rep are never bisected at all
            probes = 0
            start = bisect.bisect_left(size_index, (v_rep, -1))
            for j in range(start, len(size_index)):
                if probes >= _MAX_FIT_PROBES:
                    break
                s = slots[size_index[j][1]]
                if not s.can_host(iv.start, iv.end):
                    continue
                probes += 1
                # interval prefilter: hi(value) <= lo(slot size) proves fit
                if v_hi is not None and s.size_lo is not None \
                        and v_hi <= s.size_lo:
                    chosen, provable = s, True
                    break
                if any(sg.compare(iv.nbytes_expr, e) in (Cmp.LT, Cmp.LE, Cmp.EQ)
                       for e in s.size_exprs):
                    chosen, provable = s, True
                    break

            # 3. checked reuse: best fit at the representative env — fit is
            #    plausible but unproven, so the runtime sizes the slot per
            #    env and may grow it.  External (donated) buffers cannot
            #    grow, so they only take provable members.
            if chosen is None:
                for j in range(start, len(size_index)):   # tightest first
                    s = slots[size_index[j][1]]
                    if not s.external and s.can_host(iv.start, iv.end):
                        chosen = s
                        break
                if chosen is None:   # nothing at least v_rep: grow the biggest
                    growable = [s for s in slots
                                if not s.external
                                and s.can_host(iv.start, iv.end)]
                    if growable:
                        chosen = max(growable, key=lambda s: s.rep_size)
                if chosen is not None and iv.nbytes_expr not in chosen.size_exprs:
                    chosen.size_exprs.append(iv.nbytes_expr)
                    chosen.size_lo = None if (chosen.size_lo is None or v_lo is None) \
                        else max(chosen.size_lo, v_lo)
                    chosen.size_hi = None if (chosen.size_hi is None or v_hi is None) \
                        else max(chosen.size_hi, v_hi)
                    if v_rep > chosen.rep_size:
                        i = bisect.bisect_left(
                            size_index, (chosen.rep_size, chosen.sid))
                        del size_index[i]
                        chosen.rep_size = v_rep
                        bisect.insort(size_index,
                                      (chosen.rep_size, chosen.sid))
                    bucket = by_expr.setdefault(canon, [])
                    if chosen.sid not in bucket:
                        bucket.append(chosen.sid)

        if chosen is None:
            s = new_slot(iv, external=False)
            assignment[iv.vid] = SlotAssignment(iv.vid, s.sid, provable=True,
                                                reused=False, donated=False)
            continue

        chosen.add_member(iv.vid, iv.start, iv.end)
        assignment[iv.vid] = SlotAssignment(iv.vid, chosen.sid,
                                            provable=provable, reused=True,
                                            donated=chosen.external)

    _recount(plan)
    _add_loop_slots(plan, graph, order, sg, rep_eval)

    # hi: every resolved arena is capped by Σ non-external slot capacities,
    # so Σ per-slot interval highs is a guaranteed upper bound.  lo: the
    # packed reserve is at least as tall as its biggest single block, so
    # the largest arena-served value at its smallest in-range size is a
    # guaranteed lower bound (per-slot lows do NOT sum — address packing
    # can overlap whole slots in time).
    lo_max, hi_sum = 0, 0
    for s in plan.slots:
        if s.external:
            continue
        hi_sum = None if (hi_sum is None or s.size_hi is None) \
            else hi_sum + s.size_hi
    for vid, asg in assignment.items():
        iv = liveness.get(vid)
        if iv is None:  # loop-internal runtime keys have no outer interval
            continue
        if iv.external or plan.slots[asg.sid].external:
            continue  # served from caller buffers, not the arena
        lo = sg.bounds_of(iv.nbytes_expr)[0]
        if lo is not None:
            lo_max = max(lo_max, lo)
    plan.arena_bound_lo = lo_max
    plan.arena_bound_bytes = hi_sum
    return plan


def _add_loop_slots(plan: ArenaPlan, graph: Graph, order: Sequence[Node],
                    sg: ShapeGraph, rep_eval) -> None:
    """Project each rolled loop's *body* arena plan into the outer plan.

    Every non-external body slot becomes an outer slot reserved at the
    loop's position — doubled when it hosts a produced loop carry, because
    two carry generations (iterations ``i-1`` and ``i``) are live at once
    across the back-edge.  Used ``xs`` slices and body constants get one
    slot each.  The runtime addresses these buffers with tuple keys
    ``(loop_node_id, parity, body_value_id)`` (parity 2 = loop constants);
    the key-agnostic ``assignment`` dict routes them to their outer slot,
    so cross-iteration reuse falls out of the ordinary slot discipline and
    the steady-state arena contribution is independent of the trip count.

    Slot members being freed and re-allocated every iteration is exactly
    the in-place update pattern the paper targets: the loop's footprint is
    one iteration's worth of buffers (×2 for carries), not ``t``'s worth.
    """
    from ..ir.loop import loop_body_of

    # synthetic vids index the pseudo liveness entries used for address
    # packing; real value ids are dense [0, len(values)), so this is free
    next_vid = len(graph.values)

    for p, n in enumerate(order):
        body = loop_body_of(n)
        if body is None:
            continue
        lp = body.plan(sg)

        def add_slot(size_exprs, size_lo, size_hi, rep_size) -> int:
            nonlocal next_vid
            svid = next_vid
            next_vid += 1
            s = SlotInfo(sid=len(plan.slots), external=False,
                         size_exprs=list(size_exprs),
                         size_lo=size_lo, size_hi=size_hi, rep_size=rep_size)
            s.add_member(svid, p, p)
            plan.slots.append(s)
            plan.liveness[svid] = LiveInterval(
                vid=svid, start=p, end=p, nbytes_expr=s.size_expr,
                kind="intermediate", is_output=False)
            plan.assignment[svid] = SlotAssignment(
                svid, s.sid, provable=True, reused=False, donated=False)
            return s.sid

        for s in lp.body_arena.slots:
            if s.external:
                continue
            doubled = any(m in lp.carry_member_ids for m in s.members)
            sids = [add_slot(s.size_exprs, s.size_lo, s.size_hi, s.rep_size)
                    for _ in range(2 if doubled else 1)]
            for m in s.members:
                for par in (0, 1):
                    key = (n.id, par, m)
                    plan.assignment[key] = SlotAssignment(
                        key, sids[par] if doubled else sids[0],
                        provable=True, reused=False, donated=False)
        for j, x in enumerate(lp.x_in):
            if not lp.x_used[j]:
                continue
            e = lp.sizes[x.id]
            lo, hi = sg.bounds_of(e)
            sid = add_slot([e], lo, hi, rep_eval(e))
            for par in (0, 1):
                key = (n.id, par, x.id)
                plan.assignment[key] = SlotAssignment(
                    key, sid, provable=True, reused=False, donated=False)
        for cid in lp.const_ids:
            e = lp.sizes[cid]
            lo, hi = sg.bounds_of(e)
            sid = add_slot([e], lo, hi, rep_eval(e))
            key = (n.id, 2, cid)
            plan.assignment[key] = SlotAssignment(
                key, sid, provable=True, reused=False, donated=False)


def _recount(plan: ArenaPlan) -> None:
    """Recompute the reuse counters from the final assignment flags."""
    plan.n_reused = plan.n_provable_reuses = 0
    plan.n_checked_reuses = plan.n_donated_reuses = 0
    for vid, asg in plan.assignment.items():
        if plan.liveness[vid].external or not asg.reused:
            continue
        plan.n_reused += 1
        if asg.provable:
            plan.n_provable_reuses += 1
        else:
            plan.n_checked_reuses += 1
        if asg.donated:
            plan.n_donated_reuses += 1
