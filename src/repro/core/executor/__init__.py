from .interpreter import PlanInterpreter, RunReport
from .memory import MemoryLimitExceeded, MemoryManager, MemoryStats

__all__ = ["PlanInterpreter", "RunReport", "MemoryLimitExceeded", "MemoryManager", "MemoryStats"]
