from .interpreter import PlanInterpreter, RunReport
from .memory import MemoryLimitExceeded, MemoryManager, MemoryStats
from .vm import ProgramVM

__all__ = ["PlanInterpreter", "ProgramVM", "RunReport",
           "MemoryLimitExceeded", "MemoryManager", "MemoryStats"]
