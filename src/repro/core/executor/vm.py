"""Slim register VM over a lowered :class:`~repro.core.lowering.Program`.

The default executor.  Two regimes, chosen per dim binding by
``Program.resolve``:

* **fast stream** — when no ``MaybeEvict`` can fire at this env (no
  memory limit, or the replayed peak fits under it), the hot loop is
  exactly: gather input registers, bind the primitive, store outputs,
  null dead registers.  All sizes/params were resolved once per env and
  the call's complete ``MemoryStats`` was precomputed by the resolve
  replay — per-op dispatch overhead collapses to list indexing.
* **dynamic stream** — under real memory pressure the full instruction
  stream runs: ``MaybeEvict`` triggers the runtime remat policy at the
  op boundaries the lowering marked, ``Regen`` rematerializes evicted
  registers through reload or the candidate's lowered sub-program, and
  frees honor regeneration holds.  Outputs are bitwise-identical to the
  reference ``PlanInterpreter``; eviction counters can differ only when
  victim scores tie exactly after remat churn (the interpreter's
  storage-dict iteration order mutates on reinsertion, the VM's
  candidate order is static).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from ..ir.trace import solve_checked_env
from ..lowering.program import (OP_BIND_ARG, OP_BIND_DIM, OP_COMPUTE,
                                OP_DONATE, OP_FREE_SLOT, OP_LOOP,
                                OP_MAYBE_EVICT, OP_REGEN, Program,
                                ResolvedProgram)
from ..memplan.arena import ArenaAllocator, ArenaExhausted
from ..remat.runtime import RuntimeRematPolicy
from .interpreter import RunReport
from .memory import MemoryManager, MemoryStats


class ProgramVM:
    """Executes a lowered Program; drop-in for ``PlanInterpreter.run``."""

    def __init__(self, program: Program, *,
                 size_cache: Optional[Dict[Tuple, Dict[int, int]]] = None,
                 params_cache: Optional[
                     Dict[Tuple, Dict[int, Dict[str, Any]]]] = None,
                 arena_hard_cap: Optional[int] = None):
        self.program = program
        self.plan = program.plan
        # shared per-env caches (bucketed dispatch passes one pair to every
        # bucket executor; keys are namespaced by graph uid inside resolve)
        self._size_cache = size_cache
        self._params_cache = params_cache
        # resilience.enforce_arena_bound: the plan's guaranteed arena bound
        # as a runtime hard cap — a resolve (or runtime growth) that would
        # exceed it raises ArenaExhausted instead of silently growing
        self.arena_hard_cap = arena_hard_cap
        # optional live-occupancy probe, dynamic (eviction) stream only:
        # called as hook(idx, inst, mm) after every executed instruction.
        # The fast stream is never instrumented — its occupancy curve is
        # exactly reconstructible off the hot path (obs.timeline)
        self.timeline_hook = None

    # knobs live on the lowered artifact (they shaped the emission)
    @property
    def memory_limit(self) -> Optional[int]:
        return self.program.memory_limit

    @property
    def donate_inputs(self) -> bool:
        return self.program.donate_inputs

    @property
    def count_inputs(self) -> bool:
        return self.program.count_inputs

    # ---------------------------------------------------------------- run --
    def run(self, flat_args: Sequence[Any],
            env: Optional[Dict[str, int]] = None,
            faults: Any = None) -> Tuple[List[Any], RunReport]:
        t0 = time.perf_counter()
        prog = self.program
        if env is None:
            # pre-solved envs (bucketed dispatch hot path) skip both steps
            env = solve_checked_env(prog.graph, prog.plan.shape_graph,
                                    flat_args)
        resolved = prog.resolve(env, self._size_cache, self._params_cache)
        cap = self.arena_hard_cap
        if cap is not None and resolved.arena is not None \
                and resolved.arena.arena_bytes > cap:
            # the resolve replay is exact for this env: catching the breach
            # here covers the fast stream without instrumenting its loop
            raise ArenaExhausted(
                f"resolved arena reserve {resolved.arena.arena_bytes} "
                f"exceeds the enforced bound of {cap} bytes")
        if faults is None:
            if resolved.fast_ok:
                outs, stats = self._run_fast(flat_args, resolved)
            else:
                outs, stats = self._run_dynamic(flat_args, resolved, env)
        elif resolved.fast_ok and not faults.needs_memory:
            outs, stats = self._run_fast_faulted(flat_args, resolved, faults)
        else:
            # a memory-kind fault needs the allocation stream: the dynamic
            # regime runs the full instruction list (bitwise-identical
            # outputs — it is the generic path the fast stream specializes)
            outs, stats = self._run_dynamic(flat_args, resolved, env,
                                            faults=faults)
        if stats.measured_dims:
            # surface the measured (not cap) bound dims in the report env
            env = {**resolved.env, **stats.measured_dims}
        wall = time.perf_counter() - t0
        return outs, RunReport(stats=stats, wall_s=wall, env=env)

    # ------------------------------------------------------------- loops --
    def _exec_loop(self, info, rl, ins: Sequence[Any],
                   env: Dict[str, int]) -> List[Any]:
        """Run one rolled loop: the lowered body sub-Program per iteration
        with registers rebound (carries from the previous iteration's
        output registers, ``xs`` slices by index).

        Pure execution — memory accounting happens through the shared
        ``LoopPlanInfo.account`` engine (dynamic path) or the resolve-time
        stats replay (fast path).  The body runs the same nodes in the
        same order with the same refined params as the reference
        interpreter's op-by-op loop, so outputs are bitwise-identical."""
        body, lp = info.body, info.lp
        bprog = info.body_program
        params = rl.rbody.params
        nc, nk = body.num_consts, body.num_carry
        consts_args = list(ins[:nc])
        carries = list(ins[nc:nc + nk])
        # one unstack dispatch per used xs, not one slice per iteration
        xs = [list(x) if lp.x_used[j] else None
              for j, x in enumerate(ins[nc + nk:])]
        out_regs = bprog.out_regs          # carries then ys
        ys: List[List[Any]] = [[] for _ in lp.y_out]
        for i in range(rl.trip):
            flat = consts_args + carries + [
                xs[j][i] if lp.x_used[j] else None for j in range(len(xs))]
            storage: List[Any] = [None] * bprog.n_regs
            for inst in bprog.fast_instructions:
                op = inst.op
                if op == OP_COMPUTE:
                    b_ins = [storage[r] for r in inst.in_regs]
                    if inst.dim_as_value:
                        out = jnp.asarray(params[inst.cidx]["dim"], jnp.int32)
                        for _oi, r in inst.store:
                            storage[r] = out
                    elif inst.multi:
                        outs = inst.prim.bind(*b_ins, **params[inst.cidx])
                        for oi, r in inst.store:
                            storage[r] = outs[oi]
                    else:
                        out = inst.prim.bind(*b_ins, **params[inst.cidx])
                        for _oi, r in inst.store:
                            storage[r] = out
                elif op == OP_BIND_ARG:
                    storage[inst.reg] = (flat[inst.index]
                                         if inst.index >= 0 else inst.const)
                elif op == OP_FREE_SLOT or op == OP_DONATE:
                    storage[inst.reg] = None
            carries = [storage[r] for r in out_regs[:nk]]
            for j, r in enumerate(out_regs[nk:]):
                ys[j].append(storage[r])
        if rl.trip > 0:
            # lax.concatenate over expanded slices: bitwise-identical to
            # jnp.stack at a fraction of its dispatch cost
            stacked = [
                lax.concatenate([lax.expand_dims(y, (0,)) for y in col], 0)
                for col in ys]
        else:
            stacked = [jnp.zeros((0,) + tuple(int(d.evaluate(env))
                                              for d in v.dims), v.dtype)
                       for v in lp.y_out]
        return carries + stacked

    # ------------------------------------------------------------ fast path
    def _run_fast(self, flat_args: Sequence[Any],
                  resolved: ResolvedProgram) -> Tuple[List[Any], MemoryStats]:
        prog = self.program
        storage: List[Any] = [None] * prog.n_regs
        params = resolved.params
        for inst in prog.fast_instructions:
            op = inst.op
            if op == OP_COMPUTE:
                ins = [storage[r] for r in inst.in_regs]
                if inst.dim_as_value:
                    out = jnp.asarray(params[inst.cidx]["dim"], jnp.int32)
                    for _oi, r in inst.store:
                        storage[r] = out
                elif inst.multi:
                    outs = inst.prim.bind(*ins, **params[inst.cidx])
                    for oi, r in inst.store:
                        storage[r] = outs[oi]
                else:
                    out = inst.prim.bind(*ins, **params[inst.cidx])
                    for _oi, r in inst.store:
                        storage[r] = out
            elif op == OP_BIND_ARG:
                storage[inst.reg] = (flat_args[inst.index]
                                     if inst.index >= 0 else inst.const)
            elif op == OP_FREE_SLOT or op == OP_DONATE:
                storage[inst.reg] = None
            elif op == OP_LOOP:
                outs = self._exec_loop(
                    prog.loops[inst.lidx], resolved.loops[inst.lidx],
                    [storage[r] for r in inst.in_regs], resolved.env)
                for oi, r in inst.store:
                    storage[r] = outs[oi]
        outputs = [storage[r] for r in prog.out_regs]
        return outputs, prog.stats_for(resolved)

    # -------------------------------------------------- fast path, faulted
    def _run_fast_faulted(self, flat_args: Sequence[Any],
                          resolved: ResolvedProgram,
                          faults: Any) -> Tuple[List[Any], MemoryStats]:
        """``_run_fast`` with a fault probe ahead of every kernel bind.

        A separate loop so the clean fast stream stays branch-free: the
        zero-overhead contract is on ``_run_fast``, this copy only runs
        when a kernel fault is armed for the call."""
        prog = self.program
        storage: List[Any] = [None] * prog.n_regs
        params = resolved.params
        for inst in prog.fast_instructions:
            op = inst.op
            if op == OP_COMPUTE:
                faults.before_compute()
                ins = [storage[r] for r in inst.in_regs]
                if inst.dim_as_value:
                    out = jnp.asarray(params[inst.cidx]["dim"], jnp.int32)
                    for _oi, r in inst.store:
                        storage[r] = out
                elif inst.multi:
                    outs = inst.prim.bind(*ins, **params[inst.cidx])
                    for oi, r in inst.store:
                        storage[r] = outs[oi]
                else:
                    out = inst.prim.bind(*ins, **params[inst.cidx])
                    for _oi, r in inst.store:
                        storage[r] = out
            elif op == OP_BIND_ARG:
                storage[inst.reg] = (flat_args[inst.index]
                                     if inst.index >= 0 else inst.const)
            elif op == OP_FREE_SLOT or op == OP_DONATE:
                storage[inst.reg] = None
            elif op == OP_LOOP:
                faults.before_compute()   # a rolled loop counts as one step
                outs = self._exec_loop(
                    prog.loops[inst.lidx], resolved.loops[inst.lidx],
                    [storage[r] for r in inst.in_regs], resolved.env)
                for oi, r in inst.store:
                    storage[r] = outs[oi]
        outputs = [storage[r] for r in prog.out_regs]
        return outputs, prog.stats_for(resolved)

    # --------------------------------------------------------- dynamic path
    def _run_dynamic(self, flat_args: Sequence[Any],
                     resolved: ResolvedProgram,
                     env: Dict[str, int],
                     faults: Any = None) -> Tuple[List[Any], MemoryStats]:
        prog = self.program
        plan = prog.plan
        vid_of = prog.vid_of
        reg_of = prog.reg_of
        nbytes = resolved.nbytes
        params = resolved.params
        ensure_bytes = resolved.ensure_bytes
        death = prog.death_step

        # value-dependent bounded dims: per-call overlays.  ``env_run``
        # starts at the cap-completed resolve env and is rebound by each
        # BindDim; ``nbytes`` becomes a private copy so measured sizes
        # never leak into the shared resolve (cap) tables.
        bound = prog.has_bound_dims
        env_run = resolved.env
        if bound:
            env_run = dict(env_run)
            nbytes = list(nbytes)

        # the policy's candidate flops expressions may mention bound
        # symbols (a recompute over a padded payload): evaluate at the
        # complete resolve env, never the bare declared env
        policy = RuntimeRematPolicy(plan, resolved.env)
        arena = None
        if resolved.arena is not None:
            arena = ArenaAllocator(plan.arena_plan, resolved.arena,
                                   hard_cap=self.arena_hard_cap)
        mm = MemoryManager(prog.memory_limit, arena=arena)
        if faults is not None:
            mm.fault_hook = faults.on_memory

        storage: List[Any] = [None] * prog.n_regs
        host_storage: Dict[int, Any] = {}     # reg -> host (numpy) array
        evicted_recompute: set = set()        # regs dropped, regenerable
        holds: Dict[int, int] = {}            # regen source pins
        pending_free: Dict[int, bool] = {}    # dead-but-held: reg -> counted
        state = {"step": 0, "pinned": frozenset()}

        def is_materializable(reg: int) -> bool:
            return storage[reg] is not None or reg in host_storage \
                or reg in evicted_recompute

        def free_reg(reg: int, counted: bool) -> None:
            was_tracked = is_materializable(reg)
            storage[reg] = None
            host_storage.pop(reg, None)
            evicted_recompute.discard(reg)
            if not was_tracked:
                return
            if counted:
                mm.free(vid_of[reg])
            else:
                # uncounted donated input: still release its arena slot
                mm.arena_release(vid_of[reg])

        # -- eviction callback (the folded RuntimeRematPolicy check) ---------
        def evict(need: int) -> int:
            live: Dict[int, int] = {}
            for reg in prog.candidate_regs:
                if storage[reg] is None:
                    continue
                if death[reg] >= state["step"] or holds.get(reg, 0) > 0:
                    live[vid_of[reg]] = mm.device_bytes(vid_of[reg])
            decisions = policy.choose_victims(need, live, state["pinned"],
                                              state["step"])
            freed = 0
            for dec in decisions:
                reg = reg_of[dec.vid]
                arr = storage[reg]
                if arr is None:
                    continue
                storage[reg] = None
                method = dec.method
                sub = prog.regen.get(reg)
                if method == "recompute":
                    # recompute is only safe if every source is materializable
                    if sub is None or not all(is_materializable(s)
                                              for s in sub.source_regs):
                        method = "offload"
                if method == "offload":
                    host_storage[reg] = np.asarray(arr)
                    mm.evict_to_host(dec.vid)
                else:
                    for s in sub.source_regs:
                        holds[s] = holds.get(s, 0) + 1
                    evicted_recompute.add(reg)
                    mm.evict_drop(dec.vid)
                del arr
                freed += dec.bytes_freed
            return freed

        mm.evict_callback = evict

        # -- materialize-on-demand (Regen instruction body) ------------------
        def materialize(reg: int) -> Any:
            arr = storage[reg]
            if arr is not None:
                return arr
            vid = vid_of[reg]
            if reg in host_storage:  # reload path (H2D)
                mm.ensure(nbytes[reg])
                arr = jnp.asarray(host_storage.pop(reg))
                mm.reload(vid)
                storage[reg] = arr
                return arr
            if reg in evicted_recompute:  # recompute sub-program
                sub = prog.regen[reg]
                evicted_recompute.discard(reg)
                for s in sub.source_regs:  # recursion strictly moves up-graph
                    materialize(s)
                temps: List[Any] = [None] * sub.n_temps
                for st in sub.steps:
                    ins = [temps[idx] if is_temp else materialize(idx)
                           for is_temp, idx in st.in_refs]
                    p = params[st.params_cidx]
                    if st.dim_as_value:
                        outs = [jnp.asarray(p["dim"], jnp.int32)]
                    elif st.multi:
                        outs = st.prim.bind(*ins, **p)
                    else:
                        outs = [st.prim.bind(*ins, **p)]
                    for oi, ti in st.writes:
                        temps[ti] = outs[oi]
                out_arr = temps[sub.target_temp]
                mm.ensure(nbytes[reg])
                mm.restore(vid, nbytes[reg])
                mm.stats.recompute_flops += resolved.regen_flops[reg]
                storage[reg] = out_arr
                # release regen holds on sources
                for s in sub.source_regs:
                    holds[s] = holds.get(s, 0) - 1
                    if holds[s] <= 0:
                        holds.pop(s, None)
                        counted = pending_free.pop(s, None)
                        if counted is not None:
                            free_reg(s, counted)
                return out_arr
            raise KeyError(f"value {vid} is not materializable")

        # -- instruction loop -------------------------------------------------
        outputs: List[Any] = []
        hook = self.timeline_hook
        for idx, inst in enumerate(prog.instructions):
            op = inst.op
            if op == OP_COMPUTE:
                if faults is not None:
                    faults.before_compute()
                ins = [storage[r] if storage[r] is not None else materialize(r)
                       for r in inst.in_regs]
                p = params[inst.cidx]
                if inst.dim_as_value:
                    out = jnp.asarray(p["dim"], jnp.int32)
                    for _oi, r in inst.store:
                        storage[r] = out
                        mm.alloc(vid_of[r], nbytes[r])
                elif inst.multi:
                    outs = inst.prim.bind(*ins, **p)
                    if inst.defer_regs or inst.extra_store:
                        # introducing op: payload alloc waits for the
                        # BindDim (tight size); count scalar reaches its
                        # register unaccounted when nothing consumes it
                        for oi, r in inst.store:
                            storage[r] = outs[oi]
                            if r not in inst.defer_regs:
                                mm.alloc(vid_of[r], nbytes[r])
                        for oi, r in inst.extra_store:
                            storage[r] = outs[oi]
                    else:
                        for oi, r in inst.store:
                            storage[r] = outs[oi]
                            mm.alloc(vid_of[r], nbytes[r])
                else:
                    out = inst.prim.bind(*ins, **p)
                    for _oi, r in inst.store:
                        storage[r] = out
                        mm.alloc(vid_of[r], nbytes[r])
                del ins
            elif op == OP_BIND_DIM:
                # measure the just-computed extent, clamp to the cap at
                # the current env (chained introducers can match padding
                # rows), publish it, refresh bound-dependent sizes, then
                # run the deferred payload alloc at the tight size
                measured = int(storage[inst.count_reg])
                cap_val = int(inst.cap_expr.evaluate(env_run))
                measured = min(max(measured, 0), cap_val)
                env_run[inst.name] = measured
                mm.stats.measured_dims[inst.name] = measured
                exprs = prog.nbytes_exprs
                for r in prog.bound_dep_regs[inst.name]:
                    nbytes[r] = exprs[r].evaluate(env_run)
                for _oi, r in inst.alloc_store:
                    mm.alloc(vid_of[r], nbytes[r])
                if inst.drop_count:
                    storage[inst.count_reg] = None
            elif op == OP_REGEN:
                state["step"] = inst.step
                state["pinned"] = inst.pinned
                for r in inst.regs:
                    materialize(r)
            elif op == OP_MAYBE_EVICT:   # Remat::EvictOp check
                state["step"] = inst.step
                state["pinned"] = inst.pinned
                if bound:
                    # the resolved ensure table holds cap sizes; sum the
                    # live overlay so pressure checks see measured sizes
                    comp = prog.computes[inst.cidx]
                    mm.ensure(sum(nbytes[r] for _oi, r in comp.store))
                else:
                    mm.ensure(ensure_bytes[inst.cidx])
            elif op == OP_BIND_ARG:
                storage[inst.reg] = (flat_args[inst.index]
                                     if inst.index >= 0 else inst.const)
                if arena is not None:
                    arena.place_external(inst.vid, nbytes[inst.reg])
                if prog.count_inputs:
                    mm.alloc(inst.vid, nbytes[inst.reg])
            elif op == OP_LOOP:
                # rolled loop under the dynamic regime: the evict check is
                # hoisted — one ensure() for the loop's exact internal peak
                # delta — then the shared account() engine drives the
                # MemoryManager while execution runs the body sub-Program
                state["step"] = inst.step
                state["pinned"] = inst.pinned
                if faults is not None:
                    faults.before_compute()   # one step per rolled loop
                ins = [storage[r] if storage[r] is not None else materialize(r)
                       for r in inst.in_regs]
                rl = resolved.loops[inst.lidx]
                info = prog.loops[inst.lidx]
                mm.ensure(rl.extra_bytes)
                info.lp.account(mm, info.node.id, rl.trip,
                                rl.sizes.__getitem__, rl.outer_y,
                                rl.outer_carry)
                outs = self._exec_loop(info, rl, ins, env)
                del ins
                for oi, r in inst.store:   # account() allocated the kept outs
                    storage[r] = outs[oi]
            elif op == OP_FREE_SLOT:
                if holds.get(inst.reg, 0) > 0:
                    pending_free[inst.reg] = True
                else:
                    free_reg(inst.reg, True)
            elif op == OP_DONATE:
                if holds.get(inst.reg, 0) > 0:
                    pending_free[inst.reg] = inst.counted
                else:
                    free_reg(inst.reg, inst.counted)
            else:  # OP_RETURN
                outputs = [materialize(r) for r in inst.regs]
            if hook is not None:
                hook(idx, inst, mm)
        if arena is not None:
            arena.write_stats(mm.stats)
        return outputs, mm.stats
