"""Op-by-op executor of an ExecutionPlan — the BladeDISC++ runtime analogue.

Executes the scheduled graph on concrete arrays of *any* shape matching the
symbolic trace (one compilation, no padding, no recompile), with:

  * exact memory accounting through ``MemoryManager``;
  * the evict check at op boundaries (paper's ``Remat::EvictOp``);
  * materialize-on-demand regeneration (paper's ``Remat::RegenerateOp``),
    by recompute subgraph or host reload, chosen by the runtime policy.

Recompute-evicted tensors place a *hold* on each source of their recompute
subgraph, so sources stay materializable (alive, offloaded, or recursively
recomputable) until regeneration releases the hold.  This realises the
compile-time impact accounting (bytes(target) − bytes(kept sources)) at
runtime.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..ir.dynamism import complete_bound_env
from ..ir.graph import Graph, Node, Value
from ..ir.loop import loop_body_of
from ..ir.trace import refine_params, solve_checked_env
from ..memplan.arena import ArenaAllocator
from ..remat.planner import ExecutionPlan
from ..remat.runtime import RuntimeRematPolicy
from .memory import MemoryManager, MemoryStats


def _bind_node(node: Node, ins: Sequence[Any], params: Dict[str, Any]) -> List[Any]:
    """Execute one primitive with refined (concrete) params.

    A few shape-polymorphism helper primitives have no eager impl and are
    evaluated directly from their params.
    """
    if node.prim_name == "dim_as_value":
        # params['dim'] was already refined to a concrete int
        return [jnp.asarray(params["dim"], jnp.int32)]
    outs = node.prim.bind(*ins, **params)
    return list(outs) if node.prim.multiple_results else [outs]


@dataclass
class RunReport:
    stats: MemoryStats
    wall_s: float
    env: Dict[str, int]


class PlanInterpreter:
    def __init__(self, plan: ExecutionPlan, *,
                 memory_limit: Optional[int] = None,
                 donate_inputs: bool = False,
                 count_inputs: bool = True,
                 size_cache: Optional[Dict[Tuple, Dict[int, int]]] = None,
                 params_cache: Optional[
                     Dict[Tuple, Dict[int, Dict[str, Any]]]] = None,
                 arena_hard_cap: Optional[int] = None):
        self.plan = plan
        self.g = plan.graph
        self.memory_limit = memory_limit
        # resilience.enforce_arena_bound (see ProgramVM.arena_hard_cap)
        self.arena_hard_cap = arena_hard_cap
        self.donate_inputs = donate_inputs
        self.count_inputs = count_inputs
        self._output_ids = {v.id for v in self.g.outputs}
        self._value_by_id = {v.id: v for v in self.g.values}
        self._remaining_template: Dict[int, int] = {
            v.id: len([c for c in v.consumers if c.id in plan.pos])
            for v in self.g.values
        }
        # values whose byte size mentions a bounded dim: their sizes are
        # re-evaluated per call at the live env (the measured value) and
        # never enter the shared (cap-valued) size cache
        self._bound_dep_vids: set = set()
        if self.g.bound_dims:
            names = frozenset(self.g.bound_dims)
            self._bound_dep_vids = {v.id for v in self.g.values
                                    if v.nbytes_expr.free_vars() & names}
        # per-env caches reused across calls (training repeats shapes).
        # Both depend only on graph + env — never on the op order — so
        # bucketed dispatch passes one shared pair to every per-bucket
        # interpreter: swapping plans between calls re-derives nothing.
        self._size_cache: Dict[Tuple, Dict[int, int]] = \
            size_cache if size_cache is not None else {}
        self._params_cache: Dict[Tuple, Dict[int, Dict[str, Any]]] = \
            params_cache if params_cache is not None else {}
        # optional live-occupancy probe (see ProgramVM.timeline_hook):
        # called as hook(step, node, mm) after every executed node
        self.timeline_hook = None

    # ---------------------------------------------------------------- run --
    def run(self, flat_args: Sequence[Any],
            env: Optional[Dict[str, int]] = None,
            faults: Any = None) -> Tuple[List[Any], RunReport]:
        t0 = time.perf_counter()
        g, plan = self.g, self.plan
        if env is None:
            # a caller passing a pre-solved env (the bucketed dispatch hot
            # path) has already validated it and skips both steps
            env = solve_checked_env(g, plan.shape_graph, flat_args)
        # namespaced by graph uid: node/value ids restart at 0 per graph,
        # so a cache injected across interpreters must never let one
        # graph's refined params/sizes answer for another's same-id node.
        # Keyed by the *declared* env: bounded dims complete to caps
        # deterministically, and measured values stay out of shared caches.
        env_key = (g.uid,) + tuple(sorted(env.items()))
        env_decl = env
        env = complete_bound_env(g, env) if g.bound_dims else env
        # the live env: BindDim-equivalent measuring rebinds bounded dims
        # here mid-call (a private copy; ``env`` keeps the caps)
        env_run = dict(env) if g.bound_dims else env
        bound_dep = self._bound_dep_vids
        policy = RuntimeRematPolicy(plan, env)
        nbytes = self._size_cache.setdefault(env_key, {})
        refined = self._params_cache.setdefault(env_key, {})
        if len(self._size_cache) > 64:  # bound the per-shape caches
            self._size_cache.clear()
            self._params_cache.clear()
            nbytes = self._size_cache.setdefault(env_key, {})
            refined = self._params_cache.setdefault(env_key, {})
        arena = None
        if plan.arena_plan is not None:
            # symbolic slot sizes evaluate + carve once per env (cached
            # inside the plan, like the size/params caches above)
            arena = ArenaAllocator(plan.arena_plan,
                                   plan.arena_plan.resolve(env),
                                   hard_cap=self.arena_hard_cap)
        mm = MemoryManager(self.memory_limit, arena=arena)
        if faults is not None:
            mm.fault_hook = faults.on_memory

        def bytes_of(v: Value) -> int:
            if v.id in bound_dep:
                # tight size at the live env; bypasses the shared cache
                return v.nbytes_expr.evaluate(env_run)
            b = nbytes.get(v.id)
            if b is None:
                b = v.nbytes_expr.evaluate(env)
                nbytes[v.id] = b
            return b

        overrides = plan.kernel_overrides
        local_refined: Dict[int, Dict[str, Any]] = {}

        def params_of(node: Node) -> Dict[str, Any]:
            ov = overrides.get(node.id)
            if ov is not None:
                # kernel-variant override: merge per plan, cached per run —
                # the shared cross-bucket cache keys only (graph uid, env)
                # and other buckets' plans merge different choices
                p = local_refined.get(node.id)
                if p is None:
                    p = {**refine_params(node.params, env), **ov}
                    local_refined[node.id] = p
                return p
            p = refined.get(node.id)
            if p is None:
                p = refine_params(node.params, env)
                refined[node.id] = p
            return p

        storage: Dict[int, Any] = {}          # vid -> device array
        host_storage: Dict[int, Any] = {}     # vid -> host (numpy) array
        evicted_recompute: set = set()        # vids dropped, regenerable
        remaining = dict(self._remaining_template)
        holds: Dict[int, int] = {}            # regen source pins
        step_holder = {"i": 0}
        pinned_holder = {"s": frozenset()}

        def is_materializable(vid: int) -> bool:
            return vid in storage or vid in host_storage or vid in evicted_recompute

        def maybe_free(vid: int) -> None:
            if remaining.get(vid, 0) == 0 and holds.get(vid, 0) == 0 \
                    and vid not in self._output_ids:
                v = self._value_by_id[vid]
                if v.is_materialized_input() and not self.donate_inputs:
                    return
                was_tracked = vid in storage or vid in host_storage \
                    or vid in evicted_recompute
                storage.pop(vid, None)
                host_storage.pop(vid, None)
                evicted_recompute.discard(vid)
                if was_tracked and (self.count_inputs or not v.is_materialized_input()):
                    mm.free(vid)
                elif was_tracked:
                    # uncounted donated input: still release its arena slot
                    mm.arena_release(vid)

        # -- eviction callback wired into the memory manager ------------------
        def evict(need: int) -> int:
            live = {vid: mm.device_bytes(vid) for vid in list(storage)
                    if vid in plan.candidates
                    and (remaining.get(vid, 0) > 0 or holds.get(vid, 0) > 0)}
            decisions = policy.choose_victims(need, live, pinned_holder["s"],
                                              step_holder["i"])
            freed = 0
            for dec in decisions:
                arr = storage.pop(dec.vid, None)
                if arr is None:
                    continue
                method = dec.method
                if method == "recompute":
                    rp = plan.candidates[dec.vid].recompute
                    # recompute is only safe if every source is materializable
                    if rp is None or not all(is_materializable(s)
                                             for s in rp.source_ids):
                        method = "offload"
                if method == "offload":
                    host_storage[dec.vid] = np.asarray(arr)
                    mm.evict_to_host(dec.vid)
                else:
                    rp = plan.candidates[dec.vid].recompute
                    for sid in rp.source_ids:
                        holds[sid] = holds.get(sid, 0) + 1
                    evicted_recompute.add(dec.vid)
                    mm.evict_drop(dec.vid)
                del arr
                freed += dec.bytes_freed
            return freed

        mm.evict_callback = evict

        # -- registration of inputs & consts ---------------------------------
        # caller-provided buffers occupy external arena slots (registered
        # before mm.alloc so the arena does not treat them as fresh allocs)
        for val, arr in zip(g.inputs, flat_args):
            storage[val.id] = arr
            if arena is not None:
                arena.place_external(val.id, bytes_of(val))
            if self.count_inputs:
                mm.alloc(val.id, bytes_of(val))
        for val in g.consts:
            storage[val.id] = val.const_val
            if arena is not None:
                arena.place_external(val.id, bytes_of(val))
            if self.count_inputs:
                mm.alloc(val.id, bytes_of(val))

        # -- materialize-on-demand (Remat::RegenerateOp) -----------------------
        def materialize(v: Value) -> Any:
            arr = storage.get(v.id)
            if arr is not None:
                return arr
            if v.id in host_storage:  # reload path (H2D)
                mm.ensure(bytes_of(v))
                arr = jnp.asarray(host_storage.pop(v.id))
                mm.reload(v.id)
                storage[v.id] = arr
                return arr
            if v.id in evicted_recompute:  # recompute path
                cand = plan.candidates[v.id]
                rp = cand.recompute
                assert rp is not None
                evicted_recompute.discard(v.id)
                for sid in rp.source_ids:  # recursion strictly moves up-graph
                    materialize(self._value_by_id[sid])
                temps: Dict[int, Any] = {}

                def read_local(x: Value) -> Any:
                    if x.id in temps:
                        return temps[x.id]
                    return materialize(x)

                out_arr = None
                for nid in rp.node_ids:
                    node = plan.node_by_id[nid]
                    ins = [read_local(iv) for iv in node.invals]
                    outs = _bind_node(node, ins, params_of(node))
                    for ov, oa in zip(node.outvals, outs):
                        temps[ov.id] = oa
                        if ov.id == v.id:
                            out_arr = oa
                assert out_arr is not None, "recompute plan missed its target"
                mm.ensure(bytes_of(v))
                mm.restore(v.id, bytes_of(v))
                mm.stats.recompute_flops += rp.flops.evaluate(env)
                storage[v.id] = out_arr
                # release regen holds on sources
                for sid in rp.source_ids:
                    holds[sid] = holds.get(sid, 0) - 1
                    if holds[sid] <= 0:
                        holds.pop(sid, None)
                        maybe_free(sid)
                return out_arr
            raise KeyError(f"value {v} is not materializable")

        # -- main loop ----------------------------------------------------------
        order = plan.order
        hook = self.timeline_hook
        for i, node in enumerate(order):
            step_holder["i"] = i
            pinned_holder["s"] = frozenset(
                [iv.id for iv in node.invals] + [ov.id for ov in node.outvals])
            if faults is not None:
                faults.before_compute()
            ins = [materialize(iv) for iv in node.invals]
            body = loop_body_of(node)
            if body is not None:
                # rolled loop: one ensure for the loop's whole internal peak
                # (Remat::EvictOp hoisted out of the body), then the shared
                # account() event replay drives the MemoryManager — the same
                # engine the VM and the resolve-time stats replay use, so
                # every executor reports identical loop accounting
                lp = body.plan(plan.shape_graph)
                trip = body.length_expr.evaluate(env)
                kept = [bool(ov.consumers) or ov.id in self._output_ids
                        for ov in node.outvals]
                nk = body.num_carry
                outer_y = [(ov.id, bytes_of(ov))
                           for ov, k in zip(node.outvals[nk:], kept[nk:]) if k]
                outer_carry = [(ov.id, bytes_of(ov)) if k else None
                               for ov, k in zip(node.outvals[:nk], kept[:nk])]
                # body-side caches namespaced by the body graph's uid — body
                # value/node ids restart at 0 and must not collide with the
                # outer graph's entries
                bkey = (body.graph.uid,) + tuple(sorted(env.items()))
                bsizes = self._size_cache.setdefault(bkey, {})
                bparams = self._params_cache.setdefault(bkey, {})

                def bsize_of(bvid: int) -> int:
                    b = bsizes.get(bvid)
                    if b is None:
                        b = lp.sizes[bvid].evaluate(env)
                        bsizes[bvid] = b
                    return b

                def bparams_of(bn: Node) -> Dict[str, Any]:
                    p = bparams.get(bn.id)
                    if p is None:
                        p = refine_params(bn.params, env)
                        bparams[bn.id] = p
                    return p

                mm.ensure(lp.peak_expr_for(node, kept, trip).evaluate(env))
                lp.account(mm, node.id, trip, bsize_of, outer_y, outer_carry)
                outs = lp.execute(ins, trip, env, bparams_of, bind=_bind_node)
                del ins
                for ov, oa, k in zip(node.outvals, outs, kept):
                    if k:   # account() already allocated the kept outputs
                        storage[ov.id] = oa
            else:
                out_bytes = sum(bytes_of(ov) for ov in node.outvals
                                if ov.consumers or ov.id in self._output_ids)
                mm.ensure(out_bytes)  # Remat::EvictOp check
                outs = _bind_node(node, ins, params_of(node))
                del ins
                intro = g.bound_intros.get(node.id)
                if intro is not None:
                    # the BindDim step: measure, clamp to the cap at the
                    # live env (chained introducers can match padding
                    # rows), publish — the kept-output allocs below then
                    # see the tight size through bytes_of
                    measured = int(outs[intro.count_out])
                    cap_val = int(intro.cap.evaluate(env_run))
                    measured = min(max(measured, 0), cap_val)
                    env_run[intro.name] = measured
                    mm.stats.measured_dims[intro.name] = measured
                for ov, oa in zip(node.outvals, outs):
                    if ov.consumers or ov.id in self._output_ids:
                        storage[ov.id] = oa
                        mm.alloc(ov.id, bytes_of(ov))
            # free dead values (buffer lifetime = last consumer)
            seen = set()
            for iv in node.invals:
                if iv.id in seen:
                    continue
                seen.add(iv.id)
                remaining[iv.id] -= sum(1 for x in node.invals if x.id == iv.id)
                maybe_free(iv.id)
            if hook is not None:
                hook(i, node, mm)

        outputs = [materialize(v) for v in g.outputs]
        if arena is not None:
            arena.write_stats(mm.stats)
        wall = time.perf_counter() - t0
        # bound graphs report the live env (measured extents, not caps);
        # range-dynamic graphs report the declared env unchanged
        report_env = env_run if g.bound_dims else env_decl
        return outputs, RunReport(stats=mm.stats, wall_s=wall, env=report_env)
