"""Device/host memory accounting with a limit and an eviction hook.

On a real TPU the pools map to HBM and host DRAM (offload via
``jax.device_put`` to a host memory space); in this CPU container the pools
are exact byte accounting over the arrays the interpreter owns — the same
decision inputs the paper's runtime takes from the CUDA caching allocator,
but precise.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional


class MemoryLimitExceeded(RuntimeError):
    pass


@dataclass
class MemoryStats:
    device_used: int = 0
    device_peak: int = 0
    host_used: int = 0
    host_peak: int = 0
    evictions: int = 0
    evicted_bytes: int = 0
    reloads: int = 0
    recomputes: int = 0
    recompute_flops: int = 0
    offloads: int = 0
    # arena-plan counters (zero when running with memory_plan="none")
    arena_bytes: int = 0          # arena size for this env, growth included
    slots: int = 0                # arena-allocated slots (external excluded)
    reuse_ratio: float = 0.0      # allocations served by a reused buffer
    fragmentation_bytes: int = 0  # arena size - peak bytes in use at once
    arena_growth_bytes: int = 0   # checked-reuse / dynamic growth beyond plan
    donated_reuses: int = 0       # allocations landing in donated input slots
    # bucketed-dispatch counters (zero without optimize(..., buckets=...));
    # every counter here is cumulative over the function's lifetime as of
    # this call, while last_* fields describe this call alone —
    # last_dispatch_ns is this call's bucket-resolution time (a miss
    # includes the bucket's specialization compile), dispatch_ns_total the
    # lifetime sum of those
    bucket_hits: int = 0
    specialize_count: int = 0
    last_dispatch_ns: int = 0
    dispatch_ns_total: int = 0
    # value-dependent bounded dims: the extents measured by this call's
    # BindDim steps (bound symbol -> measured value, not the cap).  Empty
    # for purely range-dynamic graphs.
    measured_dims: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


class MemoryManager:
    """Tracks per-tensor residency; enforces a device-bytes limit.

    ``ensure(nbytes)`` is the paper's ``Remat::EvictOp`` trigger: called
    before each allocation, it invokes the eviction callback until the
    allocation fits (or raises).
    """

    def __init__(self, limit_bytes: Optional[int] = None, arena=None):
        self.limit = limit_bytes
        self.stats = MemoryStats()
        self._device: Dict[int, int] = {}  # value id -> bytes
        self._host: Dict[int, int] = {}
        self.evict_callback: Optional[Callable[[int], int]] = None
        # optional ArenaAllocator mirroring device residency through the
        # planned slots (every device alloc/free below notifies it)
        self.arena = arena
        # fault injection (resilience): called as hook(event, vid, nbytes)
        # before alloc / evict_to_host / reload / restore mutate state, so
        # an injected failure aborts the call with accounting consistent.
        # None (the default) costs one attribute test per event.
        self.fault_hook: Optional[Callable[[str, int, int], None]] = None

    def _arena_alloc(self, vid: int, nbytes: int) -> None:
        if self.arena is not None:
            self.arena.alloc(vid, nbytes)

    def arena_release(self, vid: int) -> None:
        """Arena-only free for buffers this manager never counted
        (e.g. donated inputs under ``count_inputs=False``)."""
        if self.arena is not None:
            self.arena.free(vid)

    # -- residency queries -----------------------------------------------------
    def on_device(self, vid: int) -> bool:
        return vid in self._device

    def on_host(self, vid: int) -> bool:
        return vid in self._host

    def device_bytes(self, vid: int) -> int:
        return self._device.get(vid, 0)

    # -- allocation lifecycle ----------------------------------------------------
    def ensure(self, nbytes: int) -> None:
        if self.limit is None:
            return
        if self.stats.device_used + nbytes <= self.limit:
            return
        if self.evict_callback is not None:
            need = self.stats.device_used + nbytes - self.limit
            self.evict_callback(need)
        if self.stats.device_used + nbytes > self.limit:
            raise MemoryLimitExceeded(
                f"need {nbytes} bytes; used {self.stats.device_used} of "
                f"limit {self.limit} and eviction could not free enough")

    def alloc(self, vid: int, nbytes: int) -> None:
        if self.fault_hook is not None:
            self.fault_hook("alloc", vid, nbytes)
        assert vid not in self._device, f"double alloc of value {vid}"
        self._device[vid] = nbytes
        self.stats.device_used += nbytes
        self.stats.device_peak = max(self.stats.device_peak, self.stats.device_used)
        self._arena_alloc(vid, nbytes)

    def free(self, vid: int) -> None:
        b = self._device.pop(vid, None)
        if b is not None:
            self.stats.device_used -= b
            self.arena_release(vid)
        hb = self._host.pop(vid, None)
        if hb is not None:
            self.stats.host_used -= hb

    # -- eviction paths -------------------------------------------------------
    def evict_to_host(self, vid: int) -> None:
        if self.fault_hook is not None:
            self.fault_hook("offload", vid, self._device.get(vid, 0))
        b = self._device.pop(vid)
        self.stats.device_used -= b
        self._host[vid] = b
        self.stats.host_used += b
        self.stats.host_peak = max(self.stats.host_peak, self.stats.host_used)
        self.stats.evictions += 1
        self.stats.evicted_bytes += b
        self.stats.offloads += 1
        self.arena_release(vid)

    def evict_drop(self, vid: int) -> None:
        """Eviction with recompute regeneration: bytes simply drop."""
        b = self._device.pop(vid)
        self.stats.device_used -= b
        self.stats.evictions += 1
        self.stats.evicted_bytes += b
        self.arena_release(vid)

    def reload(self, vid: int) -> None:
        if self.fault_hook is not None:
            self.fault_hook("reload", vid, self._host.get(vid, 0))
        b = self._host.pop(vid)
        self.stats.host_used -= b
        self._device[vid] = b
        self.stats.device_used += b
        self.stats.device_peak = max(self.stats.device_peak, self.stats.device_used)
        self.stats.reloads += 1
        self._arena_alloc(vid, b)

    def restore(self, vid: int, nbytes: int) -> None:
        """Re-allocation after recompute regeneration."""
        if self.fault_hook is not None:
            self.fault_hook("restore", vid, nbytes)
        self._device[vid] = nbytes
        self.stats.device_used += nbytes
        self.stats.device_peak = max(self.stats.device_peak, self.stats.device_used)
        self.stats.recomputes += 1
        self._arena_alloc(vid, nbytes)
