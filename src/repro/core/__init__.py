"""BladeDISC++-style memory optimization for dynamic-shape JAX graphs.

The paper's primary contribution lives here: symbolic shape analysis
(``repro.core.symbolic``), the graph IR (``repro.core.ir``), op scheduling
(``repro.core.scheduling``), rematerialization (``repro.core.remat``), and
the runtime (``repro.core.executor``), wired together by :func:`optimize`.
"""
from .api import (BucketPlan, BucketSpace, DynamicShapeFunction,
                  OptimizeReport, Program, ProgramVM, SpecializationTable,
                  build_bucket_space, lower_plan, optimize, scan,
                  symbolic_dim, symbolic_dims)

__all__ = ["DynamicShapeFunction", "OptimizeReport", "optimize", "scan",
           "symbolic_dim", "symbolic_dims",
           "BucketSpace", "SpecializationTable", "BucketPlan",
           "build_bucket_space",
           "Program", "ProgramVM", "lower_plan"]
