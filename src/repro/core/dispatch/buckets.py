"""Shape-space partitioning for plan specialization (dispatch stage 1).

One schedule/remat/arena plan for a whole declared range (`s ∈ [16, 4096]`)
pays worst-case conservatism at `s = 32`.  BladeDISC++ resolves what the
compile time cannot decide at runtime; SoD²-style pre-partitioning goes the
other way: split the declared shape space into *buckets*, give each bucket
its own tighter ``BoundEnv``, and let the compile-time pipeline decide more
per bucket.  This module owns the partition itself:

* ``DimBuckets`` — one dim's range cut into contiguous integer sub-ranges,
  represented by the ascending list of inclusive *upper* edges (the last
  edge may be ``None`` for a range with no declared upper bound).  Lookup
  is ``bisect`` over the edges — O(log n) per dim — and a value sitting
  exactly on an edge deterministically lands in the **lower** bucket
  (edges are inclusive).
* ``BucketSpace`` — the cross product over dims; a concrete env maps to a
  key ``(i_0, i_1, ...)``, one index per dim in sorted-name order.
* ``build_bucket_space`` — builds the partition from declared dim ranges
  and the user's ``optimize(..., buckets=...)`` spec: **geometric** by
  default (edges spaced by a constant ratio, matching how activation
  memory scales with shape), or explicit per-dim cut points / counts.
"""
from __future__ import annotations

import itertools
from bisect import bisect_left
from dataclasses import dataclass
from typing import (Dict, Iterator, List, Mapping, Optional, Sequence, Tuple,
                    Union)

from ..symbolic.intervals import Interval

# default geometric bucket count per bounded dim for buckets="geometric"
DEFAULT_BUCKETS_PER_DIM = 4

BucketsSpec = Union[bool, int, str, Mapping[str, Union[int, Sequence[int]]]]


@dataclass(frozen=True)
class DimBuckets:
    """One dim's declared range split into contiguous integer sub-ranges.

    ``uppers`` are the inclusive upper edges, ascending; only the last may
    be ``None`` (no declared upper bound — the final bucket is open).
    Bucket ``i`` covers ``[lo, uppers[0]]`` for ``i == 0`` and
    ``[uppers[i-1] + 1, uppers[i]]`` after.
    """

    name: str
    lo: int
    uppers: Tuple[Optional[int], ...]

    def __post_init__(self) -> None:
        if not self.uppers:
            raise ValueError(f"dim {self.name!r}: empty bucket edge list")
        finite = [u for u in self.uppers if u is not None]
        if None in self.uppers[:-1]:
            raise ValueError(
                f"dim {self.name!r}: only the last edge may be open (None)")
        if any(b <= a for a, b in zip(finite, finite[1:])):
            raise ValueError(
                f"dim {self.name!r}: edges must be strictly ascending, "
                f"got {self.uppers}")
        if finite and finite[0] < self.lo:
            raise ValueError(
                f"dim {self.name!r}: first edge {finite[0]} below lo={self.lo}")

    @property
    def n(self) -> int:
        return len(self.uppers)

    def index_of(self, v: int) -> int:
        """Bucket index for a concrete dim value — O(log n) bisect.

        Values on an edge land in the lower bucket (edges are inclusive
        upper bounds), so dispatch at a boundary is deterministic.  Values
        outside the partition raise: silently clamping into an edge bucket
        would group an out-of-contract request under a bucket whose plan
        (and arena bound) does not cover it.
        """
        if v < self.lo or (self.uppers[-1] is not None
                           and v > self.uppers[-1]):
            hi = "inf" if self.uppers[-1] is None else self.uppers[-1]
            raise ValueError(
                f"dim {self.name!r}={v} outside the bucketed range "
                f"[{self.lo}, {hi}]")
        finite = self.uppers[:-1] if self.uppers[-1] is None else self.uppers
        return min(bisect_left(finite, v), self.n - 1)

    def range_of(self, i: int) -> Interval:
        lo = self.lo if i == 0 else self.uppers[i - 1] + 1
        return Interval(lo, self.uppers[i])


@dataclass(frozen=True)
class BucketSpace:
    """Cross product of per-dim partitions; env -> bucket key lookup."""

    dims: Tuple[DimBuckets, ...]       # sorted by dim name

    @property
    def dim_names(self) -> Tuple[str, ...]:
        return tuple(d.name for d in self.dims)

    @property
    def n_buckets(self) -> int:
        out = 1
        for d in self.dims:
            out *= d.n
        return out

    def key_of(self, env: Mapping[str, int]) -> Tuple[int, ...]:
        """Bucket key for a concrete dim binding (one bisect per dim)."""
        try:
            return tuple(d.index_of(env[d.name]) for d in self.dims)
        except KeyError as e:
            raise KeyError(
                f"env {dict(env)!r} misses bucketed dim {e.args[0]!r}") from None

    def ranges_of(self, key: Tuple[int, ...]) -> Dict[str, Interval]:
        """The per-dim sub-ranges the bucket ``key`` covers."""
        if len(key) != len(self.dims):
            raise ValueError(f"key {key} does not match dims {self.dim_names}")
        return {d.name: d.range_of(i) for d, i in zip(self.dims, key)}

    def keys(self) -> Iterator[Tuple[int, ...]]:
        """All bucket keys, lexicographic."""
        return itertools.product(*(range(d.n) for d in self.dims))

    def describe(self, key: Tuple[int, ...]) -> str:
        parts = []
        for d, i in zip(self.dims, key):
            iv = d.range_of(i)
            hi = "inf" if iv.hi is None else str(iv.hi)
            parts.append(f"{d.name}∈[{iv.lo},{hi}]")
        return " ".join(parts)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        body = ", ".join(f"{d.name}:{d.n}" for d in self.dims)
        return f"BucketSpace({body}; {self.n_buckets} buckets)"


def _nearest_nth_root(p: int, n: int) -> int:
    """Nearest integer to the real ``n``-th root of ``p``, exactly.

    Pure integer arithmetic: the float seed is only a starting guess and
    is corrected by exact comparisons, so every process computes the same
    value regardless of libm/FPU differences.
    """
    r = max(int(round(p ** (1.0 / n))), 0)
    while r > 0 and r ** n > p:
        r -= 1
    while (r + 1) ** n <= p:
        r += 1
    # round toward the real root: root >= r + 1/2  iff  2^n * p >= (2r+1)^n
    return r + 1 if (2 ** n) * p >= (2 * r + 1) ** n else r


def _geometric_uppers(lo: int, hi: int, n: int) -> Tuple[int, ...]:
    """``n`` edges spaced by a constant ratio from ``lo`` to ``hi``.

    Each interior edge is the nearest integer to ``(lo^(n-k) * hi^k)^(1/n)``
    computed in exact integer arithmetic — identical on every host, so SPMD
    programs that each build their own :class:`SpecializationTable` from the
    same spec are guaranteed to dispatch any in-range env to the same bucket
    (a float-pow formulation can round an edge differently across machines
    and silently split replicas across buckets).

    Degenerate ranges / counts collapse buckets rather than erroring:
    edges that round onto a previous edge are dropped.
    """
    lo = max(lo, 1)
    if n <= 1 or hi <= lo:
        return (hi,)
    uppers: List[int] = []
    prev = lo - 1
    for k in range(1, n):
        u = _nearest_nth_root(lo ** (n - k) * hi ** k, n)
        if u <= prev or u >= hi:
            continue
        uppers.append(u)
        prev = u
    uppers.append(hi)
    return tuple(uppers)


def _dim_buckets(name: str, iv: Interval,
                 spec: Union[None, int, Sequence[int]],
                 default_n: int) -> DimBuckets:
    lo = 1 if iv.lo is None else iv.lo
    if spec is None:                       # un-bucketed dim: one bucket
        return DimBuckets(name, lo, (iv.hi,))
    if isinstance(spec, bool):
        raise TypeError(f"buckets[{name!r}] must be an int count or a "
                        f"sequence of edges, got {spec!r}")
    if isinstance(spec, int):
        if spec < 1:
            raise ValueError(f"buckets[{name!r}] must be >= 1, got {spec}")
        if iv.hi is None:
            raise ValueError(
                f"dim {name!r} has no declared upper bound; geometric "
                f"bucketing needs one — pass explicit edges instead")
        return DimBuckets(name, lo, _geometric_uppers(lo, iv.hi, spec))
    # explicit interior cut points; the final bucket runs to the declared hi
    edges = sorted(int(e) for e in spec)
    if any(e < lo for e in edges):
        raise ValueError(f"buckets[{name!r}]: edge below declared lo={lo}")
    if iv.hi is not None:
        edges = [e for e in edges if e < iv.hi]
    uppers = tuple(dict.fromkeys(edges)) + (iv.hi,)
    return DimBuckets(name, lo, uppers)


def build_bucket_space(declared_ranges: Mapping[str, Interval],
                       spec: BucketsSpec, *,
                       default_n: int = DEFAULT_BUCKETS_PER_DIM) -> BucketSpace:
    """Build the partition from declared dim ranges and a ``buckets=`` spec.

    ``spec`` forms:

    * ``True`` or ``"geometric"`` — every dim with a declared upper bound
      gets ``default_n`` geometric buckets; unbounded dims keep one bucket;
    * an ``int`` — geometric with that count per bounded dim;
    * a mapping ``{dim: count | [edges...]}`` — per-dim control; edges are
      interior cut points (the final bucket runs to the declared upper
      bound); dims absent from the mapping keep one bucket.
    """
    if not declared_ranges:
        raise ValueError(
            "buckets requires declared dim ranges — pass "
            "optimize(..., dynamic_dims={...}) alongside buckets=...")
    per_dim: Dict[str, Union[None, int, Sequence[int]]] = {}
    if spec is True or spec == "geometric":
        per_dim = {name: default_n if iv.hi is not None else None
                   for name, iv in declared_ranges.items()}
    elif isinstance(spec, bool):           # False slipped through
        raise ValueError("buckets=False is not a partition; omit the arg")
    elif isinstance(spec, int):
        per_dim = {name: spec if iv.hi is not None else None
                   for name, iv in declared_ranges.items()}
    elif isinstance(spec, Mapping):
        unknown = sorted(set(spec) - set(declared_ranges))
        if unknown:
            raise ValueError(
                f"buckets names {unknown} carry no declared range "
                f"(declared: {sorted(declared_ranges)})")
        per_dim = {name: spec.get(name) for name in declared_ranges}
    else:
        raise TypeError(f"unrecognized buckets spec {spec!r}")
    dims = tuple(_dim_buckets(name, declared_ranges[name], per_dim[name],
                              default_n)
                 for name in sorted(declared_ranges))
    space = BucketSpace(dims)
    if space.n_buckets <= 1:
        raise ValueError(
            "buckets spec produced a single bucket — the partition would "
            "only duplicate the whole-range plan; widen the spec or drop it")
    return space
