"""Per-bucket plan cache (dispatch stage 2).

``SpecializationTable`` maps bucket keys to compiled :class:`BucketPlan`s —
each one a full schedule → remat → memplan pipeline run under the bucket's
tighter bound env, then **lowered** to a flat executable ``Program`` with
a ready ``ProgramVM`` (the reference interpreter under
``executor="reference"``).  Compilation is **lazy**: a bucket specializes
the first time traffic lands in it (or through an explicit synchronous
``warmup(envs)``), and the table retains at most ``max_live`` plans with
LRU eviction — an evicted bucket recompiles on its next use, it does not
error.  The hit path is a dict probe after the O(log n) per-dim key
lookup: it never re-runs scheduling, remat search, memory planning, or
lowering.

The table also answers ``arena_bound_bytes(key)`` — the bucket plan's
guaranteed worst-case arena size over the bucket's sub-ranges — which the
serving path uses for admission control by bucket (see
``repro.launch.serve.BucketBatcher``).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Tuple)

from ..symbolic.intervals import Interval
from .buckets import BucketSpace

BucketKey = Tuple[int, ...]


@dataclass
class BucketPlan:
    """One bucket's compiled artifact: plan + report + ready executor.

    With the default VM executor the table caches the *lowered* artifact,
    not just the plan: ``program`` is the bucket's flat instruction
    :class:`~repro.core.lowering.Program` (``None`` under
    ``executor="reference"``) and ``interp`` is the runner bound to it —
    a ``ProgramVM``, or the reference ``PlanInterpreter``.  A dispatch
    hit therefore lands on an executable whose sizes/params/offsets
    resolve once per env, never on a plan that re-derives them per op."""

    key: BucketKey
    ranges: Dict[str, Interval]       # the sub-ranges this plan assumes
    plan: Any                         # ExecutionPlan
    report: Any                       # OptimizeReport for this bucket
    interp: Any                       # ProgramVM / PlanInterpreter runner
    program: Any = None               # lowered Program (VM executor only)

    @property
    def arena_bound_bytes(self) -> Optional[int]:
        return self.report.arena_bound_bytes

    @property
    def n_instructions(self) -> Optional[int]:
        """Instruction count of the lowered Program (observability)."""
        return None if self.program is None else self.program.n_instructions


class SpecializationTable:
    """Lazy bucket-key -> BucketPlan cache with LRU retention.

    ``compile_fn(key, ranges)`` runs the full pipeline for one bucket and
    returns a :class:`BucketPlan`; the table owns laziness, retention, and
    the dispatch counters (``hits``/``misses``/``specialize_count``/
    ``evictions``).  ``specialize_count`` counts *compilations* — it grows
    on first use and on recompilation after LRU eviction, never on a hit.
    """

    def __init__(self, space: BucketSpace,
                 compile_fn: Callable[[BucketKey, Dict[str, Interval]],
                                      BucketPlan],
                 *, max_live: int = 16):
        if max_live < 1:
            raise ValueError(f"max_live must be >= 1, got {max_live}")
        self.space = space
        self.max_live = max_live
        self._compile_fn = compile_fn
        self._plans: "OrderedDict[BucketKey, BucketPlan]" = OrderedDict()
        # bounds survive plan eviction: once a bucket has compiled, its
        # guaranteed arena bound is a fact about the bucket, not the cache —
        # admission control must not recompile (or evict a hot plan) to
        # re-learn it
        self._bounds: Dict[BucketKey, Optional[int]] = {}
        self.hits = 0
        self.misses = 0
        self.specialize_count = 0
        self.evictions = 0

    # -- dispatch --------------------------------------------------------------
    def key_of(self, env: Mapping[str, int]) -> BucketKey:
        return self.space.key_of(env)

    def lookup(self, env: Mapping[str, int]) -> Tuple[BucketPlan, bool]:
        """Dispatch an env: ``(plan, hit)``.  Miss compiles the bucket."""
        key = self.space.key_of(env)
        bp = self._plans.get(key)
        if bp is not None:
            self.hits += 1
            self._plans.move_to_end(key)
            return bp, True
        self.misses += 1
        return self._specialize(key), False

    def get(self, key: BucketKey) -> BucketPlan:
        """Plan for a bucket key, compiling if needed (no hit/miss stats)."""
        bp = self._plans.get(key)
        if bp is not None:
            self._plans.move_to_end(key)
            return bp
        return self._specialize(key)

    def peek(self, key: BucketKey) -> Optional[BucketPlan]:
        """Cached plan or ``None`` — never compiles, never reorders LRU."""
        return self._plans.get(key)

    def _specialize(self, key: BucketKey) -> BucketPlan:
        bp = self._compile_fn(key, self.space.ranges_of(key))
        self.specialize_count += 1
        self._bounds[key] = bp.arena_bound_bytes
        self._plans[key] = bp
        while len(self._plans) > self.max_live:
            self._plans.popitem(last=False)
            self.evictions += 1
        return bp

    # -- warmup & introspection ------------------------------------------------
    def warmup(self, envs: Iterable[Mapping[str, int]]) -> List[BucketKey]:
        """Compile the buckets containing ``envs`` before traffic arrives.

        Synchronous and idempotent (already-compiled buckets are skipped);
        returns the distinct bucket keys now resident, in first-seen order.
        """
        keys: List[BucketKey] = []
        for env in envs:
            key = self.space.key_of(env)
            if key not in keys:
                keys.append(key)
                self.get(key)
        return keys

    def arena_bound_bytes(self, key: BucketKey) -> Optional[int]:
        """Guaranteed worst-case arena size over the bucket's sub-ranges.

        Bounds are remembered across LRU eviction, so only a bucket never
        compiled before pays a pipeline run here; a known bucket answers
        from the bound cache without touching (or evicting from) the plan
        cache."""
        if key in self._bounds:
            return self._bounds[key]
        return self.get(key).arena_bound_bytes

    @property
    def compiled_keys(self) -> List[BucketKey]:
        return list(self._plans)

    @property
    def n_buckets(self) -> int:
        return self.space.n_buckets

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "specialize_count": self.specialize_count,
                "evictions": self.evictions,
                "resident": len(self._plans)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"SpecializationTable({self.space!r}, "
                f"resident={len(self._plans)}/{self.max_live}, "
                f"hits={self.hits}, specializations={self.specialize_count})")
