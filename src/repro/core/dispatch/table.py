"""Per-bucket plan cache (dispatch stage 2).

``SpecializationTable`` maps bucket keys to compiled :class:`BucketPlan`s —
each one a full schedule → remat → memplan pipeline run under the bucket's
tighter bound env, then **lowered** to a flat executable ``Program`` with
a ready ``ProgramVM`` (the reference interpreter under
``executor="reference"``).  Compilation is **lazy**: a bucket specializes
the first time traffic lands in it (or through an explicit synchronous
``warmup(envs)``), and the table retains at most ``max_live`` plans with
LRU eviction — an evicted bucket recompiles on its next use, it does not
error.  The hit path is a dict probe after the O(log n) per-dim key
lookup: it never re-runs scheduling, remat search, memory planning, or
lowering.

With ``background=True`` (``optimize(..., background_specialize=True)``)
a miss does not compile on the calling thread either: the request is
answered immediately with the **whole-range fallback plan** — valid for
every in-range env, it is the plan a bucket-less deployment would run —
while a single background worker compiles the bucket and atomically swaps
the finished :class:`BucketPlan` into the table.  Subsequent traffic in
that bucket hits the specialized plan.  ``warmup`` stays a synchronous,
deterministic join (it waits for in-flight compiles rather than starting
duplicates), and ``drain_background`` blocks until every in-flight
specialization lands — after it returns, ``specialize_count`` matches
what synchronous compilation would have produced.

Specialization failures are **quarantined**, not fatal: every compile —
sync, background, or recompile — runs under a per-bucket
:class:`~repro.core.resilience.quarantine.CircuitBreaker`.  A failure
(or a compile exceeding ``compile_timeout_s``) opens the breaker for an
exponentially-backed-off window during which the bucket is not
recompiled; in background mode the whole-range fallback keeps serving
its traffic with bitwise-identical results, while synchronous touches
raise :class:`BucketQuarantined`.  When the window elapses, the next
miss becomes a single half-open probe compile — success swaps the
specialized plan in and closes the breaker, failure re-opens it with
the backoff doubled.  A transiently-faulty bucket therefore heals on
its own; a deterministically-broken one degrades to the fallback
instead of crashing the serve loop or burning a core on retries.

The table also answers ``arena_bound_bytes(key)`` — the bucket plan's
guaranteed worst-case arena size over the bucket's sub-ranges — which the
serving path uses for admission control by bucket (see
``repro.launch.serve.BucketBatcher``).  In background mode an unknown
bound does not stall the caller: the whole-range bound (a sound guarantee
for *every* bucket) is returned while the exact bucket bound compiles in
the background.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor, wait as futures_wait
from dataclasses import dataclass
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Tuple)

from ..resilience.faults import CompileTimeout
from ..resilience.quarantine import BucketQuarantined, CircuitBreaker
from ..symbolic.intervals import Interval
from .buckets import BucketSpace

BucketKey = Tuple[int, ...]

# Background-compile deferral: the pipeline is Python-heavy, so under the
# GIL a compile running concurrently with request execution inflates serve
# latency.  The worker waits for the dispatch path to go quiet (no request
# executing) before it starts, polling every _BACKGROUND_POLL_S, but never
# defers longer than _BACKGROUND_MAX_DEFER_S — a saturated server still
# gets its specializations.
_BACKGROUND_POLL_S = 0.005
_BACKGROUND_MAX_DEFER_S = 2.0


@dataclass
class BucketPlan:
    """One bucket's compiled artifact: plan + report + ready executor.

    With the default VM executor the table caches the *lowered* artifact,
    not just the plan: ``program`` is the bucket's flat instruction
    :class:`~repro.core.lowering.Program` (``None`` under
    ``executor="reference"``) and ``interp`` is the runner bound to it —
    a ``ProgramVM``, or the reference ``PlanInterpreter``.  A dispatch
    hit therefore lands on an executable whose sizes/params/offsets
    resolve once per env, never on a plan that re-derives them per op.

    ``key is None`` marks the whole-range *fallback* plan a background
    table serves on a miss while the bucket compiles."""

    key: Optional[BucketKey]
    ranges: Dict[str, Interval]       # the sub-ranges this plan assumes
    plan: Any                         # ExecutionPlan
    report: Any                       # OptimizeReport for this bucket
    interp: Any                       # ProgramVM / PlanInterpreter runner
    program: Any = None               # lowered Program (VM executor only)

    @property
    def arena_bound_bytes(self) -> Optional[int]:
        return self.report.arena_bound_bytes

    @property
    def n_instructions(self) -> Optional[int]:
        """Instruction count of the lowered Program (observability)."""
        return None if self.program is None else self.program.n_instructions


class SpecializationTable:
    """Lazy bucket-key -> BucketPlan cache with LRU retention.

    ``compile_fn(key, ranges)`` runs the full pipeline for one bucket and
    returns a :class:`BucketPlan`; the table owns laziness, retention, and
    the dispatch counters (``hits``/``misses``/``specialize_count``/
    ``evictions``).  ``specialize_count`` counts *compilations* — it grows
    on first use and on recompilation after LRU eviction, never on a hit.

    All bookkeeping is lock-protected so a background worker can install
    plans while the dispatch path reads; compilations themselves are
    serialized through a dedicated lock (the pipeline mutates shared
    ShapeGraph memo tables).
    """

    def __init__(self, space: BucketSpace,
                 compile_fn: Callable[[BucketKey, Dict[str, Interval]],
                                      BucketPlan],
                 *, max_live: int = 16,
                 background: bool = False,
                 fallback: Optional[BucketPlan] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 compile_timeout_s: Optional[float] = None):
        if max_live < 1:
            raise ValueError(f"max_live must be >= 1, got {max_live}")
        if background and fallback is None:
            raise ValueError(
                "background=True requires a whole-range fallback plan")
        self.space = space
        self.max_live = max_live
        self._compile_fn = compile_fn
        self._plans: "OrderedDict[BucketKey, BucketPlan]" = OrderedDict()
        # bounds survive plan eviction: once a bucket has compiled, its
        # guaranteed arena bound is a fact about the bucket, not the cache —
        # admission control must not recompile (or evict a hot plan) to
        # re-learn it
        self._bounds: Dict[BucketKey, Optional[int]] = {}
        self.hits = 0
        self.misses = 0
        self.specialize_count = 0
        self.evictions = 0
        # per-bucket dispatch distribution (observability: Prometheus
        # gauges, explain()); keys accumulate forever like _bounds
        self.hits_by_key: Dict[BucketKey, int] = {}
        self.misses_by_key: Dict[BucketKey, int] = {}
        # background specialization
        self.background = background
        self.fallback = fallback
        self.fallback_serves = 0          # misses answered by the fallback
        self._lock = threading.RLock()    # table bookkeeping
        self._compile_lock = threading.Lock()  # serializes pipeline runs
        self._pool: Optional[ThreadPoolExecutor] = None
        self._inflight: Dict[BucketKey, Future] = {}
        # buckets whose compile raised (or timed out) are *quarantined*
        # behind a circuit breaker rather than failed forever: the breaker
        # opens on failure, the fallback keeps serving the bucket's
        # traffic, and after an exponentially-backed-off interval a single
        # half-open probe recompiles.  A transient compile fault (OOM on
        # the compile host, an injected chaos fault) therefore heals; a
        # deterministic pipeline bug re-opens on every probe without
        # burning a core in a retry loop.
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.compile_timeout_s = compile_timeout_s
        # requests currently executing (see request_began/request_ended):
        # the background worker defers compiles while this is nonzero
        self._serving = 0

    # -- dispatch --------------------------------------------------------------
    def key_of(self, env: Mapping[str, int]) -> BucketKey:
        return self.space.key_of(env)

    def lookup(self, env: Mapping[str, int]) -> Tuple[BucketPlan, bool]:
        """Dispatch an env: ``(plan, hit)``.

        A miss compiles the bucket synchronously — or, in background mode,
        schedules the compile on the worker and returns the whole-range
        fallback plan immediately (``hit`` is still ``False``)."""
        key = self.space.key_of(env)
        with self._lock:
            bp = self._plans.get(key)
            if bp is not None:
                self.hits += 1
                self.hits_by_key[key] = self.hits_by_key.get(key, 0) + 1
                self._plans.move_to_end(key)
                return bp, True
            self.misses += 1
            self.misses_by_key[key] = self.misses_by_key.get(key, 0) + 1
            if self.background:
                self._submit_background(key)
                self.fallback_serves += 1
                return self.fallback, False
        return self._specialize(key), False

    def get(self, key: BucketKey) -> BucketPlan:
        """Plan for a bucket key, compiling if needed (no hit/miss stats).

        Synchronous even on a background table: an in-flight background
        compile is awaited rather than duplicated.  A quarantined bucket
        (breaker open after a compile failure) raises
        :class:`BucketQuarantined` instead of compiling."""
        with self._lock:
            bp = self._plans.get(key)
            if bp is not None:
                self._plans.move_to_end(key)
                return bp
            fut = self._inflight.get(key)
        if fut is not None:
            fut.result()                  # join; failures live on the breaker
            with self._lock:
                bp = self._plans.get(key)
            if bp is not None:
                return bp
        return self._specialize(key)

    def peek(self, key: BucketKey) -> Optional[BucketPlan]:
        """Cached plan or ``None`` — never compiles, never reorders LRU."""
        with self._lock:
            return self._plans.get(key)

    def _specialize(self, key: BucketKey) -> BucketPlan:
        if not self.breaker.allow(key):
            raise BucketQuarantined(key, self.breaker.cause(key),
                                    self.breaker.retry_in_s(key))
        with self._compile_lock:
            with self._lock:              # a racer may have installed it
                bp = self._plans.get(key)
            if bp is not None:
                # the probe ticket (if any) resolves in the racer's favor
                self.breaker.record_success(key)
                return bp
            bp = self._timed_compile(key)
            # install before releasing the compile lock: a background
            # worker acquiring it next must see the bucket as resident
            self._install(key, bp)
        self.breaker.record_success(key)
        return bp

    def _timed_compile(self, key: BucketKey) -> BucketPlan:
        """One pipeline run under the breaker's watch.

        Exceptions and over-budget compiles record a failure on the
        breaker (tripping quarantine) and re-raise; a timed-out plan is
        discarded even though it finished — a compile that blows its
        budget signals a bucket whose pipeline cost is pathological, and
        serving its plan would hide that.  The caller records success
        only after the plan is installed."""
        t0 = time.monotonic()
        try:
            bp = self._compile_fn(key, self.space.ranges_of(key))
        except Exception as e:
            self.breaker.record_failure(key, e)
            raise
        elapsed = time.monotonic() - t0
        if self.compile_timeout_s is not None \
                and elapsed > self.compile_timeout_s:
            exc = CompileTimeout(
                f"bucket {key} specialization took {elapsed:.3f}s, over "
                f"the {self.compile_timeout_s}s budget; plan discarded")
            self.breaker.record_failure(key, exc)
            raise exc
        return bp

    def _install(self, key: BucketKey, bp: BucketPlan) -> None:
        """Atomically swap a compiled plan into the table (LRU applies)."""
        with self._lock:
            self.specialize_count += 1
            self._bounds[key] = bp.arena_bound_bytes
            self._plans[key] = bp
            self._plans.move_to_end(key)
            while len(self._plans) > self.max_live:
                self._plans.popitem(last=False)
                self.evictions += 1

    # -- background specialization ---------------------------------------------
    def _submit_background(self, key: BucketKey) -> None:
        """Schedule one compile for ``key`` unless resident, in flight, or
        quarantined.  The breaker gate is what turns every miss into a
        free re-probe opportunity: while open it answers ``False`` (the
        fallback keeps serving), and once the backoff elapses the next
        miss through here becomes the half-open probe compile.  Caller
        holds ``self._lock``."""
        if key in self._plans or key in self._inflight:
            return
        if not self.breaker.allow(key):
            return
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="specialize")
        fut = self._pool.submit(self._compile_and_install, key)
        self._inflight[key] = fut

    def request_began(self) -> None:
        """Dispatch path: a request is about to execute its plan."""
        with self._lock:
            self._serving += 1

    def request_ended(self) -> None:
        with self._lock:
            self._serving -= 1

    def _compile_and_install(self, key: BucketKey) -> Optional[BucketKey]:
        try:
            # defer (bounded) until no request is mid-execution, so the
            # Python-heavy pipeline never steals the GIL from a serve
            deadline = time.monotonic() + _BACKGROUND_MAX_DEFER_S
            while time.monotonic() < deadline:
                with self._lock:
                    busy = self._serving > 0
                if not busy:
                    break
                time.sleep(_BACKGROUND_POLL_S)
            with self._compile_lock:
                with self._lock:
                    resident = key in self._plans
                if not resident:
                    bp = self._timed_compile(key)
                    self._install(key, bp)
            self.breaker.record_success(key)
            return key
        except Exception:
            # already recorded on the breaker by _timed_compile: the
            # bucket is quarantined and the fallback keeps serving it.
            # Swallow so the worker thread survives and joiners
            # (drain_background, get) see a clean future.
            return None
        finally:
            with self._lock:
                self._inflight.pop(key, None)

    def recompile(self, key: BucketKey, *, background: bool = False
                  ) -> Optional[BucketPlan]:
        """Force one bucket through the pipeline again and swap the result.

        The re-selection half of the kernel measured fallback: the caller
        updates what ``compile_fn`` will decide (e.g. a forced kernel
        variant per node), then this rebuilds the bucket's plan and
        atomically installs it — concurrent dispatch keeps hitting the old
        plan until the instant of the swap.  ``background=True`` runs the
        rebuild on the worker (requires a background table) and returns
        ``None``; synchronous calls return the fresh plan."""
        if background:
            if not self.background:
                raise ValueError(
                    "recompile(background=True) requires a background table")
            with self._lock:
                if key in self._inflight:
                    return None
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=1, thread_name_prefix="specialize")
                # bypass _submit_background's residency check: the point
                # is to replace the resident plan
                fut = self._pool.submit(self._recompile_and_install, key)
                self._inflight[key] = fut
            return None
        with self._compile_lock:
            bp = self._timed_compile(key)
            self._install(key, bp)
        self.breaker.record_success(key)
        return bp

    def _recompile_and_install(self, key: BucketKey) -> Optional[BucketKey]:
        try:
            with self._compile_lock:
                bp = self._timed_compile(key)
                self._install(key, bp)
            self.breaker.record_success(key)
            return key
        except Exception:
            # recorded on the breaker by _timed_compile; keep the worker
            # alive and the future clean (see _compile_and_install)
            return None
        finally:
            with self._lock:
                self._inflight.pop(key, None)

    @property
    def n_pending(self) -> int:
        """Background specializations currently in flight."""
        with self._lock:
            return len(self._inflight)

    def drain_background(self, timeout: Optional[float] = None) -> List[BucketKey]:
        """Block until every background compile in flight *at call time*
        lands (compiles submitted by traffic arriving mid-drain belong to
        the next drain, so the call is bounded under sustained misses).

        Returns the drained bucket keys (first-submitted order).
        Compile failures do not raise here: a failed compile quarantines
        its bucket on the breaker (see :meth:`quarantined`) while the
        fallback keeps serving — the drain is a join, not a health check.
        ``timeout`` is one global deadline for the whole drain.  After a
        clean drain the table state is indistinguishable from having
        compiled those buckets synchronously."""
        with self._lock:
            snapshot = dict(self._inflight)
        deadline = None if timeout is None else time.monotonic() + timeout
        drained: List[BucketKey] = []
        for key, fut in snapshot.items():
            remaining = None if deadline is None                 else max(0.0, deadline - time.monotonic())
            done, not_done = futures_wait([fut], timeout=remaining)
            if not_done:
                raise TimeoutError(
                    f"background specialization of bucket {key} still "
                    f"pending after {timeout}s (drained so far: {drained})")
            drained.append(key)
            fut.result()                  # join; failures live on the breaker
        return drained

    def quarantined(self) -> List[BucketKey]:
        """Buckets currently quarantined (breaker open or half-open)."""
        return self.breaker.quarantined_keys()

    # -- warmup & introspection ------------------------------------------------
    def warmup(self, envs: Iterable[Mapping[str, int]]) -> List[BucketKey]:
        """Compile the buckets containing ``envs`` before traffic arrives.

        Synchronous and idempotent (already-compiled buckets are skipped,
        in-flight background compiles are awaited, not duplicated); returns
        the distinct bucket keys now resident, in first-seen order.
        """
        keys: List[BucketKey] = []
        for env in envs:
            key = self.space.key_of(env)
            if key not in keys:
                keys.append(key)
                self.get(key)
        return keys

    def arena_bound_bytes(self, key: BucketKey) -> Optional[int]:
        """Guaranteed worst-case arena size over the bucket's sub-ranges.

        Bounds are remembered across LRU eviction, so only a bucket never
        compiled before pays a pipeline run here; a known bucket answers
        from the bound cache without touching (or evicting from) the plan
        cache.  A background table never stalls the caller: an unknown
        bucket bound schedules the compile and conservatively answers with
        the whole-range bound, which every bucket is guaranteed to fit."""
        with self._lock:
            if key in self._bounds:
                return self._bounds[key]
            if self.background:
                self._submit_background(key)
                return self.fallback.arena_bound_bytes
        return self.get(key).arena_bound_bytes

    @property
    def compiled_keys(self) -> List[BucketKey]:
        with self._lock:
            return list(self._plans)

    @property
    def n_buckets(self) -> int:
        return self.space.n_buckets

    def per_bucket_stats(self) -> Dict[BucketKey, Dict[str, Any]]:
        """Per-bucket dispatch distribution + known arena bounds — every
        bucket traffic has ever touched, resident or evicted."""
        with self._lock:
            keys = set(self.hits_by_key) | set(self.misses_by_key) \
                | set(self._bounds)
            return {k: {"hits": self.hits_by_key.get(k, 0),
                        "misses": self.misses_by_key.get(k, 0),
                        "arena_bound_bytes": self._bounds.get(k),
                        "resident": k in self._plans}
                    for k in sorted(keys)}

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "specialize_count": self.specialize_count,
                    "evictions": self.evictions,
                    "resident": len(self._plans),
                    "fallback_serves": self.fallback_serves,
                    "background_pending": len(self._inflight),
                    "background_failed":
                        len(self.breaker.quarantined_keys())}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"SpecializationTable({self.space!r}, "
                f"resident={len(self._plans)}/{self.max_live}, "
                f"hits={self.hits}, specializations={self.specialize_count})")
