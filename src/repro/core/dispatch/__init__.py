"""Shape-bucketed plan specialization & dispatch (beyond-paper).

Partitions the declared dynamic-shape space into buckets, re-runs the
compile-time pipeline once per bucket under the bucket's tighter bounds,
and dispatches each call to its bucket's plan in O(log n) per dim — the
compilation–runtime split of BladeDISC++ sharpened by SoD²-style
shape-space pre-partitioning.
"""
from .buckets import (DEFAULT_BUCKETS_PER_DIM, BucketSpace, BucketsSpec,
                      DimBuckets, build_bucket_space)
from .table import BucketKey, BucketPlan, SpecializationTable

__all__ = [
    "DEFAULT_BUCKETS_PER_DIM", "BucketSpace", "BucketsSpec", "DimBuckets",
    "build_bucket_space",
    "BucketKey", "BucketPlan", "SpecializationTable",
]
