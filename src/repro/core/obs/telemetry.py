"""Runtime telemetry: a per-call ring buffer with a strict overhead budget.

The contract, enforced by ``tests/test_obs.py`` and ``benchmarks/
obs_bench.py``: with telemetry *disabled* the dispatch hot path pays one
attribute load and an ``is None`` test — no allocation, no locking, no
dict probe — and stays within 2% of the uninstrumented baseline on the
exec_bench dispatch-chain microbench.  With telemetry *enabled*, each
call appends one :class:`CallRecord` to a fixed-capacity ring.

The ring takes one mutex per push: a serving deployment drives a single
``DynamicShapeFunction`` from many request threads (see the chaos suite),
so the write index must move atomically or concurrent pushes overwrite
one slot and double-count another.  The lock lives on the *enabled* path
only — the disabled path never reaches it — and is uncontended in
single-threaded use.  Readers (``records()``) snapshot under the same
lock; slots are replaced wholesale, so a reader can never observe a
partial record.

Per-instruction memory timelines are *not* sampled by instrumenting the
VM fast stream — that would put a branch in the hottest loop.  Because
the fast stream's memory traffic is fully determined by the env (the
``Program.resolve`` replay fact), an enabled sampler reconstructs the
exact timeline off the hot path via :func:`.timeline.actual_timeline`
every ``sample_timeline_every``-th call.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, NamedTuple, Optional, Tuple


class CallRecord(NamedTuple):
    """One dispatched call, as the ring stores it (flat, allocation-light)."""

    seq: int                            # 0-based call number
    bucket_key: Optional[Tuple]         # specialization bucket; None = unbucketed
    env: Tuple[Tuple[str, int], ...]    # sorted dim binding
    wall_s: float
    dispatch_ns: int                    # this call's dispatch overhead
    device_peak: int
    arena_bytes: int
    evictions: int
    recomputes: int
    reloads: int
    donated_reuses: int
    loop_trips: Tuple[int, ...]         # per rolled loop, program order


class TelemetryRing:
    """Fixed-capacity ring of :class:`CallRecord` (thread-safe)."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = capacity
        self._slots: List[Optional[CallRecord]] = [None] * capacity
        self._count = 0                 # monotonic; next write position
        self._lock = threading.Lock()

    def push(self, rec: CallRecord) -> None:
        with self._lock:
            self._slots[self._count % self.capacity] = rec
            self._count += 1

    def __len__(self) -> int:
        return min(self._count, self.capacity)

    @property
    def total_pushed(self) -> int:
        return self._count

    @property
    def dropped(self) -> int:
        """Records overwritten because the ring wrapped."""
        return max(0, self._count - self.capacity)

    def records(self) -> List[CallRecord]:
        """Oldest-to-newest snapshot of the retained records."""
        with self._lock:
            n, cap = self._count, self.capacity
            if n <= cap:
                return [r for r in self._slots[:n] if r is not None]
            start = n % cap
            out = self._slots[start:] + self._slots[:start]
            return [r for r in out if r is not None]


@dataclass(frozen=True)
class AdmissionEvent:
    """One admission-control decision by the serving batcher.

    ``outcome`` distinguishes what happened to the group/request:
    ``"held"`` (over-budget group deferred to a later drain),
    ``"shed-capacity"`` (queue full, request refused at submit),
    ``"shed-deadline"`` (request's deadline expired in queue),
    ``"shed-aged"`` (held group exceeded its max hold cycles)."""

    key: Tuple                          # bucket key (dim upper bounds)
    label: str                          # human-readable bucket label
    required_bytes: int                 # the group's arena_bound_bytes
    available_bytes: int                # the batcher's memory_budget
    queue_depth: int                    # requests held in this group
    outcome: str = "held"               # held | shed-capacity |
    #                                     shed-deadline | shed-aged


class Telemetry:
    """Per-function telemetry aggregate: ring + running totals + sampled
    timelines.  Created by ``DynamicShapeFunction.enable_telemetry()``.
    Counter updates are lock-protected — concurrent request threads must
    not lose increments (the lock is on the enabled path only)."""

    def __init__(self, capacity: int = 256, sample_timeline_every: int = 0,
                 max_timelines: int = 8):
        self.ring = TelemetryRing(capacity)
        self.sample_timeline_every = sample_timeline_every
        self.max_timelines = max_timelines
        self.n_calls = 0
        self.wall_s_total = 0.0
        self.dispatch_ns_total = 0
        self.calls_by_bucket: Dict[Optional[Tuple], int] = {}
        # (seq, timeline) pairs, newest kept; see .timeline.actual_timeline
        self.timelines: List[Tuple[int, Any]] = []
        self._lock = threading.Lock()

    def on_call(self, bucket_key: Optional[Tuple], report: Any, *,
                program: Any = None,
                loop_trips: Tuple[int, ...] = ()) -> None:
        """Record one dispatched call.  Runs only when telemetry is
        enabled — the disabled path never reaches this method."""
        st = report.stats
        with self._lock:
            seq = self.n_calls
            self.n_calls += 1
            self.wall_s_total += report.wall_s
            self.dispatch_ns_total += st.last_dispatch_ns
            self.calls_by_bucket[bucket_key] = \
                self.calls_by_bucket.get(bucket_key, 0) + 1
        self.ring.push(CallRecord(
            seq=seq, bucket_key=bucket_key,
            env=tuple(sorted(report.env.items())),
            wall_s=report.wall_s, dispatch_ns=st.last_dispatch_ns,
            device_peak=st.device_peak, arena_bytes=st.arena_bytes,
            evictions=st.evictions, recomputes=st.recomputes,
            reloads=st.reloads, donated_reuses=st.donated_reuses,
            loop_trips=loop_trips))
        every = self.sample_timeline_every
        if every and program is not None and seq % every == 0:
            from .timeline import actual_timeline
            self.timelines.append((seq, actual_timeline(program, report.env)))
            if len(self.timelines) > self.max_timelines:
                del self.timelines[:len(self.timelines) - self.max_timelines]

    def summary(self) -> Dict[str, Any]:
        return dict(
            n_calls=self.n_calls,
            wall_s_total=self.wall_s_total,
            dispatch_ns_total=self.dispatch_ns_total,
            ring_retained=len(self.ring),
            ring_dropped=self.ring.dropped,
            calls_by_bucket={str(k): v
                             for k, v in self.calls_by_bucket.items()},
            timelines_sampled=len(self.timelines))
