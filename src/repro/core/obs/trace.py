"""Compile-time tracing: hierarchical spans + a structured decision log.

The pipeline phases (trace, schedule, remat search, memory planning, peak
bounds, lowering, per-bucket specialization) each run under a
:meth:`Tracer.span` context.  Spans nest through a *thread-local* stack,
so a background-specialize worker's compile becomes its own root span
(tagged with the worker's thread id) instead of corrupting the main
thread's tree — Chrome trace viewers render the two as separate tracks.

Tracing is always on at compile time: a compile emits a handful of spans,
so the cost is nanoseconds against a pipeline that runs milliseconds.
The *runtime* hot path is a different story and never touches this module
(see :mod:`.telemetry` for the per-call ring buffer and its overhead
contract).

``DecisionLog`` records the compile decisions that are only observable
*while* they happen — exchange-pass swaps, the schedule guard's
keep-or-revert choice, incremental bucket reuse.  Decisions that are
fully recoverable from the finished plan (per-candidate remat methods,
per-slot reuse) are derived on demand by :mod:`.explain` instead of being
duplicated here.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional


@dataclass
class Span:
    """One timed phase: ``[t0_ns, t1_ns]`` plus structured attributes."""

    name: str
    t0_ns: int
    t1_ns: Optional[int] = None
    attrs: Dict[str, Any] = field(default_factory=dict)
    children: List["Span"] = field(default_factory=list)
    tid: int = 0                       # thread ident (Chrome trace track)
    thread_name: str = ""

    @property
    def duration_ns(self) -> int:
        return 0 if self.t1_ns is None else self.t1_ns - self.t0_ns

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, {self.duration_ns / 1e6:.2f}ms, "
                f"{len(self.children)} children)")


class Tracer:
    """Span sink for one ``optimize`` call and everything it compiles.

    ``span(name, **attrs)`` is a context manager; spans opened while
    another span is open on the *same thread* nest under it.  Root
    appends are lock-protected so background specialization workers and
    the dispatch thread can record concurrently; ``max_roots`` bounds
    memory on long-lived functions whose buckets recompile after LRU
    eviction (oldest roots drop first).
    """

    def __init__(self, max_roots: int = 256):
        self.roots: List[Span] = []
        self.max_roots = max_roots
        self._lock = threading.Lock()
        self._tls = threading.local()

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    @contextmanager
    def span(self, name: str, **attrs):
        th = threading.current_thread()
        s = Span(name=name, t0_ns=time.perf_counter_ns(), attrs=dict(attrs),
                 tid=th.ident or 0, thread_name=th.name)
        stack = self._stack()
        if stack:
            stack[-1].children.append(s)
        else:
            with self._lock:
                self.roots.append(s)
                if len(self.roots) > self.max_roots:
                    del self.roots[:len(self.roots) - self.max_roots]
        stack.append(s)
        try:
            yield s
        finally:
            s.t1_ns = time.perf_counter_ns()
            stack.pop()

    def spans(self) -> List[Span]:
        """Flat list of every recorded span (depth-first, roots in order)."""
        with self._lock:
            roots = list(self.roots)
        return [s for r in roots for s in r.walk()]

    def find(self, name: str) -> List[Span]:
        return [s for s in self.spans() if s.name == name]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Tracer({len(self.roots)} roots, {len(self.spans())} spans)"


class _NullSpan:
    """Absorbs attribute writes from instrumented code under NullTracer."""

    __slots__ = ("attrs",)

    def __init__(self):
        self.attrs: Dict[str, Any] = {}


class NullTracer:
    """No-op tracer: ``span()`` yields a throwaway span, records nothing.

    Used by pipeline entry points called without an ``optimize`` context
    (direct ``_compile_pipeline`` use in tests/benchmarks), so the
    instrumentation never needs ``if tracer`` guards."""

    @contextmanager
    def span(self, name: str, **attrs):
        yield _NullSpan()

    def spans(self) -> List[Span]:
        return []

    def find(self, name: str) -> List[Span]:
        return []


NULL_TRACER = NullTracer()


@dataclass(frozen=True)
class Decision:
    """One recorded compile decision.

    ``kind`` is a small vocabulary (``schedule-guard``, ``exchange-swap``,
    ``bucket-reuse``, ...); ``subject`` names what was decided about;
    ``choice`` what was picked; ``why`` the symbolic / measured
    justification; ``detail`` structured extras (peaks, keys, exprs as
    strings)."""

    kind: str
    subject: str
    choice: str
    why: str
    detail: Dict[str, Any] = field(default_factory=dict)


class DecisionLog:
    """Append-only, thread-safe, bounded decision record."""

    def __init__(self, max_entries: int = 4096):
        self.max_entries = max_entries
        self._entries: List[Decision] = []
        self._lock = threading.Lock()

    def add(self, kind: str, subject: str, choice: str, why: str,
            **detail) -> None:
        with self._lock:
            self._entries.append(Decision(kind, subject, choice, why, detail))
            if len(self._entries) > self.max_entries:
                del self._entries[:len(self._entries) - self.max_entries]

    def entries(self, kind: Optional[str] = None) -> List[Decision]:
        with self._lock:
            out = list(self._entries)
        if kind is not None:
            out = [d for d in out if d.kind == kind]
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"DecisionLog({len(self)} entries)"
