"""Export surfaces: Chrome-trace JSON and Prometheus-style text metrics.

``chrome_trace`` serializes a :class:`.trace.Tracer`'s span forest (plus,
optionally, sampled runtime timelines) into the Trace Event Format that
``chrome://tracing`` and Perfetto load directly: complete events
(``ph: "X"``) with microsecond timestamps, one track per recording thread
— so background-specialize compiles render alongside the main thread's
pipeline instead of interleaved with it.

``prometheus_text`` renders the text exposition format (``# HELP`` /
``# TYPE`` / samples) over a compiled function and/or a serve-path
``BucketBatcher`` — per-bucket hit/miss/admission-hold counters and
arena-bound gauges, ready for a ``/metrics`` endpoint.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .trace import Span


def _span_events(span: Span, pid: int, out: List[Dict[str, Any]]) -> None:
    out.append({
        "name": span.name,
        "ph": "X",
        "ts": span.t0_ns / 1e3,            # Trace Event ts unit: us
        "dur": span.duration_ns / 1e3,
        "pid": pid,
        "tid": span.tid,
        "args": {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                     else repr(v))
                 for k, v in span.attrs.items()},
    })
    for c in span.children:
        _span_events(c, pid, out)


def chrome_trace(tracer, timelines: Optional[List] = None,
                 pid: int = 1) -> Dict[str, Any]:
    """Trace Event Format dict for a Tracer (json.dump straight to disk).

    ``timelines``: optional ``(seq, Timeline)`` pairs (e.g.
    ``Telemetry.timelines``) appended as counter events (``ph: "C"``) so
    the memory curve renders under the compile spans."""
    events: List[Dict[str, Any]] = []
    thread_names: Dict[int, str] = {}
    for root in getattr(tracer, "roots", []):
        for s in root.walk():
            if s.tid not in thread_names and s.thread_name:
                thread_names[s.tid] = s.thread_name
        _span_events(root, pid, events)
    # thread metadata first, so viewers label tracks by thread name
    meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": name}} for tid, name in thread_names.items()]
    for seq, tl in (timelines or []):
        for pt in tl.points:
            events.append({
                "name": f"memory (call {seq})",
                "ph": "C",
                "ts": float(pt.idx),       # pseudo-time: program counter
                "pid": pid,
                "tid": 0,
                "args": {"device_used": pt.device_used,
                         "arena_in_use": pt.arena_in_use},
            })
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def chrome_trace_json(tracer, timelines: Optional[List] = None) -> str:
    return json.dumps(chrome_trace(tracer, timelines))


# -- Prometheus text exposition ------------------------------------------------

def _key_label(key) -> str:
    if key is None:
        return "whole_range"
    return "_".join(str(k) for k in key)


def _metric(lines: List[str], name: str, kind: str, help_text: str,
            samples: List) -> None:
    """Append one metric family; ``samples`` = [(labels_dict|None, value)]."""
    lines.append(f"# HELP {name} {help_text}")
    lines.append(f"# TYPE {name} {kind}")
    for labels, value in samples:
        if labels:
            lab = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
            lines.append(f"{name}{{{lab}}} {value}")
        else:
            lines.append(f"{name} {value}")


def prometheus_text(fn=None, batcher=None, prefix: str = "repro") -> str:
    """Text-format metrics snapshot for a compiled function and/or a
    serve-path batcher.  Safe to call concurrently with traffic (reads
    are snapshots under the table/batcher locks)."""
    lines: List[str] = []

    if fn is not None:
        table = fn.specialization_table
        if table is not None:
            st = table.stats()
            _metric(lines, f"{prefix}_bucket_hits_total", "counter",
                    "Dispatch hits per specialization bucket.",
                    [({"bucket": _key_label(k)}, row["hits"])
                     for k, row in table.per_bucket_stats().items()])
            _metric(lines, f"{prefix}_bucket_misses_total", "counter",
                    "Dispatch misses per specialization bucket.",
                    [({"bucket": _key_label(k)}, row["misses"])
                     for k, row in table.per_bucket_stats().items()])
            _metric(lines, f"{prefix}_bucket_arena_bound_bytes", "gauge",
                    "Guaranteed worst-case arena bytes per compiled bucket.",
                    [({"bucket": _key_label(k)}, row["arena_bound_bytes"])
                     for k, row in table.per_bucket_stats().items()
                     if row["arena_bound_bytes"] is not None])
            _metric(lines, f"{prefix}_specializations_total", "counter",
                    "Bucket pipeline compilations (incl. recompiles).",
                    [(None, st["specialize_count"])])
            _metric(lines, f"{prefix}_bucket_evictions_total", "counter",
                    "Bucket plans evicted by LRU retention.",
                    [(None, st["evictions"])])
        bound = fn.arena_bound_bytes
        if bound is not None:
            _metric(lines, f"{prefix}_arena_bound_bytes", "gauge",
                    "Whole-range guaranteed worst-case arena bytes.",
                    [(None, bound)])
        tel = fn.telemetry
        if tel is not None:
            _metric(lines, f"{prefix}_calls_total", "counter",
                    "Dispatched calls recorded by telemetry.",
                    [(None, tel.n_calls)])
            _metric(lines, f"{prefix}_dispatch_ns_total", "counter",
                    "Cumulative bucket-dispatch overhead in nanoseconds.",
                    [(None, tel.dispatch_ns_total)])
        res = getattr(fn, "resilience", None)
        if res is not None:
            rc = res.counters()
            _metric(lines, f"{prefix}_degraded_calls_total", "counter",
                    "Calls that walked at least one degradation rung.",
                    [(None, rc["degraded_calls"])])
            _metric(lines, f"{prefix}_retries_total", "counter",
                    "Degradation-ladder retries by rung.",
                    [({"rung": "transient"}, rc["retries_transient"]),
                     ({"rung": "fallback"}, rc["retries_fallback"])])
            _metric(lines, f"{prefix}_request_failures_total", "counter",
                    "Requests rejected after exhausting the ladder.",
                    [(None, rc["failures"])])
            _metric(lines, f"{prefix}_malformed_requests_total", "counter",
                    "Requests rejected as malformed (never retried).",
                    [(None, rc["malformed"])])
        table = fn.specialization_table
        if table is not None:
            bs = table.breaker.stats()["by_state"]
            _metric(lines, f"{prefix}_quarantined_buckets", "gauge",
                    "Buckets currently quarantined by the compile breaker.",
                    [(None, bs.get("open", 0) + bs.get("half-open", 0))])

    if batcher is not None:
        _metric(lines, f"{prefix}_batcher_pending", "gauge",
                "Requests queued in the batcher.",
                [(None, batcher.pending())])
        _metric(lines, f"{prefix}_batcher_held_total", "counter",
                "Bucket groups held back by admission control.",
                [(None, batcher.held_count)])
        held_by = getattr(batcher, "held_by_key", None)
        if held_by:
            _metric(lines, f"{prefix}_batcher_held_by_bucket_total",
                    "counter", "Admission-control holds per bucket.",
                    [({"bucket": _key_label(k)}, v)
                     for k, v in held_by.items()])
        shed_by = getattr(batcher, "shed_by_outcome", None)
        if shed_by is not None:
            _metric(lines, f"{prefix}_batcher_shed_total", "counter",
                    "Requests shed by the batcher, by reason.",
                    [({"outcome": k}, v)
                     for k, v in sorted(shed_by.items())] or
                    [(None, 0)])
    return "\n".join(lines) + "\n"
