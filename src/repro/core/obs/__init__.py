"""Plan-aware observability: compile tracing, runtime telemetry, exports.

Three coordinated layers over the symbolic-shape pipeline:

* :mod:`.trace` — hierarchical compile-phase spans + a structured
  decision log, recorded by ``optimize`` and every bucket specialization
  (background compiles included, on their own track);
* :mod:`.telemetry` — a fixed-capacity per-call ring buffer behind a
  single disabled-path attribute check (the ≤2% overhead contract), plus
  exact per-instruction memory timelines reconstructed off the hot path
  (:mod:`.timeline`: the plan's symbolic events replayed at one env and
  diffed against the plan's predicted occupancy);
* :mod:`.export` / :mod:`.explain` — Chrome-trace/Perfetto JSON,
  Prometheus text metrics, and the human-readable
  ``DynamicShapeFunction.explain()`` report.
"""
from .explain import build_explain
from .export import chrome_trace, chrome_trace_json, prometheus_text
from .telemetry import (AdmissionEvent, CallRecord, Telemetry,
                        TelemetryRing)
from .timeline import (Timeline, TimelineDiff, TimelinePoint,
                       actual_timeline, diff_timeline, planned_timeline)
from .trace import (NULL_TRACER, Decision, DecisionLog, NullTracer, Span,
                    Tracer)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span",
    "Decision", "DecisionLog",
    "Telemetry", "TelemetryRing", "CallRecord", "AdmissionEvent",
    "Timeline", "TimelinePoint", "TimelineDiff",
    "actual_timeline", "planned_timeline", "diff_timeline",
    "chrome_trace", "chrome_trace_json", "prometheus_text",
    "build_explain",
]
