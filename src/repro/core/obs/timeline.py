"""Plan-vs-actual memory timelines over a lowered ``Program``.

Two curves, built from *independent* sources so drift between them means
something:

* **predicted** — straight from the compile-time plan: the liveness
  intervals of :mod:`repro.core.memplan.liveness` evaluated at one env
  give the planned occupancy at every schedule step (plus, at a rolled
  loop's step, the loop's exact internal-peak delta from the shared
  event engine's trip models);
* **actual** — a replay of the lowered instruction stream through a real
  ``MemoryManager`` + ``ArenaAllocator`` pair, recording device / arena
  occupancy after every instruction (the program counter).  For the
  no-eviction regime this reconstruction is *exact*: the fast stream's
  alloc/free traffic is fully determined by the env (the same fact
  ``Program.resolve`` exploits to precompute ``MemoryStats``), so the
  curve equals what a live run's sampled occupancy would show, without
  instrumenting the hot loop.  Runs under memory pressure can instead
  sample live occupancy through the executors' ``timeline_hook``.

``diff_timeline`` correlates the two: peak comparison against the plan's
guaranteed ``arena_bound_bytes`` and an allocation-by-allocation audit —
every actual allocation must be *explained* by a planned liveness
interval covering its step with the same byte count (rolled-loop internal
buffers, keyed ``(nid, parity, bvid)``, are driven by the plan's own
event templates and audited against them by construction).  A non-empty
``unexplained`` list is the plan-vs-reality drift alarm the acceptance
gate checks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..executor.memory import MemoryManager
from ..lowering.program import (OP_BIND_ARG, OP_BIND_DIM, OP_COMPUTE,
                                OP_DONATE, OP_FREE_SLOT, OP_LOOP, OP_RETURN,
                                Program)
from ..memplan.arena import ArenaAllocator
from ..memplan.liveness import analyze_liveness


@dataclass(frozen=True)
class TimelinePoint:
    """Occupancy right after one instruction of the lowered stream."""

    idx: int                  # program counter (instruction index)
    step: int                 # schedule step of the governing Compute/Loop
    opname: str
    device_used: int
    arena_in_use: int


@dataclass
class Timeline:
    """One reconstructed (or sampled) occupancy curve."""

    env: Dict[str, int]
    points: List[TimelinePoint] = field(default_factory=list)
    peak_device: int = 0
    peak_arena_in_use: int = 0
    arena_bytes: int = 0          # final arena size (reserve, growth incl.)

    def __len__(self) -> int:
        return len(self.points)


_OP_NAMES = {OP_BIND_ARG: "BindArg", OP_COMPUTE: "Compute",
             OP_FREE_SLOT: "FreeSlot", OP_DONATE: "Donate",
             OP_LOOP: "Loop", OP_RETURN: "Return",
             OP_BIND_DIM: "BindDim"}


class _AuditSink:
    """Forwards a rolled loop's ``account()`` traffic to the MemoryManager
    while auditing it against the loop plan's own size table."""

    def __init__(self, mm: MemoryManager, sizes_ok, unexplained: List[Dict],
                 idx: int, step: int):
        self.mm = mm
        self.sizes_ok = sizes_ok
        self.unexplained = unexplained
        self.idx = idx
        self.step = step

    def alloc(self, key, nbytes) -> None:
        self.mm.alloc(key, nbytes)
        if not self.sizes_ok(key, nbytes):
            self.unexplained.append(dict(
                kind="loop-alloc", key=repr(key), bytes=nbytes,
                idx=self.idx, step=self.step,
                why="no loop event template sizes this buffer"))

    def free(self, key) -> None:
        self.mm.free(key)


def actual_timeline(program: Program, env: Dict[str, int],
                    unexplained_out: Optional[List[Dict]] = None) -> Timeline:
    """Replay the no-eviction instruction stream, recording occupancy.

    Pure accounting — no arrays are materialized, so probing the biggest
    declared env costs microseconds.  ``unexplained_out``, when given,
    collects the allocation audit against the plan's liveness intervals
    (see :func:`diff_timeline`).

    Value-dependent bounded dims: a replay cannot measure anything, so a
    bound dim missing from ``env`` is completed to its cap — the curve is
    the "measured == cap" worst case.  Pass a measured value (e.g. from
    ``RunReport.env``) to reconstruct a specific call's tight curve."""
    resolved = program.resolve(env)
    env = resolved.env          # bound dims completed (caps unless given)
    nbytes = resolved.nbytes
    arena = None
    if resolved.arena is not None:
        arena = ArenaAllocator(program.plan.arena_plan, resolved.arena)
    mm = MemoryManager(None, arena=arena)
    vid_of = program.vid_of

    liveness = None
    loop_sizes: List[Dict[int, int]] = [rl.sizes for rl in resolved.loops]
    if unexplained_out is not None:
        ap = program.plan.arena_plan
        liveness = ap.liveness if ap is not None else analyze_liveness(
            program.plan.graph, program.plan.order,
            donate_inputs=program.donate_inputs)

    def audit(vid: int, b: int, idx: int, step: int, kind: str) -> None:
        if unexplained_out is None:
            return
        iv = liveness.get(vid)
        if iv is None:
            unexplained_out.append(dict(
                kind=kind, vid=vid, bytes=b, idx=idx, step=step,
                why="no planned liveness interval"))
        elif not (iv.start <= step <= iv.end):
            unexplained_out.append(dict(
                kind=kind, vid=vid, bytes=b, idx=idx, step=step,
                why=f"outside planned interval [{iv.start}, {iv.end}]"))
        elif iv.nbytes_expr.evaluate(env) != b:
            unexplained_out.append(dict(
                kind=kind, vid=vid, bytes=b, idx=idx, step=step,
                why=f"planned {iv.nbytes_expr.evaluate(env)} bytes, "
                    f"allocated {b}"))

    tl = Timeline(env=dict(env))
    step = -1
    for idx, inst in enumerate(program.fast_instructions):
        op = inst.op
        if op == OP_COMPUTE:
            step = inst.step
            for _oi, r in inst.store:
                if r in inst.defer_regs:
                    continue          # allocated by the following BindDim
                mm.alloc(vid_of[r], nbytes[r])
                audit(vid_of[r], nbytes[r], idx, step, "alloc")
        elif op == OP_BIND_DIM:
            for _oi, r in inst.alloc_store:
                mm.alloc(vid_of[r], nbytes[r])
                audit(vid_of[r], nbytes[r], idx, step, "alloc")
        elif op == OP_BIND_ARG:
            if arena is not None:
                arena.place_external(inst.vid, nbytes[inst.reg])
            if program.count_inputs:
                mm.alloc(inst.vid, nbytes[inst.reg])
                audit(inst.vid, nbytes[inst.reg], idx, -1, "bind")
        elif op == OP_FREE_SLOT:
            mm.free(inst.vid)
        elif op == OP_DONATE:
            if inst.counted:
                mm.free(inst.vid)
            else:
                mm.arena_release(inst.vid)
        elif op == OP_LOOP:
            step = inst.step
            rl = resolved.loops[inst.lidx]
            info = program.loops[inst.lidx]
            sizes = loop_sizes[inst.lidx]

            def sizes_ok(key, b, _sizes=sizes, _nid=info.node.id) -> bool:
                if not isinstance(key, tuple):     # outer vid: liveness audit
                    return True
                nid, _par, bvid = key
                return nid == _nid and _sizes.get(bvid) == b

            sink = mm if unexplained_out is None else _AuditSink(
                mm, sizes_ok, unexplained_out, idx, step)
            info.lp.account(sink, info.node.id, rl.trip,
                            rl.sizes.__getitem__, rl.outer_y, rl.outer_carry)
            if unexplained_out is not None:
                for ov_vid, b in rl.outer_y:
                    audit(ov_vid, b, idx, step, "loop-out")
        tl.points.append(TimelinePoint(
            idx=idx, step=step, opname=_OP_NAMES.get(op, "?"),
            device_used=mm.stats.device_used,
            arena_in_use=0 if arena is None else arena.in_use_bytes))
    tl.peak_device = mm.stats.device_peak
    if arena is not None:
        tl.peak_arena_in_use = arena.peak_in_use
        tl.arena_bytes = arena.arena_bytes
    return tl


def planned_timeline(program: Program,
                     env: Dict[str, int]) -> Tuple[List[int], List[int]]:
    """Per-schedule-step planned occupancy ``(device, arena)`` from the
    liveness intervals at ``env``.

    ``device[s]`` counts every interval covering step ``s`` (externals
    included iff the program counts inputs); ``arena[s]`` only the
    arena-served values (externals and donated-slot placements ride caller
    memory).  At a rolled loop's step the loop's internal-peak delta is
    added — the loop plan's own trip-model expression, the same number the
    executors ``ensure()`` before entering the loop."""
    plan = program.plan
    if program.graph.bound_dims:
        from ..ir.dynamism import complete_bound_env
        env = complete_bound_env(program.graph, env)
    ap = plan.arena_plan
    liveness = ap.liveness if ap is not None else analyze_liveness(
        plan.graph, plan.order, donate_inputs=program.donate_inputs)
    horizon = len(plan.order)
    device = [0] * (horizon + 1)
    arena = [0] * (horizon + 1)
    for vid, iv in liveness.items():
        b = iv.nbytes_expr.evaluate(env)
        if iv.external and not program.count_inputs:
            counted = False
        else:
            counted = True
        in_arena = not iv.external
        if in_arena and ap is not None:
            asg = ap.assignment.get(vid)
            if asg is not None and ap.slots[asg.sid].external:
                in_arena = False          # planned into a donated buffer
        lo, hi = max(iv.start, 0), min(iv.end, horizon)
        for s in range(lo, hi + 1):
            if counted:
                device[s] += b
            if in_arena:
                arena[s] += b
    resolved = program.resolve(env)
    for inst in program.instructions:
        if inst.op == OP_LOOP:
            extra = resolved.loops[inst.lidx].extra_bytes
            device[inst.step] += extra
            arena[inst.step] += extra
    return device, arena


@dataclass
class TimelineDiff:
    """The plan-vs-actual correlation for one env."""

    env: Dict[str, int]
    predicted_device: List[int]          # per schedule step
    predicted_arena: List[int]
    actual: Timeline
    predicted_peak_device: int = 0
    predicted_peak_arena: int = 0
    arena_bound_bytes: Optional[int] = None
    unexplained: List[Dict] = field(default_factory=list)

    @property
    def within_bound(self) -> bool:
        """Actual arena peak stayed under the plan's guaranteed bound
        (vacuously true when no bound exists — unbounded dims)."""
        if self.arena_bound_bytes is None:
            return True
        return self.actual.arena_bytes <= self.arena_bound_bytes

    @property
    def ok(self) -> bool:
        return self.within_bound and not self.unexplained

    def summary(self) -> str:
        bound = ("n/a" if self.arena_bound_bytes is None
                 else f"{self.arena_bound_bytes:,}")
        return (f"env={self.env}: actual device peak "
                f"{self.actual.peak_device:,} vs predicted "
                f"{self.predicted_peak_device:,}; arena "
                f"{self.actual.arena_bytes:,} <= bound {bound}: "
                f"{self.within_bound}; unexplained allocations: "
                f"{len(self.unexplained)}")


def diff_timeline(program: Program, env: Dict[str, int]) -> TimelineDiff:
    """Build both curves for ``env`` and audit actual against planned."""
    if program.graph.bound_dims:
        from ..ir.dynamism import complete_bound_env
        env = complete_bound_env(program.graph, env)
    unexplained: List[Dict] = []
    actual = actual_timeline(program, env, unexplained_out=unexplained)
    device, arena = planned_timeline(program, env)
    bound = None
    if program.plan.arena_plan is not None:
        bound = program.plan.arena_plan.arena_bound_bytes
    return TimelineDiff(
        env=dict(env), predicted_device=device, predicted_arena=arena,
        actual=actual,
        predicted_peak_device=max(device) if device else 0,
        predicted_peak_arena=max(arena) if arena else 0,
        arena_bound_bytes=bound, unexplained=unexplained)
