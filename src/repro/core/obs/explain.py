"""``DynamicShapeFunction.explain()`` — the human-readable compile report.

Renders everything the pipeline decided for one compiled function:

* the phase span tree (durations + structured attributes) recorded by the
  :class:`.trace.Tracer` during ``optimize`` and every bucket compile;
* the decision log (schedule guard, exchange swaps, bucket reuse, frozen
  remat methods, slot packing);
* per-slot symbolic sizes + the liveness intervals packed into each slot
  (derived from the finished :class:`~repro.core.memplan.assign.ArenaPlan`
  — the plan *is* the record, nothing is duplicated at plan time);
* frozen-vs-runtime remat decisions per candidate;
* the bucket dispatch table (per-bucket hits/misses/bounds);
* optionally, the plan-vs-actual memory timeline diff at one env.

Plain functions over the public objects: nothing here is needed to run a
plan, so importing stays cheap and the report can never drift from the
artifacts it reads.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from .trace import Span


def _fmt_bytes(b: Optional[int]) -> str:
    if b is None:
        return "unbounded"
    if b >= 1 << 20:
        return f"{b / (1 << 20):.2f} MiB"
    if b >= 1 << 10:
        return f"{b / (1 << 10):.1f} KiB"
    return f"{b} B"


def _fmt_attrs(attrs: Dict[str, Any]) -> str:
    parts = []
    for k, v in attrs.items():
        if isinstance(v, dict):
            if not v:
                continue
            v = "{" + ", ".join(f"{kk}: {vv}" for kk, vv in v.items()) + "}"
        parts.append(f"{k}={v}")
    return " ".join(parts)


def _render_span(span: Span, lines: List[str], depth: int) -> None:
    pad = "  " * depth
    attrs = _fmt_attrs(span.attrs)
    lines.append(f"{pad}{span.name:<12} {span.duration_ns / 1e6:8.2f} ms"
                 f"{'  ' + attrs if attrs else ''}")
    for c in span.children:
        _render_span(c, lines, depth + 1)


def render_spans(tracer) -> List[str]:
    """The compile span forest, one indented line per span."""
    lines: List[str] = []
    for root in getattr(tracer, "roots", []):
        _render_span(root, lines, 0)
    return lines


def render_decisions(decisions, limit: int = 40) -> List[str]:
    entries = decisions.entries()
    lines: List[str] = []
    for d in entries[:limit]:
        detail = _fmt_attrs(d.detail)
        lines.append(f"[{d.kind}] {d.subject}: {d.choice} — {d.why}"
                     f"{'  (' + detail + ')' if detail else ''}")
    if len(entries) > limit:
        lines.append(f"... {len(entries) - limit} more "
                     f"(DynamicShapeFunction.decisions.entries())")
    return lines


def render_slots(arena_plan) -> List[str]:
    """Per-slot symbolic sizes + the liveness intervals packed into each."""
    lines: List[str] = []
    lines.append(
        f"arena bound {_fmt_bytes(arena_plan.arena_bound_bytes)} | "
        f"{arena_plan.n_slots} arena slots | reuse: "
        f"{arena_plan.n_provable_reuses} provable + "
        f"{arena_plan.n_checked_reuses} checked + "
        f"{arena_plan.n_donated_reuses} donated")
    liveness = arena_plan.liveness
    for s in arena_plan.slots:
        kind = "external" if s.external else "arena"
        hi = f" <= {_fmt_bytes(s.size_hi)}" if s.size_hi is not None else ""
        lines.append(f"slot {s.sid:>3} [{kind:>8}] size={s.size_expr}{hi}")
        for vid in s.members:
            iv = liveness.get(vid)
            asg = arena_plan.assignment.get(vid)
            tags = []
            if asg is not None and asg.reused:
                tags.append("provable" if asg.provable else "checked")
                if asg.donated:
                    tags.append("donated")
            span = f"[{iv.start}, {iv.end}]" if iv is not None else "[?]"
            size = str(iv.nbytes_expr) if iv is not None else "?"
            lines.append(f"    %{vid:<5} live {span:<12} {size}"
                         f"{'  (' + ', '.join(tags) + ')' if tags else ''}")
    return lines


def render_remat(plan) -> List[str]:
    """Frozen-vs-runtime regeneration decision per remat candidate."""
    lines: List[str] = []
    if not plan.candidates:
        return ["no remat candidates"]
    frozen = plan.static_methods
    lines.append(f"{len(plan.candidates)} candidates, "
                 f"{len(frozen)} frozen at compile time, "
                 f"{len(plan.candidates) - len(frozen)} decided at runtime")
    for vid, cand in sorted(plan.candidates.items()):
        method = frozen.get(vid)
        if method is not None:
            decided = f"frozen: {method}"
        else:
            decided = "runtime policy"
        notes = []
        if cand.recompute is not None:
            notes.append(f"recompute impact {cand.recompute.impact}")
        elif cand.recompute_pruned_by_bounds:
            notes.append("recompute pruned by interval bounds")
        else:
            notes.append("offload only")
        lines.append(f"  %{vid:<5} {decided:<18} "
                     f"bytes {cand.bytes_interval}  {'; '.join(notes)}")
    return lines


def render_kernel_selection(fn) -> List[str]:
    """Chosen kernel variant per node, per plan (whole-range + buckets).

    Each line shows the variant the cost model baked into that plan's
    ``Compute`` params, the modeled speedup over the default configuration
    at the plan's probe corners, and the variants its VMEM footprint ruled
    out; ``[measured]`` marks a choice re-selected from wall-clock timings
    (the background measured fallback)."""
    def _plan_lines(label: str, plan) -> List[str]:
        ls: List[str] = []
        for nid, sel in sorted(plan.kernel_selections.items()):
            tags = []
            if sel.measured:
                tags.append("measured")
            if not sel.is_default:
                tags.append(f"model x{sel.model_speedup:.2f} vs default")
            ls.append(
                f"  {label} %{nid} {sel.prim_name}: {sel.variant.name}  "
                f"{sel.describe_bounds()}"
                f"{'  [' + ', '.join(tags) + ']' if tags else ''}")
            if sel.invalid:
                ls.append(f"      vmem ruled out: {', '.join(sel.invalid)}")
        return ls

    lines = _plan_lines("whole-range", fn.plan)
    table = fn.specialization_table
    if table is not None:
        for key in table.compiled_keys:
            bp = table.peek(key)
            if bp is not None and bp.plan is not fn.plan:
                lines.extend(_plan_lines(f"bucket {key}", bp.plan))
    return lines or ["(no selectable kernels in this graph)"]


def render_resilience(fn) -> List[str]:
    """Ladder config, failure counters, recent degradation events, and
    breaker (quarantine) state — shown only when resilience is enabled."""
    res = fn.resilience
    lines: List[str] = []
    pol = res.config.retry
    lines.append(
        f"ladder: evict (in-call) -> retry-transient -> retry-fallback "
        f"-> reject | max_retries={pol.max_retries} "
        f"backoff={pol.backoff_base_s}s x{pol.backoff_factor}")
    c = res.counters()
    lines.append(
        f"calls {c['calls']} | degraded {c['degraded_calls']} | "
        f"retries transient {c['retries_transient']} / fallback "
        f"{c['retries_fallback']} | failures {c['failures']} "
        f"(malformed {c['malformed']})")
    events = list(res.events)
    for ev in events[-8:]:
        lines.append(
            f"  call {ev.seq} attempt {ev.attempt}: {ev.rung}"
            f"{' bucket ' + str(ev.bucket) if ev.bucket else ''}"
            f"{f' backoff {ev.backoff_s:.3f}s' if ev.backoff_s else ''}"
            f" — {ev.cause}")
    if len(events) > 8:
        lines.append(f"  ... {len(events) - 8} earlier events "
                     f"(fn.resilience.events)")
    table = fn.specialization_table
    if table is not None:
        q = table.quarantined()
        if q:
            for key in q:
                lines.append(
                    f"  quarantined bucket {key}: "
                    f"{table.breaker.state(key)}, re-probe in "
                    f"{table.breaker.retry_in_s(key):.3f}s "
                    f"({table.breaker.cause(key)!r})")
        else:
            lines.append("  no buckets quarantined")
    return lines


def render_buckets(table) -> List[str]:
    st = table.stats()
    lines = [f"{table.n_buckets} buckets | hits {st['hits']} | "
             f"misses {st['misses']} | specializations "
             f"{st['specialize_count']} | evictions {st['evictions']} | "
             f"resident {st['resident']}"]
    for key, row in table.per_bucket_stats().items():
        lines.append(
            f"  bucket {key}: hits={row['hits']} misses={row['misses']} "
            f"arena_bound={_fmt_bytes(row['arena_bound_bytes'])}"
            f"{' [resident]' if row['resident'] else ''}")
    return lines


def render_bound_dims(fn, env: Optional[Dict[str, int]] = None) -> List[str]:
    """Reserved-cap vs measured-size per value-dependent bounded dim.

    Planning reserved every dependent slot at the cap expression; a call
    measures the actual extent at its BindDim step.  With an ``env`` the
    cap is evaluated concretely, and — when the env carries a measured
    value for the dim (e.g. ``RunReport.env`` from a finished call) — the
    reserved-vs-measured byte ratio per dependent register is shown."""
    from ..ir.dynamism import complete_bound_env

    g = fn.plan.graph
    lines: List[str] = []
    cap_env = None
    if env is not None:
        # caps evaluate over base dims only: strip any measured values
        base = {k: v for k, v in env.items() if k not in g.bound_dims}
        cap_env = complete_bound_env(g, base)
    for name, cap in g.bound_dims.items():
        line = f"{name} <= {cap}"
        if cap_env is not None:
            line += f" = {cap_env[name]}"
            measured = env.get(name)
            if measured is not None:
                line += f"  measured {measured}"
        lines.append(line)
        prog = fn.program
        if prog is None or cap_env is None:
            continue
        for r in prog.bound_dep_regs.get(name, ()):
            expr = prog.nbytes_exprs[r]
            reserved = expr.evaluate(cap_env)
            slot_line = (f"    %{prog.vid_of[r]:<5} reserved "
                         f"{_fmt_bytes(reserved)}")
            if env.get(name) is not None:
                tight = expr.evaluate({**cap_env, name: env[name]})
                slot_line += f"  measured {_fmt_bytes(tight)}"
            lines.append(slot_line)
    return lines


def build_explain(fn, env: Optional[Dict[str, int]] = None) -> str:
    """Assemble the full report for a ``DynamicShapeFunction``."""
    rep = fn.report
    out: List[str] = []
    out.append("=" * 72)
    out.append("DynamicShapeFunction.explain()")
    out.append("=" * 72)
    out.append(
        f"nodes={len(fn.plan.graph.nodes)} "
        f"candidates={rep.n_candidates} "
        f"scheduled_order={'kept' if rep.used_scheduled_order else 'reverted'} "
        f"peak_bound={_fmt_bytes(rep.peak_bound_bytes)} "
        f"arena_bound={_fmt_bytes(rep.arena_bound_bytes)}")

    out.append("")
    out.append("-- compile phases " + "-" * 54)
    out.extend(render_spans(fn.trace) or ["(no spans recorded)"])

    out.append("")
    out.append("-- decisions " + "-" * 59)
    out.extend(render_decisions(fn.decisions) or ["(none recorded)"])

    if fn.plan.arena_plan is not None:
        out.append("")
        out.append("-- arena slots " + "-" * 57)
        out.extend(render_slots(fn.plan.arena_plan))

    out.append("")
    out.append("-- rematerialization " + "-" * 51)
    out.extend(render_remat(fn.plan))

    if fn.plan.kernel_selections:
        out.append("")
        out.append("-- kernel selection " + "-" * 52)
        out.extend(render_kernel_selection(fn))

    bound_dims = fn.plan.graph.bound_dims
    if bound_dims:
        out.append("")
        out.append("-- value-dependent bounded dims " + "-" * 40)
        out.extend(render_bound_dims(fn, env))

    table = fn.specialization_table
    if table is not None:
        out.append("")
        out.append("-- bucket dispatch " + "-" * 53)
        out.extend(render_buckets(table))

    if getattr(fn, "resilience", None) is not None:
        out.append("")
        out.append("-- resilience " + "-" * 58)
        out.extend(render_resilience(fn))

    if env is not None and fn.program is not None:
        out.append("")
        out.append("-- plan vs actual @ env " + "-" * 48)
        diff = fn.memory_timeline(env)
        out.append(diff.summary())
        status = "OK" if diff.ok else "DRIFT"
        out.append(f"verdict: {status} ({len(diff.unexplained)} unexplained "
                   f"allocations)")

    tel = fn.telemetry
    if tel is not None:
        out.append("")
        out.append("-- runtime telemetry " + "-" * 51)
        for k, v in tel.summary().items():
            out.append(f"{k}: {v}")

    return "\n".join(out)
