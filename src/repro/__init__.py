"""BladeDISC++ reproduction: memory optimizations based on symbolic shape,
as a multi-pod JAX training/inference framework.  See README.md."""

__version__ = "1.0.0"
