"""Shared building blocks for all architectures (pure JAX, no flax)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec


def _context_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def shard_hint(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint that no-ops outside a mesh context.

    ``axes``: one entry per dim — an axis name, a tuple of names, or None.
    Axes not present in the ambient mesh, or not dividing the dim, drop to
    None, so model code works on any mesh (and on plain CPU).
    """
    mesh = _context_mesh()
    if mesh is None:
        return x
    clean = []
    for dim, a in zip(x.shape, axes):
        names = a if isinstance(a, tuple) else ((a,) if a else ())
        names = tuple(n for n in names if n in mesh.axis_names)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if not names or not isinstance(dim, int) or size == 0 or dim % size:
            clean.append(None)
        else:
            clean.append(names if len(names) > 1 else names[0])
    spec = PartitionSpec(*clean)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


DP = ("pod", "data")  # data-parallel axes (pod present on multi-pod meshes)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


# -- RoPE ---------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # (hd//2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd//2)
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- initializers --------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32, scale: float = 1.0):
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Deterministic fold-in key generator for nested param init."""

    def __init__(self, key):
        self._key = key
        self._i = 0

    def __call__(self):
        self._i += 1
        return jax.random.fold_in(self._key, self._i)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over valid positions; logits (..., V), labels int (...).

    Partition-friendly for vocab-sharded logits: the label logit is picked
    with a fused ``iota == label`` masked reduction (local compare + psum)
    instead of take_along_axis (which would gather across the sharded
    vocab dim), and log-sum-exp reduces over vocab the same way.
    """
    v = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    shifted = lg - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, len(logits.shape) - 1)
    onehot = (iota == labels[..., None].astype(jnp.int32))
    label_logit = jnp.sum(jnp.where(onehot, shifted, 0.0), axis=-1)
    ll = label_logit - lse
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
