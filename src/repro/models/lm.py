"""Top-level language model: init / forward / loss / prefill / decode.

One code path serves all ten assigned architectures, driven by
``ModelConfig``.  Layers are stacked pytrees scanned with ``lax.scan`` (so
the compiled HLO is one block, not n_layers copies) with a configurable
remat policy.  xLSTM uses grouped stacks (runs of mLSTM + periodic sLSTM).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .attention import KVCache
from .blocks import BlockCache, block_apply, block_decode, block_init
from .common import KeyGen, dense_init, embed_init, rms_norm, softmax_cross_entropy
from .mla import MLACache
from .ssm import ssm_init_cache
from .xlstm import (MLSTMCache, SLSTMCache, mlstm_apply, mlstm_decode_step,
                    mlstm_init, mlstm_init_cache, slstm_apply,
                    slstm_decode_step, slstm_init, slstm_init_cache)


# -- per-layer window schedule (hybrid archs) -------------------------------------


def layer_windows(cfg: ModelConfig) -> Optional[np.ndarray]:
    """hymba-style: sliding window everywhere except {first, middle, last}."""
    if cfg.window is None:
        return None
    big = np.int32(2 ** 30)  # "global" == effectively unbounded window
    w = np.full((cfg.n_layers,), cfg.window, np.int32)
    for g in {0, cfg.n_layers // 2, cfg.n_layers - 1}:
        w[g] = big
    return w


def xlstm_meta(cfg: ModelConfig) -> Dict[str, int]:
    n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
    n_groups = max(n_s, 1)
    m_per_group = (cfg.n_layers - n_s) // n_groups
    return dict(n_groups=n_groups, m_per_group=m_per_group, n_s=n_s)


# -- init --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict:
    kg = KeyGen(key)
    dtype = cfg.jax_dtype
    p: Dict[str, Any] = {}
    vpad = cfg.padded_vocab
    if cfg.input_mode in ("tokens", "vlm"):
        p["embed"] = embed_init(kg(), (vpad, cfg.d_model), dtype=dtype)
    if not cfg.tie_embeddings or cfg.input_mode == "embeddings":
        if cfg.n_codebooks:
            p["lm_head"] = dense_init(kg(), (cfg.d_model,
                                             cfg.n_codebooks * vpad),
                                      dtype=dtype)
        else:
            p["lm_head"] = dense_init(kg(), (cfg.d_model, vpad), dtype=dtype)
    p["final_norm"] = jnp.zeros((cfg.d_model,), dtype)

    if cfg.block_kind == "xlstm":
        xc = cfg.xlstm_config()
        meta = xlstm_meta(cfg)
        base = kg()
        mkeys = jnp.stack([jnp.stack([jax.random.fold_in(base, i * 100 + j)
                                      for j in range(meta["m_per_group"])])
                           for i in range(meta["n_groups"])])
        p["mlstm"] = jax.vmap(jax.vmap(lambda k: mlstm_init(k, xc, dtype)))(mkeys)
        if meta["n_s"]:
            skeys = jnp.stack([jax.random.fold_in(base, 10_000 + i)
                               for i in range(meta["n_s"])])
            p["slstm"] = jax.vmap(lambda k: slstm_init(k, xc, dtype))(skeys)
        return p

    keys = jnp.stack([jax.random.fold_in(kg(), i) for i in range(cfg.n_layers)])
    p["layers"] = jax.vmap(lambda k: block_init(k, cfg, dtype))(keys)
    return p


# -- forward (training / prefill path) ----------------------------------------------


def _embed_inputs(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    dtype = cfg.jax_dtype
    if cfg.input_mode == "tokens":
        return params["embed"][batch["tokens"]].astype(dtype)
    if cfg.input_mode == "embeddings":
        return batch["frame_embed"].astype(dtype)
    if cfg.input_mode == "vlm":
        txt = params["embed"][batch["tokens"]].astype(dtype)
        vis = batch["vis_embed"].astype(dtype)
        return jnp.concatenate([vis, txt], axis=1)
    raise ValueError(cfg.input_mode)


def _mask_pad_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """Pad-vocab columns (table padded to a tile boundary) never win."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    len(logits.shape) - 1)
    return jnp.where(iota < cfg.vocab, logits,
                     jnp.asarray(-1e30, logits.dtype))


def _lm_logits(cfg: ModelConfig, params: Dict, h: jax.Array) -> jax.Array:
    from .common import DP, shard_hint
    h = rms_norm(h, params["final_norm"])
    if cfg.n_codebooks:
        logits = h @ params["lm_head"]
        b, s, _ = h.shape
        logits = shard_hint(
            logits.reshape(b, s, cfg.n_codebooks, cfg.padded_vocab),
            DP, None, None, "model")
        return _mask_pad_vocab(cfg, logits)
    if cfg.tie_embeddings and cfg.input_mode != "embeddings":
        logits = h @ params["embed"].T.astype(h.dtype)
    else:
        logits = h @ params["lm_head"]
    return _mask_pad_vocab(cfg, shard_hint(logits, DP, None, "model"))


def _scan_blocks(cfg: ModelConfig, params: Dict, x: jax.Array,
                 q_offset=0) -> Tuple[jax.Array, jax.Array]:
    windows = layer_windows(cfg)

    if not cfg.scan_layers:
        # python-unrolled: the flat graph the dynamic-shape optimizer
        # schedules / rematerializes (remat is *its* job, not jax.checkpoint's)
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            layer_p = jax.tree.map(lambda a: a[i], params["layers"])
            w = None if windows is None else int(windows[i])
            x, a = block_apply(layer_p, cfg, x, window=w, q_offset=q_offset)
            aux = aux + a
        return x, aux

    def body(carry, xs):
        h, aux = carry
        if windows is None:
            layer_p = xs
            w = None
        else:
            layer_p, w = xs
        y, a = block_apply(layer_p, cfg, h, window=w, q_offset=q_offset)
        return (y, aux + a), None

    body_fn = body
    if cfg.remat_policy != "none":
        body_fn = jax.checkpoint(body, prevent_cse=False)
    xs = params["layers"] if windows is None else (params["layers"],
                                                   jnp.asarray(windows))
    (h, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), xs)
    return h, aux


def _xlstm_forward(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    xc = cfg.xlstm_config()
    meta = xlstm_meta(cfg)

    def m_body(h, layer_p):
        return h + mlstm_apply(layer_p, xc, rms_norm(h, layer_p["ln"])), None

    m_fn = jax.checkpoint(m_body, prevent_cse=False) \
        if cfg.remat_policy != "none" else m_body
    for g in range(meta["n_groups"]):
        group_p = jax.tree.map(lambda a: a[g], params["mlstm"])
        x, _ = jax.lax.scan(m_fn, x, group_p)
        if meta["n_s"]:
            sp = jax.tree.map(lambda a: a[g], params["slstm"])
            x = x + slstm_apply(sp, xc, rms_norm(x, sp["ln"]))
    return x


def forward(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits, moe_aux_loss)."""
    x = _embed_inputs(cfg, params, batch)
    if cfg.block_kind == "xlstm":
        h = _xlstm_forward(cfg, params, x)
        aux = jnp.zeros((), jnp.float32)
    else:
        h, aux = _scan_blocks(cfg, params, x)
    return _lm_logits(cfg, params, h), aux


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.input_mode == "vlm":  # loss only over text positions
        logits = logits[:, -labels.shape[1]:]
    if cfg.n_codebooks:
        loss = softmax_cross_entropy(logits, labels)     # labels (B,S,K)
    else:
        loss = softmax_cross_entropy(logits, labels, batch.get("mask"))
    return loss + 0.01 * aux


# -- serving: prefill + single-token decode -------------------------------------------


class DecodeState(NamedTuple):
    caches: Any          # stacked per-layer caches
    xlstm: Any = None    # xlstm grouped caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> DecodeState:
    dtype = cfg.jax_dtype
    hd = cfg.resolved_head_dim
    if cfg.block_kind == "xlstm":
        xc = cfg.xlstm_config()
        meta_groups = max(cfg.n_layers // cfg.slstm_every, 1)
        m_per = (cfg.n_layers - cfg.n_layers // cfg.slstm_every) // meta_groups
        m_cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (meta_groups, m_per) + a.shape),
            mlstm_init_cache(xc, batch, dtype))
        s_cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (meta_groups,) + a.shape),
            slstm_init_cache(xc, batch))
        return DecodeState(caches=None, xlstm=(m_cache, s_cache))
    L = cfg.n_layers
    if cfg.attn_kind == "mla":
        kv = MLACache(
            c_kv=jnp.zeros((L, batch, max_len, cfg.r_kv), dtype),
            k_rope=jnp.zeros((L, batch, max_len, cfg.qk_rope), dtype),
            length=jnp.zeros((L,), jnp.int32))
    else:
        kv = KVCache(
            k=jnp.zeros((L, batch, max_len, cfg.n_kv_heads, hd), dtype),
            v=jnp.zeros((L, batch, max_len, cfg.n_kv_heads, hd), dtype),
            length=jnp.zeros((L,), jnp.int32))
    ssm = None
    if cfg.family == "hybrid":
        ssm = jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape),
                           ssm_init_cache(cfg.ssm_config(), batch, dtype))
    return DecodeState(caches=BlockCache(kv=kv, ssm=ssm))


def prefill(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    """Forward over the prompt; returns last-position logits.

    (Cache-filling prefill for serving reuses forward() compute; for the
    dry-run cells the compiled artifact of interest is this forward.)
    """
    logits, _ = forward(cfg, params, batch)
    return logits[:, -1]


def decode_step(cfg: ModelConfig, params: Dict, state: DecodeState,
                inp: Dict) -> Tuple[jax.Array, DecodeState]:
    """One new token against the running cache.

    inp: {'token': (B,1)} or {'frame_embed': (B,1,D)} per input_mode.
    """
    dtype = cfg.jax_dtype
    if cfg.input_mode in ("tokens", "vlm"):
        x = params["embed"][inp["token"]].astype(dtype)
    else:
        x = inp["frame_embed"].astype(dtype)

    if cfg.block_kind == "xlstm":
        xc = cfg.xlstm_config()
        meta = xlstm_meta(cfg)
        m_cache, s_cache = state.xlstm
        meta_groups = m_cache.c.shape[0]

        def m_body(h, xs):
            layer_p, cache = xs
            y, new_cache = mlstm_decode_step(
                layer_p, xc, rms_norm(h, layer_p["ln"]), cache)
            return h + y, new_cache

        new_m, new_s = [], []
        for g in range(meta_groups):
            gp = jax.tree.map(lambda a: a[g], params["mlstm"])
            gc = jax.tree.map(lambda a: a[g], m_cache)
            x, nc = jax.lax.scan(m_body, x, (gp, gc))
            new_m.append(nc)
            if meta["n_s"]:
                sp = jax.tree.map(lambda a: a[g], params["slstm"])
                sc = jax.tree.map(lambda a: a[g], s_cache)
                y, nsc = slstm_decode_step(sp, xc, rms_norm(x, sp["ln"]), sc)
                x = x + y
                new_s.append(nsc)
        m_stack = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
        s_stack = jax.tree.map(lambda *a: jnp.stack(a), *new_s) if new_s else s_cache
        logits = _lm_logits(cfg, params, x)[:, -1:]
        return logits, DecodeState(caches=None, xlstm=(m_stack, s_stack))

    windows = layer_windows(cfg)

    def body(h, xs):
        if windows is None:
            layer_p, cache = xs
            w = None
        else:
            layer_p, cache, w = xs
        y, new_cache = block_decode(layer_p, cfg, h, cache, window=w)
        return y, new_cache

    xs = (params["layers"], state.caches) if windows is None else \
        (params["layers"], state.caches, jnp.asarray(windows))
    x, new_caches = jax.lax.scan(body, x, xs)
    logits = _lm_logits(cfg, params, x)[:, -1:]
    return logits, DecodeState(caches=new_caches)
