"""Multi-head Latent Attention (DeepSeek-V3 style).

Prefill/train: expand the compressed KV latent to per-head K/V and run
standard attention.  Decode: the *absorbed* path — cache only the latent
(r_kv per token) plus the shared RoPE key (qk_rope per token), and fold
W_UK / W_UV into the query/output projections.  This is the MLA memory
win: 576 cached floats/token vs 2·H·128.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import NEG_INF, dense_attention
from .common import apply_rope, dense_init, rms_norm


class MLAConfig(NamedTuple):
    d_model: int
    n_heads: int
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128
    r_kv: int = 512
    r_q: int = 1536  # 0 -> full-rank Q projection
    rope_theta: float = 10000.0


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope + cfg.qk_rope
    p = {
        "w_dkv": dense_init(ks[0], (d, cfg.r_kv), dtype=dtype),
        "kv_norm": jnp.zeros((cfg.r_kv,), dtype),
        "w_uk": dense_init(ks[1], (cfg.r_kv, h, cfg.qk_nope), dtype=dtype),
        "w_uv": dense_init(ks[2], (cfg.r_kv, h, cfg.v_dim), dtype=dtype),
        "w_kr": dense_init(ks[3], (d, cfg.qk_rope), dtype=dtype),
        "w_o": dense_init(ks[4], (h, cfg.v_dim, d), in_axis=0, dtype=dtype),
    }
    if cfg.r_q:
        p["w_dq"] = dense_init(ks[5], (d, cfg.r_q), dtype=dtype)
        p["q_norm"] = jnp.zeros((cfg.r_q,), dtype)
        p["w_uq"] = dense_init(ks[6], (cfg.r_q, h, qd), dtype=dtype)
    else:
        p["w_q"] = dense_init(ks[7], (d, h, qd), dtype=dtype)
    return p


def _queries(params: Dict, cfg: MLAConfig, x: jax.Array, positions) -> Tuple[jax.Array, jax.Array]:
    if cfg.r_q:
        cq = rms_norm(x @ params["w_dq"], params["q_norm"])
        q = jnp.einsum("bsr,rhd->bshd", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhq->bshq", x, params["w_q"])
    q_nope = q[..., :cfg.qk_nope]
    q_rope = apply_rope(q[..., cfg.qk_nope:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_prefill(params: Dict, cfg: MLAConfig, x: jax.Array,
                q_offset=0) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """x (B,S,D) -> (out (B,S,D), (c_kv, k_rope) latent cache entries)."""
    b, s, d = x.shape
    positions = q_offset + jnp.arange(s)[None, :]
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv = rms_norm(x @ params["w_dkv"], params["kv_norm"])       # (B,S,r)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)                            # (B,S,1,rope)
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uv"])
    h = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope))],
                        axis=-1)
    scale = 1.0 / math.sqrt(cfg.qk_nope + cfg.qk_rope)
    attn = dense_attention(q, k, v, causal=True, q_offset=q_offset,
                           softmax_scale=scale)
    out = jnp.einsum("bshd,hdm->bsm", attn, params["w_o"])
    return out, (c_kv, k_rope[:, :, 0, :])


class MLACache(NamedTuple):
    c_kv: jax.Array     # (B, Smax, r_kv)
    k_rope: jax.Array   # (B, Smax, qk_rope)
    length: jax.Array


def mla_decode(params: Dict, cfg: MLAConfig, x: jax.Array,
               cache: MLACache) -> Tuple[jax.Array, MLACache]:
    """Absorbed decode: x (B,1,D); cache latent, never expand K/V."""
    b = x.shape[0]
    pos = (cache.length - 1) + jnp.arange(1)[None, :] + 1  # next position
    pos = jnp.broadcast_to(cache.length[None, None], (b, 1))
    q_nope, q_rope = _queries(params, cfg, x, pos)
    c_new = rms_norm(x @ params["w_dkv"], params["kv_norm"])       # (B,1,r)
    kr_new = apply_rope((x @ params["w_kr"])[:, :, None, :], pos,
                        cfg.rope_theta)[:, :, 0, :]                # (B,1,rope)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, cache.length, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, cache.length, axis=1)
    new_len = cache.length + 1
    t = c_kv.shape[1]
    valid = (jnp.arange(t)[None, :] < new_len)                     # (1,T)

    # absorb W_UK into q: (B,1,H,nope) x (r,H,nope) -> (B,1,H,r)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, params["w_uk"])
    scale = 1.0 / math.sqrt(cfg.qk_nope + cfg.qk_rope)
    scores = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                         c_kv.astype(jnp.float32)) +
              jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                         k_rope.astype(jnp.float32))) * scale
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhst,btr->bshr", p, c_kv.astype(jnp.float32))
    attn = jnp.einsum("bshr,rhd->bshd", ctx_lat, params["w_uv"].astype(jnp.float32))
    out = jnp.einsum("bshd,hdm->bsm", attn.astype(x.dtype), params["w_o"])
    return out, MLACache(c_kv, k_rope, new_len)
