from .lm import (DecodeState, decode_step, forward, init_cache, init_params,
                 loss_fn, prefill)

__all__ = ["DecodeState", "decode_step", "forward", "init_cache",
           "init_params", "loss_fn", "prefill"]
