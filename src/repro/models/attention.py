"""Attention variants: causal GQA/MQA (dense + blockwise), sliding window, MLA.

Dense is used for small/symbolic-shape graphs (the dynamic-shape optimizer
path); blockwise (scan-based online softmax — the pure-JAX twin of the
Pallas flash kernel) is used on the compiled path for long sequences so the
S×S score matrix is never materialized.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _grouped(q: jax.Array, n_kv: int) -> jax.Array:
    """(B,S,Hq,hd) -> (B,S,Hkv,G,hd)."""
    b, s, hq, hd = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, hd)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    q_offset=0,
                    window: Optional[int] = None,
                    pad_mask: Optional[jax.Array] = None,
                    softmax_scale: Optional[float] = None) -> jax.Array:
    """q (B,S,Hq,hd), k/v (B,T,Hkv,hd) -> (B,S,Hq,hd).

    ``q_offset``: absolute position of q[0] (for decode, q_offset=T-1).
    ``window``: sliding-window size (attend to [pos-window+1, pos]).
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = _grouped(q, hkv) * scale
    scores = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(s)[:, None]        # (S,1)
    kv_pos = jnp.arange(t)[None, :]                   # (1,T)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if pad_mask is not None:  # (B,T) True=valid
        scores = jnp.where(pad_mask[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, hq, v.shape[-1]).astype(q.dtype)


_NO_WINDOW = 2 ** 30  # sentinel: effectively unbounded sliding window


def _block_mask(sc, kv_pos, q_pos, causal, window, mblk):
    """Apply causal/window/padding masks to a score block (in-loop: the
    block position comes from a loop-carried counter so XLA cannot hoist a
    precomputed (nblk, ..., S, blk) mask stack out of the scan).

    ``window`` may be a traced scalar (per-layer windows scanned over a
    layer stack); the sentinel ``_NO_WINDOW`` disables it numerically.
    """
    neg = jnp.float32(NEG_INF)
    if causal:
        sc = jnp.where((kv_pos[None, :] <= q_pos[:, None])[None, None, None],
                       sc, neg)
    if window is not None:
        sc = jnp.where((kv_pos[None, :] > q_pos[:, None] - window)
                       [None, None, None], sc, neg)
    if mblk is not None:
        sc = jnp.where(mblk[:, None, None, None, :], sc, neg)
    return sc


def _flash_fwd_scan(q, kb, vb, mb, q_pos, *, causal, window, block_kv, scale):
    """Online-softmax forward over KV blocks.  Returns out (f32) and lse."""
    b, s, hkv, g, hd = q.shape
    hd_v = vb.shape[-1]

    def body(carry, xs):
        m, l, acc, c = carry
        kblk, vblk = xs[0], xs[1]
        mblk = xs[2] if len(xs) > 2 else None
        kv_pos = c * block_kv + jnp.arange(block_kv)
        sc = jnp.einsum("bshgd,bthd->bhgst", q, kblk,
                        preferred_element_type=jnp.float32) * scale
        sc = _block_mask(sc, kv_pos, q_pos, causal, window, mblk)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgst,bthd->bshgd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new, c + 1), None

    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, s, hkv, g, hd_v), jnp.float32)
    xs = (kb, vb) if mb is None else (kb, vb, mb)
    (m, l, acc, _), _ = jax.lax.scan(
        body, (m0, l0, a0, jnp.zeros((), jnp.int32)), xs)
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe.transpose(0, 3, 1, 2)[..., None]
    lse = m + jnp.log(l_safe)       # (b, hkv, g, s)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash_attention(q, k, v, pad_mask, window, causal, block_kv, scale,
                     q_offset):
    """window is a traced int32 scalar (``_NO_WINDOW`` disables)."""
    out, _ = _flash_attention_fwd(q, k, v, pad_mask, window, causal, block_kv,
                                  scale, q_offset)
    return out


def _prep_blocks(k, v, pad_mask, block_kv):
    b, t, hkv, hd = k.shape
    nblk = -(-t // block_kv)
    t_pad = nblk * block_kv
    if t_pad != t:
        pad = [(0, 0), (0, t_pad - t), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        if pad_mask is not None:
            pad_mask = jnp.pad(pad_mask, [(0, 0), (0, t_pad - t)])
        else:
            pad_mask = jnp.broadcast_to(jnp.arange(t_pad)[None, :] < t, (b, t_pad))
    kb = k.reshape(b, nblk, block_kv, hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block_kv, hkv, v.shape[-1]).transpose(1, 0, 2, 3, 4)
    mb = (pad_mask.reshape(b, nblk, block_kv).transpose(1, 0, 2)
          if pad_mask is not None else None)
    return kb, vb, mb, nblk, t_pad


def _flash_attention_fwd(q, k, v, pad_mask, window, causal, block_kv, scale,
                         q_offset):
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = _grouped(q, hkv)
    kb, vb, mb, _, _ = _prep_blocks(k, v, pad_mask, block_kv)
    q_pos = q_offset + jnp.arange(s)
    out, lse = _flash_fwd_scan(qg, kb, vb, mb, q_pos, causal=causal,
                               window=window, block_kv=block_kv, scale=scale)
    out_ret = out.reshape(b, s, hq, v.shape[-1]).astype(q.dtype)
    return out_ret, (q, k, v, pad_mask, window, out, lse)


def _flash_attention_bwd(causal, block_kv, scale, q_offset, res, d_out):
    """Flash backward: re-stream KV blocks, never materialize (S,T) probs."""
    q, k, v, pad_mask, window, out, lse = res
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    g = hq // hkv
    qg = _grouped(q, hkv).astype(jnp.float32)                 # (b,s,hkv,g,hd)
    do = _grouped(d_out.astype(jnp.float32), hkv)             # (b,s,hkv,g,hdv)
    kb, vb, mb, nblk, t_pad = _prep_blocks(k, v, pad_mask, block_kv)
    q_pos = q_offset + jnp.arange(s)
    # delta = rowsum(do * o): (b,hkv,g,s)
    delta = jnp.einsum("bshgd,bshgd->bhgs", do, out)

    def body(carry, xs):
        dq, c = carry
        kblk, vblk = xs[0], xs[1]
        mblk = xs[2] if len(xs) > 2 else None
        kv_pos = c * block_kv + jnp.arange(block_kv)
        sc = jnp.einsum("bshgd,bthd->bhgst", qg, kblk.astype(jnp.float32)) \
            * scale
        sc = _block_mask(sc, kv_pos, q_pos, causal, window, mblk)
        p = jnp.exp(sc - lse[..., None])                       # (b,hkv,g,s,t)
        dv_blk = jnp.einsum("bhgst,bshgd->bthd", p, do)
        dp = jnp.einsum("bshgd,bthd->bhgst", do, vblk.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_blk = jnp.einsum("bhgst,bthd->bshgd", ds, kblk.astype(jnp.float32))
        dk_blk = jnp.einsum("bhgst,bshgd->bthd", ds, qg)
        return (dq + dq_blk, c + 1), (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, s, hkv, g, hd), jnp.float32)
    xs = (kb, vb) if mb is None else (kb, vb, mb)
    (dq, _), (dk_b, dv_b) = jax.lax.scan(
        body, (dq0, jnp.zeros((), jnp.int32)), xs)
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(b, t_pad, hkv, hd)[:, :t]
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(b, t_pad, hkv, hd_v)[:, :t]
    dq_out = dq.reshape(b, s, hq, hd)
    return (dq_out.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_flash_attention.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        q_offset=0,
                        window: Optional[int] = None,
                        pad_mask: Optional[jax.Array] = None,
                        block_kv: int = 512,
                        softmax_scale: Optional[float] = None) -> jax.Array:
    """Flash-style attention: online-softmax forward over KV blocks and a
    block-restreaming custom VJP — the (S,T) score/prob matrices are never
    materialized in either pass.  This is the pure-JAX twin of the Pallas
    kernel in ``repro.kernels.flash_attention``.
    """
    hd = q.shape[-1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    w = jnp.asarray(_NO_WINDOW if window is None else window, jnp.int32)
    return _flash_attention(q, k, v, pad_mask, w, causal, block_kv,
                            scale, q_offset)


def attention(q, k, v, *, causal=True, q_offset=0, window=None, pad_mask=None,
              softmax_scale=None, block_kv: int = 512,
              blockwise_threshold: int = 2048) -> jax.Array:
    """Dispatch dense vs blockwise.  Symbolic shapes always go dense."""
    t = k.shape[1]
    concrete = isinstance(t, int)
    if concrete and t > blockwise_threshold:
        return blockwise_attention(q, k, v, causal=causal, q_offset=q_offset,
                                   window=window, pad_mask=pad_mask,
                                   block_kv=block_kv, softmax_scale=softmax_scale)
    return dense_attention(q, k, v, causal=causal, q_offset=q_offset,
                           window=window, pad_mask=pad_mask,
                           softmax_scale=softmax_scale)


# -- KV cache -------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array      # (B, Smax, Hkv, hd)
    v: jax.Array
    length: jax.Array  # () int32 — tokens currently valid


def kv_cache_update(cache: KVCache, k_new: jax.Array, v_new: jax.Array) -> KVCache:
    """Append one step (B,1,Hkv,hd) at position cache.length."""
    idx = cache.length
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, idx, axis=1)
    return KVCache(k, v, cache.length + k_new.shape[1])


def decode_attention(q: jax.Array, cache: KVCache, *,
                     window: Optional[int] = None,
                     softmax_scale: Optional[float] = None) -> jax.Array:
    """One-token decode: q (B,1,Hq,hd) against the cache (masked by length)."""
    t = cache.k.shape[1]
    valid = jnp.arange(t)[None, :] < cache.length  # (1,T)
    return dense_attention(q, cache.k, cache.v, causal=False, window=window,
                           q_offset=cache.length - 1,
                           pad_mask=jnp.broadcast_to(valid, (q.shape[0], t)),
                           softmax_scale=softmax_scale)
