"""Feed-forward variants: SwiGLU / GeGLU / vanilla, and token-choice MoE.

The MoE dispatch is sort-based (argsort by expert id + capacity-bounded
scatter into (E, C, D) buffers), the production-style alternative to the
(N, E, C) one-hot einsum dispatch whose memory explodes at scale.  FLOPs
match the active-parameter count (top-k experts per token).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import DP, dense_init, shard_hint


def _hint_hidden(h: jax.Array) -> jax.Array:
    if h.ndim == 3:
        return shard_hint(h, DP, None, "model")
    if h.ndim == 2:
        return shard_hint(h, DP, "model")
    return h


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return _hint_hidden(h) @ w2


def geglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ w1, approximate=True) * (x @ w3)
    return _hint_hidden(h) @ w2


def ffn_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w3": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w2": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def ffn_apply(params: Dict, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    fn = geglu if kind == "geglu" else swiglu
    return fn(x, params["w1"], params["w3"], params["w2"])


# -- Mixture of Experts ----------------------------------------------------------


def moe_init(key, d_model: int, d_ff: int, n_experts: int, n_shared: int = 0,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), dtype=jnp.float32),
        "w1": dense_init(ks[1], (n_experts, d_model, d_ff), in_axis=1, dtype=dtype),
        "w3": dense_init(ks[2], (n_experts, d_model, d_ff), in_axis=1, dtype=dtype),
        "w2": dense_init(ks[3], (n_experts, d_ff, d_model), in_axis=1, dtype=dtype),
    }
    if n_shared:
        p["shared"] = ffn_init(ks[4], d_model, d_ff * n_shared, dtype=dtype)
    return p


def moe_apply(params: Dict, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25,
              ffn_kind: str = "swiglu") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    Token-choice routing with *per-example* capacity and a batched
    sort-based dispatch.  Every dispatch/combine op keeps the leading batch
    dim, so under SPMD the routing math stays local to each data shard and
    the only cross-device movement is the token->expert exchange on the
    expert-sharded buffers (the canonical MoE all-to-all).  A global
    flattened dispatch would instead force XLA to replicate (B·S, D)
    buffers through giant all-reduces — see §Perf iteration 2.

    Overflow beyond an expert's per-example capacity is dropped (standard);
    shared experts always run.
    """
    b, s, d = x.shape
    e = params["router"].shape[1]
    nk = s * top_k

    # router in x's dtype with f32 accumulation: no f32 copy of the (B,S,D)
    # activations is materialized (its f32 cotangent would double the MoE
    # backward's HBM traffic — §Perf iteration 2b)
    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)         # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)                      # (B,S,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style), over all tokens
    frac_tokens = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0) / (b * nk)
    mean_prob = probs.reshape(-1, e).mean(axis=0)
    aux = e * jnp.sum(frac_tokens * mean_prob)

    cap = int(max(1, round(s * top_k * capacity_factor / e)))

    # batched sort of (token, choice) pairs by expert id, per example
    flat_e = top_i.reshape(b, nk)                                    # (B, N)
    sort_idx = jnp.argsort(flat_e, axis=-1)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    run_start = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e),
                                                     side="left"))(sorted_e)
    pos_sorted = jnp.arange(nk)[None, :] - jnp.take_along_axis(
        run_start, sorted_e, axis=-1)
    keep = pos_sorted < cap
    dest = jnp.where(keep, sorted_e * cap + pos_sorted, e * cap)     # (B, N)

    token_of = sort_idx // top_k                                     # (B, N)

    # vmap'd dispatch: scatter/gather carry operand-batching dims, so the
    # SPMD partitioner keeps them local to each (data-sharded) example.
    def _dispatch_one(x_row, dest_row, tok_row):
        xg = x_row[tok_row]                                          # (N, D)
        return jnp.zeros((e * cap + 1, d), x.dtype).at[dest_row].set(xg)

    buf = jax.vmap(_dispatch_one)(x, dest, token_of)                 # (B,EC+1,D)
    buf = shard_hint(buf[:, :-1].reshape(b, e, cap, d),
                     DP, "model", None, None)

    # expert FFN (grouped einsum over the expert dim, expert-parallel)
    act = jax.nn.gelu if ffn_kind == "geglu" else jax.nn.silu
    h = act(jnp.einsum("becd,edf->becf", buf, params["w1"])) * \
        jnp.einsum("becd,edf->becf", buf, params["w3"])
    h = shard_hint(h, DP, "model", None, None)
    y = jnp.einsum("becf,efd->becd", h, params["w2"])                # (B,E,C,D)
    y = shard_hint(y, DP, "model", None, None)

    # combine back, weighted by router prob (vmap'd for batching dims)
    y_flat = jnp.concatenate([y.reshape(b, e * cap, d),
                              jnp.zeros((b, 1, d), y.dtype)], axis=1)
    weights = jnp.take_along_axis(top_p.reshape(b, nk), sort_idx,
                                  axis=-1).astype(y_flat.dtype)

    def _combine_one(yf_row, dest_row, tok_row, w_row):
        gathered = yf_row[dest_row] * w_row[:, None]                 # (N, D)
        return jnp.zeros((s, d), y_flat.dtype).at[tok_row].add(gathered)

    out = jax.vmap(_combine_one)(y_flat, dest, token_of, weights)

    if "shared" in params:
        out = out + ffn_apply(params["shared"], x.reshape(b * s, d),
                              kind=ffn_kind).reshape(b, s, d)
    return out.astype(x.dtype), aux
