"""Transformer / hybrid / xLSTM blocks assembled from the mixer modules."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import KVCache, attention, decode_attention, kv_cache_update
from .common import DP, KeyGen, apply_rope, dense_init, rms_norm, shard_hint
from .ffn import ffn_apply, ffn_init, moe_apply, moe_init
from .mla import MLACache, MLAConfig, mla_decode, mla_init, mla_prefill
from .ssm import SSMCache, SSMConfig, ssm_apply, ssm_decode_step, ssm_init, ssm_init_cache


# -- GQA attention sub-block -----------------------------------------------------


def gqa_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
             dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    return {
        "wq": dense_init(kg(), (d_model, n_heads * head_dim), dtype=dtype),
        "wk": dense_init(kg(), (d_model, n_kv_heads * head_dim), dtype=dtype),
        "wv": dense_init(kg(), (d_model, n_kv_heads * head_dim), dtype=dtype),
        "wo": dense_init(kg(), (n_heads * head_dim, d_model), dtype=dtype),
    }


def gqa_qkv(params: Dict, x: jax.Array, n_heads: int, n_kv_heads: int,
            head_dim: int, positions, rope_theta: float):
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ params["wv"]).reshape(b, s, n_kv_heads, head_dim)
    q = shard_hint(apply_rope(q, positions, rope_theta), DP, None, "model", None)
    k = shard_hint(apply_rope(k, positions, rope_theta), DP, None, "model", None)
    v = shard_hint(v, DP, None, "model", None)
    return q, k, v


def gqa_full(params: Dict, x: jax.Array, *, n_heads, n_kv_heads, head_dim,
             rope_theta, q_offset=0, window=None) -> jax.Array:
    b, s, _ = x.shape
    positions = q_offset + jnp.arange(s)[None, :]
    q, k, v = gqa_qkv(params, x, n_heads, n_kv_heads, head_dim, positions,
                      rope_theta)
    out = attention(q, k, v, causal=True, q_offset=q_offset, window=window)
    return out.reshape(b, s, n_heads * head_dim) @ params["wo"]


def gqa_decode(params: Dict, x: jax.Array, cache: KVCache, *, n_heads,
               n_kv_heads, head_dim, rope_theta, window=None
               ) -> Tuple[jax.Array, KVCache]:
    b, s, _ = x.shape  # s == 1
    positions = jnp.broadcast_to(cache.length[None, None], (b, s))
    q, k, v = gqa_qkv(params, x, n_heads, n_kv_heads, head_dim, positions,
                      rope_theta)
    cache = kv_cache_update(cache, k, v)
    out = decode_attention(q, cache, window=window)
    return out.reshape(b, s, n_heads * head_dim) @ params["wo"], cache


# -- Transformer block (dense or MoE FFN; GQA or MLA attention) -------------------


def block_init(key, cfg, dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    p: Dict[str, Any] = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.attn_kind == "mla":
        p["attn"] = mla_init(kg(), cfg.mla_config(), dtype=dtype)
    else:
        p["attn"] = gqa_init(kg(), cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.resolved_head_dim, dtype=dtype)
    if cfg.n_experts:
        p["moe"] = moe_init(kg(), cfg.d_model, cfg.d_ff, cfg.n_experts,
                            cfg.n_shared, dtype=dtype)
    elif cfg.d_ff:
        p["ffn"] = ffn_init(kg(), cfg.d_model, cfg.d_ff, dtype=dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_init(kg(), cfg.ssm_config(), dtype=dtype)
        p["ln_ssm"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def block_apply(params: Dict, cfg, x: jax.Array, *, window=None,
                q_offset=0) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence block forward. Returns (y, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = shard_hint(x, DP, None, None)
    h = rms_norm(x, params["ln1"])
    if cfg.attn_kind == "mla":
        attn_out, _ = mla_prefill(params["attn"], cfg.mla_config(), h,
                                  q_offset=q_offset)
    else:
        attn_out = gqa_full(params["attn"], h, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_kv_heads,
                            head_dim=cfg.resolved_head_dim,
                            rope_theta=cfg.rope_theta, q_offset=q_offset,
                            window=window)
    if cfg.family == "hybrid":  # parallel attn + SSM heads (hymba)
        ssm_out = ssm_apply(params["ssm"], cfg.ssm_config(),
                            rms_norm(x, params["ln_ssm"]))
        attn_out = 0.5 * (attn_out + ssm_out)
    x = x + attn_out
    if cfg.n_experts:
        y, aux = moe_apply(params["moe"], rms_norm(x, params["ln2"]),
                           top_k=cfg.top_k, capacity_factor=cfg.moe_capacity,
                           ffn_kind=cfg.ffn_kind)
        x = x + y
    elif cfg.d_ff:
        x = x + ffn_apply(params["ffn"], rms_norm(x, params["ln2"]),
                          kind=cfg.ffn_kind)
    return x, aux


class BlockCache(NamedTuple):
    kv: Optional[Any] = None      # KVCache or MLACache
    ssm: Optional[SSMCache] = None


def block_decode(params: Dict, cfg, x: jax.Array, cache: BlockCache, *,
                 window=None) -> Tuple[jax.Array, BlockCache]:
    h = rms_norm(x, params["ln1"])
    if cfg.attn_kind == "mla":
        attn_out, kv = mla_decode(params["attn"], cfg.mla_config(), h, cache.kv)
    else:
        attn_out, kv = gqa_decode(params["attn"], h, cache.kv,
                                  n_heads=cfg.n_heads,
                                  n_kv_heads=cfg.n_kv_heads,
                                  head_dim=cfg.resolved_head_dim,
                                  rope_theta=cfg.rope_theta, window=window)
    new_ssm = cache.ssm
    if cfg.family == "hybrid":
        ssm_out, new_ssm = ssm_decode_step(params["ssm"], cfg.ssm_config(),
                                           rms_norm(x, params["ln_ssm"]),
                                           cache.ssm)
        attn_out = 0.5 * (attn_out + ssm_out)
    x = x + attn_out
    if cfg.n_experts:
        y, _ = moe_apply(params["moe"], rms_norm(x, params["ln2"]),
                         top_k=cfg.top_k, capacity_factor=cfg.moe_capacity,
                         ffn_kind=cfg.ffn_kind)
        x = x + y
    elif cfg.d_ff:
        x = x + ffn_apply(params["ffn"], rms_norm(x, params["ln2"]),
                          kind=cfg.ffn_kind)
    return x, BlockCache(kv=kv, ssm=new_ssm)
