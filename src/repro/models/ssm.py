"""Mamba-style selective SSM (diagonal state) with chunked scan.

Training/prefill uses a chunkwise algorithm: ``lax.scan`` over chunks with
an ``associative_scan`` inside each (rematerialized), so compiled activation
memory is O(B · n_chunks · d_inner · N) boundary states plus one chunk's
transient — not the full (B, S, d_inner, N) tensor.  Decode is the O(1)
recurrent update.  This is the TPU-native adaptation of mamba's fused GPU
kernel (which keeps h in SRAM): we keep the chunk transient in VMEM-scale
working sets and let XLA fuse the elementwise chain.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int
    d_state: int = 16
    dt_rank: int = 0        # 0 -> ceil(d_model/16)
    conv_kernel: int = 4
    chunk: int = 256

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def ssm_init(key, cfg: SSMConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    di, n, r = cfg.d_inner, cfg.d_state, cfg.rank
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, di), dtype=dtype, scale=1.0),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, r + 2 * n), dtype=dtype),
        "dt_proj": dense_init(ks[3], (r, di), dtype=dtype),
        "dt_bias": jnp.full((di,), -4.0, dtype),  # softplus ~= small init dt
        "a_log": jnp.log(a),                       # (di, N), A = -exp(a_log)
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], (di, cfg.d_model), dtype=dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv: u (B,S,di), w (K,di)."""
    k = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = init_state
    up = jnp.concatenate([pad, u], axis=1)
    s = u.shape[1]
    # K is tiny (4): unrolled taps over shifted windows, XLA fuses the chain
    out = sum(up[:, i:i + s, :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _ssm_params_from_u(params: Dict, cfg: SSMConfig, u: jax.Array):
    """u (B,S,di) -> dt (B,S,di), Bm (B,S,N), Cm (B,S,N)."""
    r, n = cfg.rank, cfg.d_state
    proj = u @ params["x_proj"]
    dt = jax.nn.softplus(proj[..., :r] @ params["dt_proj"] + params["dt_bias"])
    bm = proj[..., r:r + n]
    cm = proj[..., r + n:]
    return dt, bm, cm


def _chunk_scan(log_a: jax.Array, bu: jax.Array, h0: jax.Array):
    """Associative scan within a chunk.

    log_a, bu: (B, Q, di, N); h0: (B, di, N).
    h_t = exp(log_a_t) * h_{t-1} + bu_t
    Returns hs (B, Q, di, N) and final h (B, di, N).
    """
    def combine(x, y):
        (la1, b1), (la2, b2) = x, y
        return la1 + la2, b1 * jnp.exp(la2) + b2

    la_c, b_c = jax.lax.associative_scan(combine, (log_a, bu), axis=1)
    hs = jnp.exp(la_c) * h0[:, None] + b_c
    return hs, hs[:, -1]


def selective_scan_chunked(u: jax.Array, dt: jax.Array, a_log: jax.Array,
                           bm: jax.Array, cm: jax.Array, d_skip: jax.Array,
                           h0: Optional[jax.Array] = None,
                           chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """u,dt (B,S,di); bm,cm (B,S,N); returns y (B,S,di), h_final (B,di,N)."""
    b, s, di = u.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    nchunks = -(-s // q)
    s_pad = nchunks * q
    if s_pad != s:
        padw = [(0, 0), (0, s_pad - s), (0, 0)]
        u, dt, bm, cm = (jnp.pad(t, padw) for t in (u, dt, bm, cm))
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    a = -jnp.exp(a_log.astype(jnp.float32))  # (di, N)

    def chunk_body(h, xs):
        uc, dtc, bmc, cmc = xs  # (B, Q, ...)
        log_ac = dtc.astype(jnp.float32)[..., None] * a  # (B,Q,di,N)
        buc = (dtc * uc).astype(jnp.float32)[..., None] * bmc[:, :, None, :]
        hs, h_next = _chunk_scan(log_ac, buc, h)
        yc = jnp.einsum("bqdn,bqn->bqd", hs, cmc.astype(jnp.float32))
        yc = yc + uc.astype(jnp.float32) * d_skip[None, None, :]
        return h_next, yc.astype(u.dtype)

    xs = tuple(t.reshape(b, nchunks, q, -1).transpose(1, 0, 2, 3)
               for t in (u, dt, bm, cm))
    h_final, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s_pad, di)[:, :s]
    return y, h_final


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, K-1, di) last inputs
    h: jax.Array      # (B, di, N)


def ssm_apply(params: Dict, cfg: SSMConfig, x: jax.Array,
              chunk: Optional[int] = None) -> jax.Array:
    """Full-sequence forward (training/prefill). x (B,S,D) -> (B,S,D)."""
    ui = x @ params["in_proj"]
    u, z = jnp.split(ui, 2, axis=-1)
    u = jax.nn.silu(_causal_conv(u, params["conv_w"], params["conv_b"]))
    dt, bm, cm = _ssm_params_from_u(params, cfg, u)
    y, _ = selective_scan_chunked(u, dt, params["a_log"], bm, cm,
                                  params["d_skip"].astype(jnp.float32),
                                  chunk=chunk or cfg.chunk)
    return (y * jax.nn.silu(z)) @ params["out_proj"]


def ssm_init_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
        h=jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    )


def ssm_decode_step(params: Dict, cfg: SSMConfig, x: jax.Array,
                    cache: SSMCache) -> Tuple[jax.Array, SSMCache]:
    """One token: x (B,1,D) -> (B,1,D), O(1) state update."""
    ui = x @ params["in_proj"]
    u, z = jnp.split(ui, 2, axis=-1)              # (B,1,di)
    window = jnp.concatenate([cache.conv, u], axis=1)  # (B,K,di)
    w = params["conv_w"]
    u_conv = jnp.einsum("bkd,kd->bd", window, w)[:, None, :] + params["conv_b"]
    u_conv = jax.nn.silu(u_conv)
    dt, bm, cm = _ssm_params_from_u(params, cfg, u_conv)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    log_a = dt[:, 0].astype(jnp.float32)[..., None] * a          # (B,di,N)
    bu = (dt[:, 0] * u_conv[:, 0]).astype(jnp.float32)[..., None] * \
        bm[:, 0][:, None, :]
    h = jnp.exp(log_a) * cache.h + bu
    y = jnp.einsum("bdn,bn->bd", h, cm[:, 0].astype(jnp.float32)) + \
        u_conv[:, 0].astype(jnp.float32) * params["d_skip"]
    y = (y[:, None, :].astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    return y, SSMCache(conv=window[:, 1:], h=h)
