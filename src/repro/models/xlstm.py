"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory) and scan sLSTM.

mLSTM implements the stabilized chunkwise algorithm (official xLSTM form):
within a chunk, a (Q×Q) decay-masked score matrix; across chunks, the
(hd×hd) matrix memory carried with a log-space stabilizer ``m``.  Decode is
the O(1) recurrence.  sLSTM (scalar memory) runs as a ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init

NEG_INF = -1e30


class XLSTMConfig(NamedTuple):
    d_model: int
    n_heads: int = 4
    proj_factor: float = 2.0
    chunk: int = 128
    conv_kernel: int = 4

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# -- mLSTM ---------------------------------------------------------------------


def mlstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 8)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "ln": jnp.zeros((d,), dtype),
        "up_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, di), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], (di, di), dtype=dtype),
        "wk": dense_init(ks[3], (di, di), dtype=dtype),
        "wv": dense_init(ks[4], (di, di), dtype=dtype),
        "w_igate": dense_init(ks[5], (di, h), dtype=jnp.float32),
        "b_igate": jnp.zeros((h,), jnp.float32),
        "w_fgate": dense_init(ks[6], (di, h), dtype=jnp.float32),
        "b_fgate": jnp.full((h,), 3.0, jnp.float32),  # start remembering
        "out_norm": jnp.zeros((di,), dtype),
        "down_proj": dense_init(ks[7], (di, d), dtype=dtype),
    }


def _heads(x: jax.Array, h: int) -> jax.Array:
    b, s, di = x.shape
    return x.reshape(b, s, h, di // h).transpose(0, 2, 1, 3)  # (B,H,S,hd)


def _causal_conv(u, w, b):
    k = w.shape[0]
    pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    s = u.shape[1]
    return sum(up[:, i:i + s, :] * w[i][None, None, :] for i in range(k)) + b


def mlstm_chunkwise(q, k, v, log_i, log_f, state=None, chunk: int = 128):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B,H,S,hd); log_i, log_f: (B,H,S).
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)) or None.
    Returns h (B,H,S,hd), final state.
    """
    b, h, s, hd = q.shape
    qc = min(chunk, s)
    nch = -(-s // qc)
    s_pad = nch * qc
    if s_pad != s:
        padw = [(0, 0), (0, 0), (0, s_pad - s), (0, 0)]
        q, k, v = (jnp.pad(t, padw) for t in (q, k, v))
        log_i = jnp.pad(log_i, [(0, 0), (0, 0), (0, s_pad - s)],
                        constant_values=NEG_INF)
        log_f = jnp.pad(log_f, [(0, 0), (0, 0), (0, s_pad - s)])
    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state
    scale = 1.0 / math.sqrt(hd)

    def body(carry, xs):
        # NOTE: q/k/v stay in their storage dtype inside the scan xs and all
        # matmuls accumulate in f32 via preferred_element_type — an explicit
        # astype(f32) here would be hoisted by XLA into a pre-converted f32
        # copy of the whole stacked (nchunks, ...) tensor (2x HBM traffic).
        c_p, n_p, m_p = carry
        qx, kx, vx, lix, lfx = xs  # (B,H,Q,hd) / (B,H,Q)
        f32 = jnp.float32
        F = jnp.cumsum(lfx, axis=-1)                       # (B,H,Q) decay incl t
        # log decay matrix: D[t,j] = F_t - F_j + li_j  (j<=t)
        logd = F[..., :, None] - F[..., None, :] + lix[..., None, :]
        tri = jnp.tril(jnp.ones((qc, qc), bool))
        logd = jnp.where(tri[None, None], logd, NEG_INF)
        m_intra = logd.max(axis=-1)                        # (B,H,Q)
        m_t = jnp.maximum(m_p[..., None] + F, m_intra)
        w_intra = jnp.exp(logd - m_t[..., None])           # (B,H,Q,Q) f32
        sc = jnp.einsum("bhqd,bhjd->bhqj", qx, kx,
                        preferred_element_type=f32) * scale
        pw = (sc * w_intra)
        num = jnp.einsum("bhqj,bhjd->bhqd", pw.astype(qx.dtype), vx,
                         preferred_element_type=f32)
        # denominator: |q·n_t| where n_t = decayed n_prev + sum_j w k_j
        w_inter = jnp.exp(m_p[..., None] + F - m_t)        # (B,H,Q)
        num = num + w_inter[..., None] * jnp.einsum(
            "bhqd,bhde->bhqe", qx, c_p.astype(qx.dtype),
            preferred_element_type=f32) * scale
        n_t = w_inter[..., None] * n_p[:, :, None, :] + jnp.einsum(
            "bhqj,bhjd->bhqd", w_intra.astype(qx.dtype), kx,
            preferred_element_type=f32)
        den = jnp.abs(jnp.einsum("bhqd,bhqd->bhq", qx.astype(f32) * scale,
                                 n_t))
        hx = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        # carry update
        tot_F = F[..., -1]                                  # (B,H)
        m_out = jnp.maximum(m_p + tot_F,
                            (tot_F[..., None] - F + lix).max(axis=-1))
        decay_c = jnp.exp(m_p + tot_F - m_out)
        w_k = jnp.exp(tot_F[..., None] - F + lix - m_out[..., None])  # (B,H,Q)
        kw = kx.astype(f32) * w_k[..., None]
        c_n = decay_c[..., None, None] * c_p + jnp.einsum(
            "bhqd,bhqe->bhde", kw.astype(qx.dtype), vx,
            preferred_element_type=f32)
        n_n = decay_c[..., None] * n_p + kw.sum(axis=2)
        return (c_n, n_n, m_out), hx

    xs = tuple(t.reshape(b, h, nch, qc, -1).transpose(2, 0, 1, 3, 4)
               for t in (q, k, v)) + tuple(
        t.reshape(b, h, nch, qc).transpose(2, 0, 1, 3) for t in (log_i, log_f))
    (c_f, n_f, m_f), hs = jax.lax.scan(jax.checkpoint(body), (c0, n0, m0), xs)
    hx = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, s_pad, hd)[:, :, :s]
    return hx.astype(q.dtype), (c_f, n_f, m_f)


def mlstm_apply(params: Dict, cfg: XLSTMConfig, x: jax.Array) -> jax.Array:
    """Full-sequence mLSTM block. x (B,S,D) -> (B,S,D)."""
    from .common import DP, shard_hint
    up = x @ params["up_proj"]
    main, z = jnp.split(up, 2, axis=-1)             # (B,S,di)
    conv = jax.nn.silu(_causal_conv(main, params["conv_w"], params["conv_b"]))
    h = cfg.n_heads
    # mixer runs batch-parallel: with n_heads(4) < model-axis size the head
    # reshape defeats TP propagation, so pin batch sharding here (redundant
    # mixer compute on the model axis is ~8% of the block's FLOPs).
    q = shard_hint(_heads(conv @ params["wq"], h), DP, None, None, None)
    k = shard_hint(_heads(conv @ params["wk"], h), DP, None, None, None)
    v = shard_hint(_heads(main @ params["wv"], h), DP, None, None, None)
    log_i = (conv @ params["w_igate"] + params["b_igate"]).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(
        (conv @ params["w_fgate"] + params["b_fgate"])).transpose(0, 2, 1)
    hx, _ = mlstm_chunkwise(q, k, v, log_i.astype(jnp.float32),
                            log_f.astype(jnp.float32), chunk=cfg.chunk)
    hx = hx.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], cfg.d_inner)
    # per-dim RMS-style output norm then skip-gate
    var = jnp.mean(jnp.square(hx.astype(jnp.float32)), axis=-1, keepdims=True)
    hx = (hx.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
          (1.0 + params["out_norm"])).astype(x.dtype)
    return (hx * jax.nn.silu(z)) @ params["down_proj"]


class MLSTMCache(NamedTuple):
    conv: jax.Array   # (B, K-1, di)
    c: jax.Array      # (B, H, hd, hd)
    n: jax.Array      # (B, H, hd)
    m: jax.Array      # (B, H)


def mlstm_init_cache(cfg: XLSTMConfig, batch: int, dtype=jnp.float32) -> MLSTMCache:
    h, hd = cfg.n_heads, cfg.head_dim
    return MLSTMCache(
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
        c=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


def mlstm_decode_step(params: Dict, cfg: XLSTMConfig, x: jax.Array,
                      cache: MLSTMCache) -> Tuple[jax.Array, MLSTMCache]:
    """One-token recurrent mLSTM step. x (B,1,D)."""
    up = x @ params["up_proj"]
    main, z = jnp.split(up, 2, axis=-1)
    window = jnp.concatenate([cache.conv, main], axis=1)
    conv = jnp.einsum("bkd,kd->bd", window, params["conv_w"])[:, None, :] + \
        params["conv_b"]
    conv = jax.nn.silu(conv)
    h, hd = cfg.n_heads, cfg.head_dim
    q = _heads(conv @ params["wq"], h)[:, :, 0].astype(jnp.float32)   # (B,H,hd)
    k = _heads(conv @ params["wk"], h)[:, :, 0].astype(jnp.float32)
    v = _heads(main @ params["wv"], h)[:, :, 0].astype(jnp.float32)
    li = (conv @ params["w_igate"] + params["b_igate"])[:, 0]         # (B,H)
    lf = jax.nn.log_sigmoid((conv @ params["w_fgate"] + params["b_fgate"]))[:, 0]
    m_new = jnp.maximum(lf + cache.m, li)
    di_ = jnp.exp(li - m_new)
    df = jnp.exp(lf + cache.m - m_new)
    c_new = df[..., None, None] * cache.c + di_[..., None, None] * \
        (k[..., :, None] * v[..., None, :])
    n_new = df[..., None] * cache.n + di_[..., None] * k
    scale = 1.0 / math.sqrt(hd)
    num = jnp.einsum("bhd,bhde->bhe", q * scale, c_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q * scale, n_new))
    hx = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]           # (B,H,hd)
    hx = hx.reshape(x.shape[0], 1, cfg.d_inner)
    var = jnp.mean(jnp.square(hx), axis=-1, keepdims=True)
    hx = (hx * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["out_norm"])).astype(x.dtype)
    out = (hx * jax.nn.silu(z)) @ params["down_proj"]
    return out, MLSTMCache(conv=window[:, 1:], c=c_new, n=n_new, m=m_new)


# -- sLSTM ---------------------------------------------------------------------


def slstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    d, di = cfg.d_model, cfg.d_inner
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_z": dense_init(ks[0], (d, di), dtype=dtype),
        "w_i": dense_init(ks[1], (d, di), dtype=jnp.float32),
        "w_f": dense_init(ks[2], (d, di), dtype=jnp.float32),
        "b_f": jnp.full((di,), 3.0, jnp.float32),
        "w_o": dense_init(ks[3], (d, di), dtype=dtype),
        "down_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def slstm_apply(params: Dict, cfg: XLSTMConfig, x: jax.Array,
                state=None) -> jax.Array:
    """Scalar-memory LSTM with exponential gating, scanned over S."""
    b, s, d = x.shape
    di = cfg.d_inner
    z = jnp.tanh(x @ params["w_z"]).astype(jnp.float32)
    li = (x.astype(jnp.float32) @ params["w_i"])
    lf = jax.nn.log_sigmoid(x.astype(jnp.float32) @ params["w_f"] + params["b_f"])
    o = jax.nn.sigmoid((x @ params["w_o"]).astype(jnp.float32))
    if state is None:
        c0 = jnp.zeros((b, di), jnp.float32)
        n0 = jnp.zeros((b, di), jnp.float32)
        m0 = jnp.full((b, di), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, xs):
        c, n, m = carry
        zt, lit, lft, ot = xs
        m_new = jnp.maximum(lft + m, lit)
        i_s = jnp.exp(lit - m_new)
        f_s = jnp.exp(lft + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new), h

    xs = tuple(t.transpose(1, 0, 2) for t in (z, li, lf, o))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    return h @ params["down_proj"]


class SLSTMCache(NamedTuple):
    c: jax.Array
    n: jax.Array
    m: jax.Array


def slstm_init_cache(cfg: XLSTMConfig, batch: int) -> SLSTMCache:
    di = cfg.d_inner
    return SLSTMCache(jnp.zeros((batch, di), jnp.float32),
                      jnp.zeros((batch, di), jnp.float32),
                      jnp.full((batch, di), -1e30, jnp.float32))


def slstm_decode_step(params: Dict, cfg: XLSTMConfig, x: jax.Array,
                      cache: SLSTMCache) -> Tuple[jax.Array, SLSTMCache]:
    xt = x[:, 0]
    z = jnp.tanh(xt @ params["w_z"]).astype(jnp.float32)
    li = xt.astype(jnp.float32) @ params["w_i"]
    lf = jax.nn.log_sigmoid(xt.astype(jnp.float32) @ params["w_f"] + params["b_f"])
    o = jax.nn.sigmoid((xt @ params["w_o"]).astype(jnp.float32))
    m_new = jnp.maximum(lf + cache.m, li)
    i_s = jnp.exp(li - m_new)
    f_s = jnp.exp(lf + cache.m - m_new)
    c_new = f_s * cache.c + i_s * z
    n_new = f_s * cache.n + i_s
    h = (o * c_new / jnp.maximum(n_new, 1e-6)).astype(x.dtype)
    out = (h @ params["down_proj"])[:, None, :]
    return out, SLSTMCache(c_new, n_new, m_new)
