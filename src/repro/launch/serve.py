"""Batched decode server loop + bucket-aware request batching.

``serve`` demonstrates the decode path of every architecture (KV caches
for transformers, latent caches for MLA, recurrent states for SSM/xLSTM).
``BucketBatcher`` is the shape-bucketed serving front end: it groups
queued requests by specialization bucket before dispatch, so one
specialized plan — lowered to a flat executable ``Program`` run by the
slim VM — serves each group, and admission control can reason in
per-bucket guaranteed arena bounds.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.obs import AdmissionEvent
from ..models import decode_step, forward, init_cache, init_params


# -- bucket-aware batching -----------------------------------------------------


@dataclass
class BucketGroup:
    """One drained batch: same-bucket requests dispatched together."""

    key: Tuple[int, ...]
    label: str                               # human-readable bucket ranges
    envs: List[Dict[str, int]]
    payloads: List[Any]
    # guaranteed worst-case arena size of the bucket's plan (None when the
    # bucket has an unbounded dim or memory_plan="none")
    arena_bound_bytes: Optional[int] = None
    # instruction count of the bucket's lowered Program when its plan is
    # resident (None: not yet compiled, or executor="reference") — an
    # observability hook: the group will run a flat executable, and this
    # is how long it is
    n_instructions: Optional[int] = None

    def __len__(self) -> int:
        return len(self.envs)


class BucketBatcher:
    """Groups queued requests into specialization buckets before dispatch.

    Serving traffic is shape-diverse; dispatching each request alone makes
    every shape a fresh arena resolve, and dispatching mixed shapes in
    arrival order ping-pongs between bucket plans.  The batcher instead
    queues ``(env, payload)`` requests, keyed by the bucket the env lands
    in (same O(log n) lookup the call path uses), and ``drain()`` returns
    same-bucket groups — buckets with a resident specialized plan first
    (so background specialization never blocks hot traffic), largest
    group first within each class.

    ``memory_budget`` enables admission control by bucket: a group whose
    bucket plan carries ``arena_bound_bytes`` above the budget stays
    queued (the bound is a *guarantee* — any request in the bucket fits
    under it), so the server can run small-shape traffic while deferring
    heavy buckets to a bigger worker or an off-peak window.
    """

    def __init__(self, fn, *, memory_budget: Optional[int] = None):
        table = getattr(fn, "specialization_table", None)
        if table is None:
            raise ValueError(
                "BucketBatcher requires a bucketed function — build it with "
                "optimize(..., dynamic_dims=..., buckets=...)")
        self.fn = fn
        self.table = table
        self.memory_budget = memory_budget
        # bucket key -> queued (env, payload), FIFO within a bucket
        self._queue: "OrderedDict[Tuple[int, ...], List[Tuple[Dict[str, int], Any]]]" = OrderedDict()
        # admission-control observability: cumulative hold count, per-bucket
        # breakdown, and the most recent structured events (bounded — a
        # perpetually-held bucket must not grow memory drain after drain)
        self.held_count = 0
        self.held_by_key: Dict[Tuple[int, ...], int] = {}
        self.admission_events: "deque[AdmissionEvent]" = deque(maxlen=256)

    def submit(self, env: Mapping[str, int], payload: Any = None) -> Tuple[int, ...]:
        """Queue one request; returns the bucket key it grouped under.

        An env outside the declared ranges raises here — at intake, where
        the client error belongs — rather than mid-drain after the group
        was admitted under a bucket bound the request does not satisfy.
        """
        key = self.table.key_of(env)
        self._queue.setdefault(key, []).append((dict(env), payload))
        return key

    def pending(self) -> int:
        return sum(len(reqs) for reqs in self._queue.values())

    def pending_by_bucket(self) -> Dict[Tuple[int, ...], int]:
        return {key: len(reqs) for key, reqs in self._queue.items()}

    def drain(self) -> List[BucketGroup]:
        """Admitted same-bucket groups — compiled buckets first, then by
        group size; held groups remain.

        Buckets whose specialized plan is already resident dispatch ahead
        of buckets that would still need a compile: with background
        specialization that keeps the worker serving specialized traffic
        at full speed while cold buckets finish compiling off-thread
        (their requests run on the whole-range fallback only if drained
        before the swap lands).  Within each class, largest group first.

        A group is held when ``memory_budget`` is set and the bucket's
        guaranteed arena bound exceeds it.  Admission asks the table for
        the bound, which compiles a bucket the *first* time it is ever
        seen (bounds are then remembered across plan eviction, so held
        buckets are not recompiled drain after drain) — in background
        mode it instead schedules the compile and admits against the
        conservative whole-range bound; use ``fn.warmup(envs)``
        beforehand to move even that first compile off the serving path.
        """
        admitted: List[BucketGroup] = []
        held: "OrderedDict[Tuple[int, ...], List[Tuple[Dict[str, int], Any]]]" = OrderedDict()
        order = sorted(self._queue,
                       key=lambda k: (self.table.peek(k) is None,
                                      -len(self._queue[k])))
        for key in order:
            reqs = self._queue[key]
            bound = self.table.arena_bound_bytes(key)
            if self.memory_budget is not None and bound is not None \
                    and bound > self.memory_budget:
                # structured admission event: what was refused, what it
                # needed, what was available, and how deep its queue is —
                # the silent-hold observability gap this surface closes
                self.held_count += 1
                self.held_by_key[key] = self.held_by_key.get(key, 0) + 1
                self.admission_events.append(AdmissionEvent(
                    key=key, label=self.table.space.describe(key),
                    required_bytes=bound,
                    available_bytes=self.memory_budget,
                    queue_depth=len(reqs)))
                held[key] = reqs
                continue
            # resident plans carry their lowered Program; peek only — a
            # group must never force a compile just to report its length
            resident = self.table.peek(key)
            admitted.append(BucketGroup(
                key=key, label=self.table.space.describe(key),
                envs=[e for e, _ in reqs], payloads=[p for _, p in reqs],
                arena_bound_bytes=bound,
                n_instructions=None if resident is None
                else resident.n_instructions))
        self._queue = held
        return admitted

    def metrics_text(self, prefix: str = "repro") -> str:
        """Prometheus text metrics for this batcher + its function:
        per-bucket hit/miss/arena-bound series and the admission-control
        counters (``held_total``, per-bucket holds, queue depth)."""
        from ..core.obs import prometheus_text
        return prometheus_text(fn=self.fn, batcher=self, prefix=prefix)


def serve(cfg, *, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          seed: int = 0, greedy: bool = True):
    rng = np.random.RandomState(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    max_len = prompt_len + gen + 1
    state = init_cache(cfg, batch, max_len=max_len)

    if cfg.input_mode == "embeddings":
        prompt = jnp.asarray(rng.randn(batch, prompt_len, cfg.d_model),
                             jnp.float32)
        feed = lambda t: {"frame_embed": prompt[:, t:t + 1]}
    else:
        prompt_toks = jnp.asarray(rng.randint(1, cfg.vocab,
                                              (batch, prompt_len)), jnp.int32)
        feed = lambda t: {"token": prompt_toks[:, t:t + 1]}

    sfn = jax.jit(lambda p, s, i: decode_step(cfg, p, s, i))

    t0 = time.time()
    logits = None
    for t in range(prompt_len):           # cache-filling prefill
        logits, state = sfn(params, state, feed(t))
    prefill_s = time.time() - t0

    out_tokens = []
    t1 = time.time()
    cur = None
    for _ in range(gen):
        if cfg.n_codebooks:
            nxt = jnp.argmax(logits[:, -1], axis=-1)         # (B, K)
            out_tokens.append(np.asarray(nxt))
            emb = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
            logits, state = sfn(params, state, {"frame_embed": emb})
            continue
        nxt = jnp.argmax(logits[:, -1], axis=-1)             # (B,)
        out_tokens.append(np.asarray(nxt))
        logits, state = sfn(params, state, {"token": nxt[:, None]})
    jax.block_until_ready(logits)
    decode_s = time.time() - t1
    return {
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "decode_tok_per_s": batch * gen / max(decode_s, 1e-9),
        "tokens": np.stack(out_tokens, axis=1) if out_tokens else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    r = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
    print(f"[serve] prefill {r['prefill_s']*1000:.0f} ms, "
          f"decode {r['decode_tok_per_s']:.1f} tok/s")
    if r["tokens"] is not None:
        print("[serve] sample:", r["tokens"][0][:10].tolist())


if __name__ == "__main__":
    main()
