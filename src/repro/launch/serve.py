"""Batched decode server loop + bucket-aware request batching.

``serve`` demonstrates the decode path of every architecture (KV caches
for transformers, latent caches for MLA, recurrent states for SSM/xLSTM).
``BucketBatcher`` is the shape-bucketed serving front end: it groups
queued requests by specialization bucket before dispatch, so one
specialized plan — lowered to a flat executable ``Program`` run by the
slim VM — serves each group, and admission control can reason in
per-bucket guaranteed arena bounds.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.obs import AdmissionEvent
from ..models import decode_step, forward, init_cache, init_params


# -- bucket-aware batching -----------------------------------------------------


@dataclass
class BucketGroup:
    """One drained batch: same-bucket requests dispatched together."""

    key: Tuple[int, ...]
    label: str                               # human-readable bucket ranges
    envs: List[Dict[str, int]]
    payloads: List[Any]
    # guaranteed worst-case arena size of the bucket's plan (None when the
    # bucket has an unbounded dim or memory_plan="none")
    arena_bound_bytes: Optional[int] = None
    # instruction count of the bucket's lowered Program when its plan is
    # resident (None: not yet compiled, or executor="reference") — an
    # observability hook: the group will run a flat executable, and this
    # is how long it is
    n_instructions: Optional[int] = None

    def __len__(self) -> int:
        return len(self.envs)


class BucketBatcher:
    """Groups queued requests into specialization buckets before dispatch.

    Serving traffic is shape-diverse; dispatching each request alone makes
    every shape a fresh arena resolve, and dispatching mixed shapes in
    arrival order ping-pongs between bucket plans.  The batcher instead
    queues ``(env, payload)`` requests, keyed by the bucket the env lands
    in (same O(log n) lookup the call path uses), and ``drain()`` returns
    same-bucket groups — buckets with a resident specialized plan first
    (so background specialization never blocks hot traffic), largest
    group first within each class.

    ``memory_budget`` enables admission control by bucket: a group whose
    bucket plan carries ``arena_bound_bytes`` above the budget stays
    queued (the bound is a *guarantee* — any request in the bucket fits
    under it), so the server can run small-shape traffic while deferring
    heavy buckets to a bigger worker or an off-peak window.

    Serve hardening (every knob defaults *off*, preserving the plain
    grouping behavior):

    * ``max_queue`` bounds the total queue; a full queue applies
      ``shed_policy`` — ``"reject-new"`` raises a structured
      :class:`~repro.core.resilience.RequestRejected` at submit,
      ``"drop-oldest"`` evicts the oldest queued request instead.
    * ``default_deadline_s`` / ``submit(..., deadline_s=)`` attach a
      deadline; requests still queued when it expires are shed at the
      next drain (``shed-deadline``) instead of dispatching stale.
    * ``max_hold_cycles`` ages out over-budget groups: a group held more
      than this many drains is shed whole (``shed-aged``) rather than
      re-enqueued forever — the unbounded-requeue gap this closes.
      ``hold_backoff_s`` (doubled by ``hold_backoff_factor`` per
      consecutive hold, per bucket) keeps a held group quietly queued
      between re-checks instead of re-probing the bound every drain.

    Every shed is recorded: ``shed_count`` / ``shed_by_outcome``
    counters, an :class:`AdmissionEvent` with the matching ``outcome``,
    and the shed requests themselves retrievable via :meth:`take_shed`
    so a serve loop can answer those clients.
    """

    def __init__(self, fn, *, memory_budget: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject-new",
                 max_hold_cycles: Optional[int] = None,
                 hold_backoff_s: float = 0.0,
                 hold_backoff_factor: float = 2.0,
                 default_deadline_s: Optional[float] = None,
                 clock=time.monotonic):
        table = getattr(fn, "specialization_table", None)
        if table is None:
            raise ValueError(
                "BucketBatcher requires a bucketed function — build it with "
                "optimize(..., dynamic_dims=..., buckets=...)")
        if shed_policy not in ("reject-new", "drop-oldest"):
            raise ValueError(
                f"shed_policy must be 'reject-new' or 'drop-oldest', "
                f"got {shed_policy!r}")
        self.fn = fn
        self.table = table
        self.memory_budget = memory_budget
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.max_hold_cycles = max_hold_cycles
        self.hold_backoff_s = hold_backoff_s
        self.hold_backoff_factor = hold_backoff_factor
        self.default_deadline_s = default_deadline_s
        self._clock = clock
        # bucket key -> queued (env, payload, deadline_t), FIFO per bucket
        self._queue: "OrderedDict[Tuple[int, ...], List[Tuple[Dict[str, int], Any, Optional[float]]]]" = OrderedDict()
        # admission-control observability: cumulative hold count, per-bucket
        # breakdown, and the most recent structured events (bounded — a
        # perpetually-held bucket must not grow memory drain after drain)
        self.held_count = 0
        self.held_by_key: Dict[Tuple[int, ...], int] = {}
        self.admission_events: "deque[AdmissionEvent]" = deque(maxlen=256)
        # shed accounting: counters by outcome + the shed requests
        # themselves (bounded), retrievable once via take_shed()
        self.shed_count = 0
        self.shed_by_outcome: Dict[str, int] = {}
        self._shed: "deque[Tuple[Tuple[int, ...], Dict[str, int], Any, str]]" = deque(maxlen=256)
        # per-bucket hold aging: key -> [consecutive holds, next check t]
        self._hold_state: Dict[Tuple[int, ...], List[float]] = {}

    def _record_shed(self, key: Tuple[int, ...], reqs, outcome: str,
                     *, required: int = 0, available: int = 0) -> None:
        self.shed_count += len(reqs)
        self.shed_by_outcome[outcome] = \
            self.shed_by_outcome.get(outcome, 0) + len(reqs)
        self.admission_events.append(AdmissionEvent(
            key=key, label=self.table.space.describe(key),
            required_bytes=required, available_bytes=available,
            queue_depth=len(reqs), outcome=outcome))
        for env, payload, _dl in reqs:
            self._shed.append((key, env, payload, outcome))

    def _drop_oldest(self) -> None:
        """Evict the oldest queued request (the first request of the
        first-queued bucket) to make room for a new one."""
        for key in self._queue:
            reqs = self._queue[key]
            self._record_shed(key, reqs[:1], "shed-capacity")
            del reqs[0]
            if not reqs:
                del self._queue[key]
            return

    def submit(self, env: Mapping[str, int], payload: Any = None, *,
               deadline_s: Optional[float] = None) -> Tuple[int, ...]:
        """Queue one request; returns the bucket key it grouped under.

        An env outside the declared ranges raises here — at intake, where
        the client error belongs — rather than mid-drain after the group
        was admitted under a bucket bound the request does not satisfy.
        With ``max_queue`` set, a full queue sheds per ``shed_policy``:
        ``reject-new`` raises :class:`RequestRejected` (structured — the
        caller answers the client), ``drop-oldest`` evicts silently into
        :meth:`take_shed`.  ``deadline_s`` (default
        ``default_deadline_s``) bounds how long the request may wait.
        """
        key = self.table.key_of(env)
        if self.max_queue is not None and self.pending() >= self.max_queue:
            if self.shed_policy == "drop-oldest":
                self._drop_oldest()
            else:
                from ..core.resilience import RequestRejected
                self.shed_count += 1
                self.shed_by_outcome["shed-capacity"] = \
                    self.shed_by_outcome.get("shed-capacity", 0) + 1
                self.admission_events.append(AdmissionEvent(
                    key=key, label=self.table.space.describe(key),
                    required_bytes=0, available_bytes=0,
                    queue_depth=self.pending(), outcome="shed-capacity"))
                raise RequestRejected(
                    f"queue full ({self.max_queue} pending); request shed",
                    reason="shed-capacity", env=env, bucket=key)
        dl = deadline_s if deadline_s is not None else self.default_deadline_s
        deadline_t = None if dl is None else self._clock() + dl
        self._queue.setdefault(key, []).append(
            (dict(env), payload, deadline_t))
        return key

    def pending(self) -> int:
        return sum(len(reqs) for reqs in self._queue.values())

    def pending_by_bucket(self) -> Dict[Tuple[int, ...], int]:
        return {key: len(reqs) for key, reqs in self._queue.items()}

    def drain(self) -> List[BucketGroup]:
        """Admitted same-bucket groups — compiled buckets first, then by
        group size; held groups remain.

        Buckets whose specialized plan is already resident dispatch ahead
        of buckets that would still need a compile: with background
        specialization that keeps the worker serving specialized traffic
        at full speed while cold buckets finish compiling off-thread
        (their requests run on the whole-range fallback only if drained
        before the swap lands).  Within each class, largest group first.

        A group is held when ``memory_budget`` is set and the bucket's
        guaranteed arena bound exceeds it.  Admission asks the table for
        the bound, which compiles a bucket the *first* time it is ever
        seen (bounds are then remembered across plan eviction, so held
        buckets are not recompiled drain after drain) — in background
        mode it instead schedules the compile and admits against the
        conservative whole-range bound; use ``fn.warmup(envs)``
        beforehand to move even that first compile off the serving path.

        Hardening hooks (when configured): expired-deadline requests are
        shed before admission, a group inside its hold-backoff window
        stays queued without re-checking, and a group held more than
        ``max_hold_cycles`` drains is shed whole instead of re-enqueued
        indefinitely.
        """
        admitted: List[BucketGroup] = []
        held: "OrderedDict[Tuple[int, ...], List[Tuple[Dict[str, int], Any, Optional[float]]]]" = OrderedDict()
        now = self._clock()
        order = sorted(self._queue,
                       key=lambda k: (self.table.peek(k) is None,
                                      -len(self._queue[k])))
        for key in order:
            reqs = self._queue[key]
            # deadline shedding first: a request whose deadline passed in
            # queue must not dispatch stale, whatever its group's fate
            expired = [r for r in reqs if r[2] is not None and r[2] <= now]
            if expired:
                self._record_shed(key, expired, "shed-deadline")
                reqs = [r for r in reqs if not (r[2] is not None
                                                and r[2] <= now)]
                if not reqs:
                    self._hold_state.pop(key, None)
                    continue
            bound = self.table.arena_bound_bytes(key)
            if self.memory_budget is not None and bound is not None \
                    and bound > self.memory_budget:
                st = self._hold_state.get(key)
                if st is not None and now < st[1]:
                    held[key] = reqs      # inside the backoff window
                    continue
                cycles = int(st[0]) + 1 if st is not None else 1
                if self.max_hold_cycles is not None \
                        and cycles > self.max_hold_cycles:
                    # aged out: shed the whole group instead of holding
                    # it (and re-probing its bound) forever
                    self._record_shed(key, reqs, "shed-aged",
                                      required=bound,
                                      available=self.memory_budget)
                    self._hold_state.pop(key, None)
                    continue
                backoff = self.hold_backoff_s \
                    * (self.hold_backoff_factor ** (cycles - 1)) \
                    if self.hold_backoff_s else 0.0
                self._hold_state[key] = [cycles, now + backoff]
                # structured admission event: what was refused, what it
                # needed, what was available, and how deep its queue is —
                # the silent-hold observability gap this surface closes
                self.held_count += 1
                self.held_by_key[key] = self.held_by_key.get(key, 0) + 1
                self.admission_events.append(AdmissionEvent(
                    key=key, label=self.table.space.describe(key),
                    required_bytes=bound,
                    available_bytes=self.memory_budget,
                    queue_depth=len(reqs)))
                held[key] = reqs
                continue
            self._hold_state.pop(key, None)
            # resident plans carry their lowered Program; peek only — a
            # group must never force a compile just to report its length
            resident = self.table.peek(key)
            admitted.append(BucketGroup(
                key=key, label=self.table.space.describe(key),
                envs=[e for e, _, _ in reqs],
                payloads=[p for _, p, _ in reqs],
                arena_bound_bytes=bound,
                n_instructions=None if resident is None
                else resident.n_instructions))
        self._queue = held
        return admitted

    def take_shed(self) -> List[Tuple[Tuple[int, ...], Dict[str, int],
                                      Any, str]]:
        """Drain the shed-request record: ``(key, env, payload, outcome)``
        per shed request, oldest first.  A serve loop calls this after
        ``drain()`` to answer the clients whose requests were shed."""
        out = list(self._shed)
        self._shed.clear()
        return out

    def process(self, groups: Optional[List[BucketGroup]] = None
                ) -> List[Dict[str, Any]]:
        """Drain (unless given ``groups``) and run every admitted request
        through the function — the hardened serve inner loop.

        Each payload is treated as the request's call arguments (a tuple
        is splatted, anything else passed as the single argument).  Only
        the structured :class:`~repro.core.resilience.RequestFailed` is
        caught — with resilience enabled one failing request yields a
        structured outcome instead of killing the loop, while unexpected
        exceptions still propagate loudly.  Returns one outcome dict per
        request: ``env``, ``bucket``, ``payload``, ``ok``, and ``value``
        + ``report`` + ``arena_bound`` (success) or ``error`` (failure).
        """
        from ..core.resilience import RequestFailed
        if groups is None:
            groups = self.drain()
        outcomes: List[Dict[str, Any]] = []
        for g in groups:
            for env, payload in zip(g.envs, g.payloads):
                base = {"env": env, "bucket": g.key, "payload": payload}
                try:
                    args = payload if isinstance(payload, tuple) \
                        else (payload,)
                    value = self.fn(*args)
                    outcomes.append(dict(
                        base, ok=True, value=value,
                        report=self.fn.last_report,
                        arena_bound=getattr(self.fn, "last_arena_bound",
                                            None)))
                except RequestFailed as e:
                    outcomes.append(dict(base, ok=False, error=e))
        return outcomes

    def metrics_text(self, prefix: str = "repro") -> str:
        """Prometheus text metrics for this batcher + its function:
        per-bucket hit/miss/arena-bound series and the admission-control
        counters (``held_total``, per-bucket holds, queue depth)."""
        from ..core.obs import prometheus_text
        return prometheus_text(fn=self.fn, batcher=self, prefix=prefix)


def serve(cfg, *, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          seed: int = 0, greedy: bool = True):
    rng = np.random.RandomState(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    max_len = prompt_len + gen + 1
    state = init_cache(cfg, batch, max_len=max_len)

    if cfg.input_mode == "embeddings":
        prompt = jnp.asarray(rng.randn(batch, prompt_len, cfg.d_model),
                             jnp.float32)
        feed = lambda t: {"frame_embed": prompt[:, t:t + 1]}
    else:
        prompt_toks = jnp.asarray(rng.randint(1, cfg.vocab,
                                              (batch, prompt_len)), jnp.int32)
        feed = lambda t: {"token": prompt_toks[:, t:t + 1]}

    sfn = jax.jit(lambda p, s, i: decode_step(cfg, p, s, i))

    t0 = time.time()
    logits = None
    for t in range(prompt_len):           # cache-filling prefill
        logits, state = sfn(params, state, feed(t))
    prefill_s = time.time() - t0

    out_tokens = []
    t1 = time.time()
    cur = None
    for _ in range(gen):
        if cfg.n_codebooks:
            nxt = jnp.argmax(logits[:, -1], axis=-1)         # (B, K)
            out_tokens.append(np.asarray(nxt))
            emb = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
            logits, state = sfn(params, state, {"frame_embed": emb})
            continue
        nxt = jnp.argmax(logits[:, -1], axis=-1)             # (B,)
        out_tokens.append(np.asarray(nxt))
        logits, state = sfn(params, state, {"token": nxt[:, None]})
    jax.block_until_ready(logits)
    decode_s = time.time() - t1
    return {
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "decode_tok_per_s": batch * gen / max(decode_s, 1e-9),
        "tokens": np.stack(out_tokens, axis=1) if out_tokens else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    r = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
    print(f"[serve] prefill {r['prefill_s']*1000:.0f} ms, "
          f"decode {r['decode_tok_per_s']:.1f} tok/s")
    if r["tokens"] is not None:
        print("[serve] sample:", r["tokens"][0][:10].tolist())


if __name__ == "__main__":
    main()
