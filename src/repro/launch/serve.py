"""Batched decode server loop: prefill + token-by-token generation.

Demonstrates the serving path of every architecture (KV caches for
transformers, latent caches for MLA, recurrent states for SSM/xLSTM).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import decode_step, forward, init_cache, init_params


def serve(cfg, *, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          seed: int = 0, greedy: bool = True):
    rng = np.random.RandomState(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    max_len = prompt_len + gen + 1
    state = init_cache(cfg, batch, max_len=max_len)

    if cfg.input_mode == "embeddings":
        prompt = jnp.asarray(rng.randn(batch, prompt_len, cfg.d_model),
                             jnp.float32)
        feed = lambda t: {"frame_embed": prompt[:, t:t + 1]}
    else:
        prompt_toks = jnp.asarray(rng.randint(1, cfg.vocab,
                                              (batch, prompt_len)), jnp.int32)
        feed = lambda t: {"token": prompt_toks[:, t:t + 1]}

    sfn = jax.jit(lambda p, s, i: decode_step(cfg, p, s, i))

    t0 = time.time()
    logits = None
    for t in range(prompt_len):           # cache-filling prefill
        logits, state = sfn(params, state, feed(t))
    prefill_s = time.time() - t0

    out_tokens = []
    t1 = time.time()
    cur = None
    for _ in range(gen):
        if cfg.n_codebooks:
            nxt = jnp.argmax(logits[:, -1], axis=-1)         # (B, K)
            out_tokens.append(np.asarray(nxt))
            emb = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
            logits, state = sfn(params, state, {"frame_embed": emb})
            continue
        nxt = jnp.argmax(logits[:, -1], axis=-1)             # (B,)
        out_tokens.append(np.asarray(nxt))
        logits, state = sfn(params, state, {"token": nxt[:, None]})
    jax.block_until_ready(logits)
    decode_s = time.time() - t1
    return {
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "decode_tok_per_s": batch * gen / max(decode_s, 1e-9),
        "tokens": np.stack(out_tokens, axis=1) if out_tokens else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    r = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
    print(f"[serve] prefill {r['prefill_s']*1000:.0f} ms, "
          f"decode {r['decode_tok_per_s']:.1f} tok/s")
    if r["tokens"] is not None:
        print("[serve] sample:", r["tokens"][0][:10].tolist())


if __name__ == "__main__":
    main()
