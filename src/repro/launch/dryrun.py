import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this produces the compiled artifact's memory analysis, cost
analysis (FLOPs / bytes), and the collective-bytes tally parsed from the
optimized HLO — the inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..configs import ARCHS, cells_for, get_config
from ..configs.base import SHAPES
from .mesh import make_production_mesh
from .sharding import ShardingRules
from .specs import input_specs
from .steps import make_prefill_step, make_serve_step, make_train_step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes of every collective op in the optimized HLO."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s.startswith("%") and " = " not in s:
            continue
        m = re.match(r"%?[\w.\-]+ = (.*?) (all-gather|all-reduce|reduce-scatter|"
                     r"all-to-all|collective-permute)(-start|-done)?\(", s)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        out[m.group(2)] += _shape_bytes(m.group(1))
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             compile_cell: bool = True, grad_accum: int = 8) -> Dict[str, Any]:
    cfg = get_config(arch)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    skip = dict(cells_for(cfg))[shape_name].get("skip")
    if skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = skip
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh)
    kind, specs = input_specs(cfg, shape_name)

    def shard(tree, spec_fn):
        pspecs = spec_fn(tree)
        shardings = rules.named(pspecs)
        return jax.tree.map(
            lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
            tree, shardings)

    rec["grad_accum"] = grad_accum if kind == "train" else None
    with mesh:
        if kind == "train":
            fn = make_train_step(cfg, grad_accum=grad_accum)
            args = (shard(specs["params"], rules.params_pspecs),
                    shard(specs["opt_state"], rules.params_pspecs),
                    shard(specs["batch"], rules.batch_specs))
            jfn = jax.jit(fn, donate_argnums=(0, 1))
        elif kind == "prefill":
            fn = make_prefill_step(cfg)
            args = (shard(specs["params"], rules.params_pspecs),
                    shard(specs["batch"], rules.batch_specs))
            jfn = jax.jit(fn)
        else:
            fn = make_serve_step(cfg)
            args = (shard(specs["params"], rules.params_pspecs),
                    shard(specs["state"], rules.cache_specs),
                    shard(specs["inp"], rules.batch_specs))
            jfn = jax.jit(fn, donate_argnums=(1,))

        lowered = jfn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        if not compile_cell:
            rec["status"] = "lowered"
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        try:
            rec["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            }
            # per-device total (arguments are sharded already)
            rec["memory"]["total_per_device_bytes"] = (
                rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
                + rec["memory"]["output_bytes"])
        except AttributeError:
            rec["memory"] = {"repr": str(mem)}

        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float)) and (
                               "flops" in k or "bytes" in k or k == "utilization")}
            rec["flops"] = float(ca.get("flops", 0.0))
            rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        except Exception as e:  # cost analysis missing on some backends
            rec["cost_error"] = str(e)

        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)  # unscaled (per HLO body)
        try:
            from .hlo_analysis import HLOAnalyzer
            an = HLOAnalyzer(hlo)
            scaled = an.analyze()
            rec["scaled"] = {k: float(v) for k, v in scaled.items()}
            rec["scaled_warnings"] = len(an.warnings)
        except Exception as e:
            rec["scaled_error"] = str(e)
        rec["hlo_bytes"] = len(hlo)
        rec["sharding_fallbacks"] = dict(rules.fallbacks)
        rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [a for a in ARCHS if a != "llama2_1b"] if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod=mp)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    mem = rec.get("memory", {}).get("total_per_device_bytes")
                    sc = rec.get("scaled", {})
                    extra = (f" mem/dev={mem/2**30:.2f}GiB" if mem else "") + \
                        f" flops={sc.get('flops', 0):.3e}" + \
                        f" hbm={sc.get('hbm_bytes', 0)/2**30:.1f}GiB" + \
                        f" coll={sc.get('collective_bytes', 0)/2**30:.2f}GiB"
                print(f"[done] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
