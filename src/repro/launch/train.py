"""End-to-end trainer.

Two execution paths share the data pipeline / optimizer / checkpointing:

  * ``compiled``  — jit + mesh sharding (production; dry-run lowers this);
  * ``dynamic``   — the BladeDISC++ path: one symbolic trace, the op
    scheduler + runtime remat execute every variable-shape batch without
    recompilation or padding (paper §2/§3).

Usage (CPU scale-down):
    PYTHONPATH=src python -m repro.launch.train --arch llama2-1b --smoke \
        --steps 50 --mode dynamic --memory-limit-mb 200
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import get_config, get_smoke_config
from ..core import optimize, symbolic_dims
from ..data import DataPipeline, PipelineConfig
from ..distributed import StragglerMonitor
from ..models import init_params
from ..optim import init_state
from .steps import adamw_config_for, make_train_step


def build_dynamic_step(cfg, params, opt_state):
    """Symbolically trace the train step once; runs any (B, S)."""
    import dataclasses
    cfg = dataclasses.replace(cfg, scan_layers=False)  # flat graph for the
    # symbolic optimizer (scheduling + remat own the memory plan)
    B, S = symbolic_dims("b, s")
    step = make_train_step(cfg)
    p_spec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    o_spec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state)
    batch_spec = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                  "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                  "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
    return optimize(step, p_spec, o_spec, batch_spec, donate_inputs=True)


def train(cfg, *, steps: int = 50, batch_size: int = 8, mode: str = "dynamic",
          memory_limit: Optional[int] = None, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 25, resume: bool = True, data_mode: str = "dynamic",
          log_every: int = 10, seed: int = 0) -> Dict[str, Any]:
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_state(params, adamw_config_for(cfg))
    pipe = DataPipeline(PipelineConfig(vocab=cfg.vocab, batch_size=batch_size,
                                       seed=seed, mode=data_mode,
                                       min_tokens=16, max_tokens=96))
    ck = Checkpointer(ckpt_dir, keep=3) if ckpt_dir else None
    start_step = 0
    if ck is not None and resume and ck.latest_step() is not None:
        start_step, state, extra = ck.restore()
        params, opt_state = state["params"], state["opt_state"]
        pipe.restore(extra["pipeline"])
        print(f"[train] resumed from step {start_step}")

    monitor = StragglerMonitor()
    stats: Dict[str, Any] = {"losses": [], "tokens": 0, "peak_bytes": 0,
                             "recompilations": 0}

    if mode == "dynamic":
        dyn = build_dynamic_step(cfg, params, opt_state)
        if memory_limit:
            dyn = dyn.with_memory_limit(memory_limit)
        step_fn = None
    else:
        jit_cache: Dict[Any, Any] = {}
        base_step = make_train_step(cfg)

        def step_fn(params, opt_state, batch):
            key = batch["tokens"].shape
            if key not in jit_cache:
                jit_cache[key] = jax.jit(base_step, donate_argnums=(0, 1))
                stats["recompilations"] += 1
            return jit_cache[key](params, opt_state, batch)

    t0 = time.time()
    for step in range(start_step, steps):
        raw = pipe.next_batch()
        batch = {"tokens": jnp.asarray(raw["tokens"]),
                 "labels": jnp.asarray(raw["labels"]),
                 "mask": jnp.asarray(raw["mask"])}
        ts = time.time()
        if mode == "dynamic":
            loss, params, opt_state = dyn(params, opt_state, batch)
            rep = dyn.last_report
            stats["peak_bytes"] = max(stats["peak_bytes"],
                                      rep.stats.device_peak)
        else:
            loss, params, opt_state = step_fn(params, opt_state, batch)
            loss.block_until_ready()
        dt = time.time() - ts
        monitor.record_step({0: dt})
        stats["losses"].append(float(loss))
        stats["tokens"] += int(raw["mask"].sum())
        if ck is not None and (step + 1) % ckpt_every == 0:
            ck.save(step + 1, {"params": params, "opt_state": opt_state},
                    extra={"pipeline": pipe.state()}, blocking=False)
        if (step + 1) % log_every == 0:
            print(f"[train] step {step+1} loss={float(loss):.4f} "
                  f"({dt*1000:.0f} ms)", flush=True)
    if ck is not None:
        ck.wait()
    wall = time.time() - t0
    stats["wall_s"] = wall
    stats["tokens_per_s"] = stats["tokens"] / max(wall, 1e-9)
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--mode", choices=["dynamic", "compiled"], default="dynamic")
    ap.add_argument("--data-mode", choices=["dynamic", "bucketed"],
                    default="dynamic")
    ap.add_argument("--memory-limit-mb", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    stats = train(cfg, steps=args.steps, batch_size=args.batch_size,
                  mode=args.mode, data_mode=args.data_mode,
                  memory_limit=args.memory_limit_mb * 2**20 or None,
                  ckpt_dir=args.ckpt_dir)
    print(f"[train] done: {stats['tokens_per_s']:.0f} tokens/s, "
          f"final loss {stats['losses'][-1]:.4f}, "
          f"peak {stats['peak_bytes']/2**20:.1f} MiB, "
          f"recompiles {stats['recompilations']}")


if __name__ == "__main__":
    main()
