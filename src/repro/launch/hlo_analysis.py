"""Static analysis of optimized HLO with while-loop trip-count scaling.

``jax.lax.scan`` lowers to HLO while loops whose bodies XLA's
``cost_analysis`` counts exactly once — a 61-layer scanned transformer would
report 1/61st of its FLOPs.  This module parses the optimized HLO text,
resolves each while loop's trip count (from the loop-bound constant threaded
through the init tuple), and accumulates:

  * flops           — dot/convolution FLOPs (including dots inside fusions),
  * hbm_bytes       — operand+result bytes of top-level (materializing)
                      instructions: a fusion-aware HBM-traffic estimate,
  * collectives     — bytes by collective type,

each scaled by the product of enclosing trip counts.  These feed the
roofline's three terms (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# opcodes that don't touch HBM (aliases / control / metadata)
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota", "rng-bit-generator",
}


def _shape_nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _leading_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _ARRAY_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    raw_operands: str = ""
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


_COMP_HEAD = re.compile(r"^(ENTRY )?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_INSTR = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")


def _split_instr_rhs(rhs: str):
    """rhs like 'f32[2,3]{1,0} dot(%a, %b), attrs' ->
    (type_str, opcode, operand_names, attrs, raw_operand_str)."""
    m = _OPCODE.search(rhs)
    if not m:
        return None
    type_str = rhs[:m.start()].strip()
    opcode = m.group(1)
    # find matching close paren for the operand list
    i = m.end()  # position just after '('
    depth = 1
    j = i
    while j < len(rhs) and depth:
        if rhs[j] == "(":
            depth += 1
        elif rhs[j] == ")":
            depth -= 1
        j += 1
    oper_str = rhs[i:j - 1]
    attrs = rhs[j:].lstrip(", ")
    operands = re.findall(r"%([\w.\-]+)", oper_str)
    return type_str, opcode, operands, attrs, oper_str


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_HEAD.match(line.strip())
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        parsed = _split_instr_rhs(m.group(3))
        if parsed is None:
            continue
        type_str, opcode, operands, attrs, raw_ops = parsed
        ins = Instr(m.group(2), type_str, opcode, operands, attrs,
                    raw_operands=raw_ops, is_root=bool(m.group(1)))
        cur.instrs.append(ins)
        cur.by_name[ins.name] = ins
    return comps, entry


class HLOAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._totals: Dict[str, Dict[str, float]] = {}
        self.warnings: List[str] = []

    # -- trip count resolution ----------------------------------------------------
    def _resolve(self, comp: Computation, name: str, depth: int = 0) -> Optional[Instr]:
        ins = comp.by_name.get(name)
        while ins is not None and depth < 8 and \
                ins.opcode in ("copy", "bitcast", "convert"):
            if not ins.operands:
                break
            ins = comp.by_name.get(ins.operands[0])
            depth += 1
        return ins

    @staticmethod
    def _const_int(ins: Instr) -> Optional[int]:
        if ins.opcode != "constant":
            return None
        m = re.search(r"(\d+)", ins.raw_operands or "")
        return int(m.group(1)) if m else None

    def trip_count(self, while_instr: Instr, comp: Computation,
                   cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        # Common pattern: the loop bound is an s32 constant in the condition
        # computation (compared -- possibly inside a wrapped_compare fusion --
        # against the induction variable).
        consts = [v for ins in cond.instrs
                  if "s32" in ins.type_str and (v := self._const_int(ins)) is not None]
        if consts:
            return max(1, max(consts))
        # Fallback: bound threaded through the init tuple: find the compared
        # tuple index, then resolve that element of the while's init tuple.
        idxs = []
        for ins in cond.instrs:
            if ins.opcode == "get-tuple-element":
                mi = re.search(r"index=(\d+)", ins.attrs)
                if mi:
                    idxs.append(int(mi.group(1)))
        if while_instr.operands:
            init = self._resolve(comp, while_instr.operands[0])
            if init is not None and init.opcode == "tuple":
                for k in idxs:
                    if k == 0 or k >= len(init.operands):
                        continue  # index 0 is the induction variable
                    elem = self._resolve(comp, init.operands[k])
                    if elem is not None and (v := self._const_int(elem)) is not None:
                        return max(1, v)
        self.warnings.append(f"trip count unresolved for {while_instr.name}")
        return 1

    # -- flops --------------------------------------------------------------------
    def _dot_flops(self, ins: Instr, comp: Computation) -> float:
        out_elems = 0
        for _dt, dims in _leading_dims(ins.type_str):
            n = 1
            for d in dims:
                n *= d
            out_elems += n
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        if m and ins.operands:
            lhs = comp.by_name.get(ins.operands[0])
            if lhs is not None:
                shapes = _leading_dims(lhs.type_str)
                if shapes:
                    _, dims = shapes[0]
                    for ci in m.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
        return 2.0 * out_elems * k

    # -- fusion HBM traffic with slice-aware accounting ---------------------------
    _TRANSPARENT = ("convert", "bitcast", "copy", "reshape", "transpose",
                    "bitcast-convert")

    def _fusion_traffic(self, ins: Instr, comp: Computation,
                        called: Optional[str]) -> float:
        """Operand+result bytes of a fusion, modelling TPU buffer semantics:

        * a parameter only read through an inner dynamic-slice counts the
          slice (a scan reading one layer of stacked params);
        * a root that is a dynamic-update-slice (possibly wrapped in
          converts/bitcasts) counts only the updated slice, and the aliased
          base parameter counts nothing (in-place carry update).
        """
        fused = self.comps.get(called) if called else None
        if fused is None:
            total = _shape_nbytes(ins.type_str)
            for o in ins.operands:
                oi = comp.by_name.get(o)
                if oi is not None and oi.opcode != "constant":
                    total += _shape_nbytes(oi.type_str)
            return float(total)

        param_of: Dict[str, int] = {}
        for fi in fused.instrs:
            if fi.opcode == "parameter":
                mi = re.search(r"(\d+)", fi.raw_operands or "")
                idx = int(mi.group(1)) if mi else len(param_of)
                param_of[fi.name] = idx

        # consumer map inside the fusion
        consumers: Dict[str, List[Tuple[Instr, int]]] = {}
        for fi in fused.instrs:
            for oi_idx, oname in enumerate(fi.operands):
                consumers.setdefault(oname, []).append((fi, oi_idx))

        def reaches_only(name: str, pred) -> bool:
            """True if every consumer path through transparent ops ends at
            an instruction satisfying pred(instr, operand_idx)."""
            stack = [name]
            seen = set()
            ok_any = False
            while stack:
                n = stack.pop()
                if n in seen:
                    continue
                seen.add(n)
                for ci, cidx in consumers.get(n, []):
                    if ci.opcode in self._TRANSPARENT:
                        stack.append(ci.name)
                    elif pred(ci, cidx):
                        ok_any = True
                    else:
                        return False
            return ok_any

        # root analysis: chase through transparent wrappers to find DUS roots
        root = next((fi for fi in fused.instrs if fi.is_root), None)
        dus_update_bytes: Optional[int] = None
        dus_base_params: set = set()
        if root is not None:
            roots = [root]
            if root.opcode == "tuple":
                roots = [fused.by_name.get(o) for o in root.operands if o]

            def chase(r):
                d = 0
                while r is not None and r.opcode in self._TRANSPARENT \
                        and r.operands and d < 8:
                    r = fused.by_name.get(r.operands[0])
                    d += 1
                return r

            resolved = [chase(r) for r in roots]
            if any(r is not None and r.opcode == "dynamic-update-slice"
                   for r in resolved):
                total_bytes = 0
                for r in resolved:
                    if r is None:
                        continue
                    if r.opcode == "dynamic-update-slice" and len(r.operands) >= 2:
                        upd = fused.by_name.get(r.operands[1])
                        total_bytes += (_shape_nbytes(upd.type_str)
                                        if upd is not None else 0)
                        # find the aliased base parameter (operand 0 chased up)
                        base = fused.by_name.get(r.operands[0])
                        d = 0
                        while base is not None and base.opcode in self._TRANSPARENT \
                                and base.operands and d < 8:
                            base = fused.by_name.get(base.operands[0])
                            d += 1
                        if base is not None and base.name in param_of:
                            dus_base_params.add(param_of[base.name])
                    else:
                        total_bytes += _shape_nbytes(r.type_str)
                dus_update_bytes = total_bytes

        # per-parameter read accounting
        slice_read: Dict[int, int] = {}
        for fi in fused.instrs:
            if fi.opcode != "dynamic-slice":
                continue
            for oname in fi.operands[:1]:
                if oname in param_of:
                    pidx = param_of[oname]
                    slice_read[pidx] = slice_read.get(pidx, 0) + \
                        _shape_nbytes(fi.type_str)
        only_sliced: set = set()
        for fname, pidx in param_of.items():
            if pidx in slice_read and reaches_only(
                    fname, lambda ci, cidx: ci.opcode == "dynamic-slice"):
                only_sliced.add(pidx)

        total = dus_update_bytes if dus_update_bytes is not None \
            else _shape_nbytes(ins.type_str)
        for i, o in enumerate(ins.operands):
            oi = comp.by_name.get(o)
            if oi is None or oi.opcode == "constant":
                continue
            if i in dus_base_params:
                continue  # aliased in-place carry: no traffic
            if i in only_sliced:
                total += slice_read[i]
            else:
                total += _shape_nbytes(oi.type_str)
        return float(total)

    # -- per-computation totals ------------------------------------------------------
    def totals(self, comp_name: str) -> Dict[str, float]:
        if comp_name in self._totals:
            return self._totals[comp_name]
        comp = self.comps.get(comp_name)
        zero = {"flops": 0.0, "hbm_bytes": 0.0, "transcendentals": 0.0,
                **{c: 0.0 for c in COLLECTIVE_OPS}}
        if comp is None:
            return zero
        self._totals[comp_name] = dict(zero)  # break cycles
        tot = dict(zero)
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                trips = self.trip_count(ins, comp, cond.group(1)) if cond else 1
                if body:
                    sub = self.totals(body.group(1))
                    for k2, v in sub.items():
                        tot[k2] += v * trips
                continue
            if op in ("call", "conditional"):
                for target in re.findall(r"(?:to_apply|branch_computations=\{|"
                                         r"true_computation|false_computation)"
                                         r"=?%?([\w.\-]+)", ins.attrs):
                    sub = self.totals(target)
                    for k2, v in sub.items():
                        tot[k2] += v
                continue
            if op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if m:
                    sub = self.totals(m.group(1))
                    tot["flops"] += sub["flops"]
                    tot["transcendentals"] += sub["transcendentals"]
                tot["hbm_bytes"] += self._fusion_traffic(ins, comp,
                                                         m.group(1) if m else None)
                continue
            if op in ("dot", "convolution"):
                tot["flops"] += self._dot_flops(ins, comp)
            if op.rstrip("-startdone") in COLLECTIVE_OPS or \
                    any(op.startswith(c) for c in COLLECTIVE_OPS):
                if op.endswith("-done"):
                    continue
                base = next(c for c in COLLECTIVE_OPS if op.startswith(c))
                tot[base] += _shape_nbytes(ins.type_str)
            if op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                      "logistic", "sine", "cosine"):
                tot["transcendentals"] += _shape_nbytes(ins.type_str) / 4.0
            if op in _NO_TRAFFIC:
                continue
            tot["hbm_bytes"] += _shape_nbytes(ins.type_str)
            for o in ins.operands:
                oi = comp.by_name.get(o)
                if oi is not None and oi.opcode not in ("constant", "tuple",
                                                        "get-tuple-element"):
                    tot["hbm_bytes"] += _shape_nbytes(oi.type_str)
        self._totals[comp_name] = tot
        return tot

    def analyze(self) -> Dict[str, float]:
        if self.entry is None:
            # fall back: largest computation
            if not self.comps:
                return {}
            self.entry = max(self.comps, key=lambda c: len(self.comps[c].instrs))
        out = self.totals(self.entry)
        out["collective_bytes"] = sum(out[c] for c in COLLECTIVE_OPS)
        out["n_warnings"] = float(len(self.warnings))
        return out


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    return HLOAnalyzer(hlo_text).analyze()
