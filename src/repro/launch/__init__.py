from .mesh import dp_axes, make_debug_mesh, make_production_mesh
from .sharding import ShardingRules
from .steps import make_prefill_step, make_serve_step, make_train_step

__all__ = ["dp_axes", "make_debug_mesh", "make_production_mesh",
           "ShardingRules", "make_prefill_step", "make_serve_step",
           "make_train_step"]
