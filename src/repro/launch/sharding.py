"""Sharding rules: params / optimizer / batches / caches -> PartitionSpec.

Strategy (see DESIGN.md §6):
  * 2D param sharding: FSDP on ``data`` x tensor-parallel on ``model``;
  * TP shards attention heads / FFN columns / vocab where divisible by the
    model-axis size; non-divisible dims gracefully fall back to replication
    (recorded — the roofline then shows the cost and the hillclimb fixes
    the worst offenders);
  * MoE experts shard on ``model`` (EP);
  * ``pod`` is pure data parallelism.
Rules match parameter *names*; stacked-layer leading axes get None.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax import tree_util
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes, model_axis_size


def _divides(n, k: int) -> bool:
    return isinstance(n, int) and k > 0 and n % k == 0


class ShardingRules:
    def __init__(self, mesh, *, fsdp: bool = True):
        self.mesh = mesh
        self.model = model_axis_size(mesh)
        self.data = mesh.shape.get("data", 1)
        self.dp = dp_axes(mesh)
        self.fsdp = fsdp
        self.fallbacks: Dict[str, str] = {}

    # -- helpers --------------------------------------------------------------------
    def _axis(self, name: str, dim_size, axis: Optional[str]):
        """axis if divisible else None (recorded as fallback)."""
        if axis is None:
            return None
        k = self.model if axis == "model" else self.data
        if axis == "data" and not self.fsdp:
            return None
        if _divides(dim_size, k):
            return axis
        self.fallbacks[name] = f"dim {dim_size} % {axis}({k}) != 0 -> replicated"
        return None

    def spec_for(self, name: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for a parameter leaf by its (path) name."""
        parts = name.split("/")
        base = parts[-1]
        is_moe = "moe" in parts and base in ("w1", "w2", "w3", "router")
        nd = len(shape)

        def two_d(row_axis, col_axis, rank=2):
            """rule for trailing `rank` dims; leading dims -> None."""
            lead = [None] * (nd - rank)
            dims = list(shape[nd - rank:])
            axes = [row_axis, col_axis][-rank:] if rank == 2 else [col_axis]
            out = []
            for d, a in zip(dims, axes):
                out.append(self._axis(name, d, a))
            return P(*(lead + out))

        # embeddings / lm head: vocab-parallel, contraction (D) unsharded so
        # the logits matmul keeps activations batch-sharded.
        if base == "embed":
            return two_d("model", None)
        if base == "lm_head":
            return two_d(None, "model")
        # attention (gqa)
        if base in ("wq", "wk", "wv"):
            return two_d("data", "model")
        if base == "wo":
            return two_d("model", "data")
        # MLA
        if base in ("w_dq", "w_dkv", "w_kr"):
            return two_d("data", "model")
        if base in ("w_uq", "w_uk", "w_uv"):  # (r, H, d): shard heads
            lead = [None] * (nd - 3)
            return P(*(lead + [self._axis(name, shape[-3], "data"),
                               self._axis(name, shape[-2], "model"), None]))
        if base == "w_o" and nd >= 3:          # (H, v, D)
            lead = [None] * (nd - 3)
            return P(*(lead + [self._axis(name, shape[-3], "model"), None,
                               self._axis(name, shape[-1], "data")]))
        # MoE experts: EP on the expert dim
        if is_moe and base in ("w1", "w3") and nd >= 3:  # (E, D, F)
            lead = [None] * (nd - 3)
            return P(*(lead + [self._axis(name, shape[-3], "model"),
                               self._axis(name, shape[-2], "data"), None]))
        if is_moe and base == "w2" and nd >= 3:          # (E, F, D)
            lead = [None] * (nd - 3)
            return P(*(lead + [self._axis(name, shape[-3], "model"), None,
                               self._axis(name, shape[-1], "data")]))
        if base == "router":
            return two_d("data", None)
        # dense FFN
        if base in ("w1", "w3"):
            return two_d("data", "model")
        if base == "w2":
            return two_d("model", "data")
        # SSM
        if base in ("in_proj", "up_proj"):
            return two_d("data", "model")
        if base in ("out_proj", "down_proj"):
            return two_d("model", "data")
        if base in ("x_proj",):
            return two_d("model", None)
        if base in ("dt_proj",):
            return two_d(None, "model")
        if base in ("a_log",):
            return two_d("model", None)
        # xlstm in-block projections (di, di)
        if base in ("w_igate", "w_fgate", "w_z", "w_i", "w_f", "w_o_gate"):
            return two_d("data", "model") if nd >= 2 else P(*([None] * nd))
        # 1-D / small: replicate
        return P(*([None] * nd))

    # -- pytree-level APIs --------------------------------------------------------
    def params_pspecs(self, params_shapes: Any) -> Any:
        flat, treedef = tree_util.tree_flatten_with_path(params_shapes)
        out = []
        for path, leaf in flat:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            out.append(self.spec_for(name, tuple(leaf.shape)))
        return tree_util.tree_unflatten(treedef, out)

    def named(self, pspecs: Any) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    def batch_pspec(self) -> P:
        return P(self.dp if len(self.dp) > 1 else self.dp[0])

    def batch_specs(self, batch_shapes: Any) -> Any:
        """Shard leading (batch) dim on the DP axes when divisible."""
        dp_size = int(np.prod([self.mesh.shape[a] for a in self.dp]))

        def spec(leaf):
            b = leaf.shape[0] if leaf.shape else 1
            if _divides(b, dp_size):
                return P(*((self.dp if len(self.dp) > 1 else self.dp[0],) +
                           (None,) * (len(leaf.shape) - 1)))
            return P(*((None,) * len(leaf.shape)))
        return jax.tree.map(spec, batch_shapes)

    def cache_specs(self, cache_shapes: Any) -> Any:
        """Decode caches: (L, B, S, H, hd)-style; batch on dp, heads/feature
        on model when divisible, else seq on data (long-context B=1)."""
        dp_size = int(np.prod([self.mesh.shape[a] for a in self.dp]))

        def spec(leaf):
            shape = leaf.shape
            nd = len(shape)
            out = [None] * nd
            if nd >= 2 and _divides(shape[1], dp_size):
                out[1] = self.dp if len(self.dp) > 1 else self.dp[0]
            # shard the widest remaining dim on model if divisible
            best, best_dim = None, 0
            for i in range(2, nd):
                if _divides(shape[i], self.model) and shape[i] > best_dim:
                    best, best_dim = i, shape[i]
            if best is not None:
                out[best] = "model"
            # B=1 long-context: shard seq (axis 2) on data
            if nd >= 3 and out[1] is None and _divides(shape[2], self.data) \
                    and shape[2] >= 4096:
                out[2] = "data"
            return P(*out)
        return jax.tree.map(spec, cache_shapes)
