"""Step functions lowered by the dry-run and driven by the trainer/server."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.compression import CompressionState, compress_gradients
from ..models import decode_step as model_decode_step
from ..models import loss_fn, prefill as model_prefill
from ..optim import AdamWConfig, apply_updates


def adamw_config_for(cfg: ModelConfig) -> AdamWConfig:
    return AdamWConfig(state_dtype=jnp.bfloat16
                       if cfg.optimizer_dtype == "bfloat16" else jnp.float32)


def _grad_fn(cfg: ModelConfig, grad_accum: int):
    """value_and_grad, optionally micro-batched (gradient accumulation).

    Accumulation slashes activation peak (logits and attention transients
    scale with the micro-batch) at zero FLOP cost; grads accumulate in the
    params' own dtype, sharded like the params.
    """
    base = jax.value_and_grad(lambda p, b: loss_fn(cfg, p, b))
    if grad_accum <= 1:
        return base

    def accum(params, batch):
        micro = jax.tree.map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = base(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, g_acc, g)), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (loss_sum, g_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        inv = 1.0 / grad_accum
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)
    return accum


def make_train_step(cfg: ModelConfig, *, compress: bool = False,
                    grad_accum: int = 1):
    ocfg = adamw_config_for(cfg)
    gfn = _grad_fn(cfg, grad_accum)

    if compress:
        def train_step(params, opt_state, comp_state, batch):
            loss, grads = gfn(params, batch)
            grads, comp_state = compress_gradients(grads, comp_state)
            new_params, new_opt = apply_updates(params, grads, opt_state, ocfg)
            return loss, new_params, new_opt, comp_state
        return train_step

    def train_step(params, opt_state, batch):
        loss, grads = gfn(params, batch)
        new_params, new_opt = apply_updates(params, grads, opt_state, ocfg)
        return loss, new_params, new_opt
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return model_prefill(cfg, params, batch)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, state, inp):
        return model_decode_step(cfg, params, state, inp)
    return serve_step
