"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

``input_specs(cfg, shape_name)`` returns (step_kind, specs) where specs are
the flat inputs of the corresponding step function — weak-type-correct,
shardable, no device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ModelConfig
from ..models import init_cache, init_params
from ..optim import AdamWConfig, init_state


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    D = cfg.d_model
    dt = cfg.jax_dtype
    if cfg.input_mode == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.input_mode == "embeddings":
        return {"frame_embed": jax.ShapeDtypeStruct((batch, seq, D), dt),
                "labels": jax.ShapeDtypeStruct((batch, seq, cfg.n_codebooks),
                                               jnp.int32)}
    if cfg.input_mode == "vlm":
        s_txt = max(seq - cfg.vis_tokens, 8)
        return {"vis_embed": jax.ShapeDtypeStruct((batch, cfg.vis_tokens, D), dt),
                "tokens": jax.ShapeDtypeStruct((batch, s_txt), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, s_txt), jnp.int32)}
    raise ValueError(cfg.input_mode)


def decode_input_specs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    if cfg.input_mode in ("tokens", "vlm"):
        return {"token": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    return {"frame_embed": jax.ShapeDtypeStruct((batch, 1, cfg.d_model),
                                                cfg.jax_dtype)}


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def opt_specs(cfg: ModelConfig, p_specs: Any) -> Any:
    ocfg = AdamWConfig(state_dtype=jnp.bfloat16
                       if cfg.optimizer_dtype == "bfloat16" else jnp.float32)
    return jax.eval_shape(lambda p: init_state(p, ocfg), p_specs)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape_name: str) -> Tuple[str, Dict[str, Any]]:
    spec = SHAPES[shape_name]
    kind = spec["kind"]
    b, s = spec["global_batch"], spec["seq_len"]
    p = params_specs(cfg)
    if kind == "train":
        return "train", {"params": p, "opt_state": opt_specs(cfg, p),
                         "batch": batch_specs(cfg, b, s)}
    if kind == "prefill":
        batch = dict(batch_specs(cfg, b, s))
        batch.pop("labels", None)
        return "prefill", {"params": p, "batch": batch}
    if kind == "decode":
        return "decode", {"params": p,
                          "state": cache_specs(cfg, b, s),
                          "inp": decode_input_specs(cfg, b)}
    raise ValueError(kind)
