"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16)."""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests on CPU)."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axis names of a mesh (pod is pure DP)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
