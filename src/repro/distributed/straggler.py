"""Straggler detection & step-time health monitoring.

At 1000+ nodes slow hosts (thermal throttle, failing HBM, network
congestion) stretch every synchronous step.  The monitor keeps an EWMA +
variance of per-host step times, flags hosts whose times exceed a z-score
threshold for ``patience`` consecutive steps, and exposes the decision to
the launcher (which can drop the host and trigger an elastic restart from
the last checkpoint — see Checkpointer elastic restore).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set


@dataclass
class StragglerConfig:
    z_threshold: float = 3.0
    patience: int = 3
    ewma_alpha: float = 0.1
    min_steps: int = 8


@dataclass
class HostStats:
    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    strikes: int = 0


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.hosts: Dict[int, HostStats] = {}
        self.flagged: Set[int] = set()

    def record_step(self, times: Dict[int, float]) -> List[int]:
        """Record per-host step times; returns hosts newly flagged.

        A straggler is judged against the *fleet's* per-step distribution
        (median + MAD), never against its own history — a persistently slow
        host must not normalize itself.
        """
        if not times:
            return []
        vals = sorted(times.values())
        med = vals[len(vals) // 2]
        rels = {h: t / max(med, 1e-9) for h, t in times.items()}
        # robust spread of the healthy population (MAD -> sigma)
        healthy_rels = sorted(r for h, r in rels.items()
                              if h not in self.flagged)
        mad = sorted(abs(r - 1.0) for r in healthy_rels)[len(healthy_rels) // 2]
        sigma = max(mad * 1.4826, 1e-3)
        newly = []
        for host, t in times.items():
            st = self.hosts.setdefault(host, HostStats())
            a = self.cfg.ewma_alpha
            rel = rels[host]
            # per-host EWMA kept for drift telemetry
            if st.count == 0:
                st.mean, st.var = rel, 0.01
            else:
                d = rel - st.mean
                st.mean += a * d
                st.var = (1 - a) * (st.var + a * d * d)
            st.count += 1
            if st.count >= self.cfg.min_steps:
                z = (rel - 1.0) / sigma
                if z > self.cfg.z_threshold and rel > 1.1:
                    st.strikes += 1
                else:
                    st.strikes = 0
                if st.strikes >= self.cfg.patience and host not in self.flagged:
                    self.flagged.add(host)
                    newly.append(host)
        return newly

    def healthy_hosts(self, all_hosts: List[int]) -> List[int]:
        return [h for h in all_hosts if h not in self.flagged]
