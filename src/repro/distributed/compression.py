"""Gradient compression with error feedback for slow inter-pod links.

int8 per-tensor-block quantization (scale = max|g| per block) applied before
the cross-pod all-reduce, with an error-feedback accumulator so quantization
noise is unbiased over steps (Karimireddy et al., 2019).  4x reduction in
cross-pod collective bytes; the roofline's collective term scales with it.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # pytree of residuals, same structure as grads


def init_compression_state(grads_like: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def quantize_int8(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization; returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_decompress(g: jax.Array, err: jax.Array,
                        block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback round: returns (g_hat, new_err).

    In production the int8 payload is what crosses the pod link (psum of q
    with per-block rescale); numerically the all-reduce of dequantized
    values equals psum(g_hat), so this function is the exact simulation of
    the compressed collective and plugs into the train step directly.
    """
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target, block)
    g_hat = dequantize_int8(q, scale, g.shape, jnp.float32)
    new_err = target - g_hat
    return g_hat.astype(g.dtype), new_err


def compress_gradients(grads: Any, state: CompressionState,
                       block: int = 256) -> Tuple[Any, CompressionState]:
    out = jax.tree.map(lambda g, e: compress_decompress(g, e, block),
                       grads, state.error)
    g_hat = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, CompressionState(error=new_err)
