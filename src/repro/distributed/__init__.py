from .compression import (CompressionState, compress_gradients,
                          init_compression_state)
from .straggler import StragglerConfig, StragglerMonitor

__all__ = ["CompressionState", "compress_gradients", "init_compression_state",
           "StragglerConfig", "StragglerMonitor"]
