"""Lowering & ProgramVM: instruction emission, differential execution,
per-env resolve, and the shared-cache keying regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import tree_util

from repro.core import lower_plan, optimize, symbolic_dims
from repro.core.executor.interpreter import PlanInterpreter
from repro.core.executor.memory import MemoryLimitExceeded
from repro.core.executor.vm import ProgramVM
from repro.core.ir import trace_to_graph
from repro.core.lowering.program import (OP_COMPUTE, OP_MAYBE_EVICT,
                                         OP_REGEN)
from repro.core.remat.planner import build_plan
from repro.core.scheduling.scheduler import ScheduleResult
from repro.core.symbolic import ShapeGraph

B, S = symbolic_dims("b, s")
V, D, F = 300, 32, 64


def loss_fn(params, tokens, labels):
    emb = params["emb"][tokens]
    h = jax.nn.gelu(emb @ params["w1"])
    h2 = h @ params["w2"]
    logits = h2 @ params["emb"].T
    logp = jax.nn.log_softmax(logits)
    oh = jax.nn.one_hot(labels, logits.shape[-1])
    return -(oh * logp).sum() / (1.0 * tokens.shape[0] * tokens.shape[1])


def train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    return loss, jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)


def specs():
    p = {"emb": jax.ShapeDtypeStruct((V, D), jnp.float32),
         "w1": jax.ShapeDtypeStruct((D, F), jnp.float32),
         "w2": jax.ShapeDtypeStruct((F, D), jnp.float32)}
    t = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return p, t, t


def concrete_params(seed=0):
    rng = np.random.RandomState(seed)
    return {"emb": jnp.asarray(rng.randn(V, D), jnp.float32),
            "w1": jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
            "w2": jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)}


def _assert_trees_equal(a, b):
    la = tree_util.tree_leaves(a)
    lb = tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            "executors disagree bitwise"


# -- the differential harness: every bench arch, both executors ---------------

BENCH_ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]
PROBE_ENVS = [{"b": 1, "s": 16}, {"b": 2, "s": 40}, {"b": 3, "s": 96}]


@pytest.mark.parametrize("arch", BENCH_ARCHS)
def test_differential_vm_vs_reference_on_bench_arch(arch):
    """VM and reference interpreter agree bitwise on every bench arch at
    >=3 probe envs, and the VM's peak bytes never exceed the reference's."""
    from benchmarks.memplan_bench import _step_and_specs, concretize_spec

    r = _step_and_specs(arch)
    assert r is not None, f"{arch} missing from the bench arch set"
    step, args = r
    fn = optimize(step, *args,
                  dynamic_dims={"b": (1, 8), "s": (8, 128)})
    assert fn.program is not None
    ref = PlanInterpreter(fn.plan)          # same plan, reference executor
    flat_specs, _ = tree_util.tree_flatten((args, {}))
    rng = np.random.RandomState(0)
    for env in PROBE_ENVS:
        flat = [concretize_spec(s, env, rng) for s in flat_specs]
        outs_vm, rep_vm = fn.interp.run(flat)
        outs_ref, rep_ref = ref.run(flat)
        _assert_trees_equal(outs_vm, outs_ref)
        assert rep_vm.env == env and rep_ref.env == env
        assert rep_vm.stats.device_peak <= rep_ref.stats.device_peak
        # the fast path precomputes the whole stats template — it must
        # match the reference's per-op accounting exactly
        assert rep_vm.stats.device_peak == rep_ref.stats.device_peak
        assert rep_vm.stats.arena_bytes == rep_ref.stats.arena_bytes
        assert rep_vm.stats.reuse_ratio == rep_ref.stats.reuse_ratio


class TestInstructionEmission:
    def test_no_evict_path_without_limit(self):
        fn = optimize(train_step, *specs())
        counts = fn.program.counts()
        assert counts["MaybeEvict"] == 0 and counts["Regen"] == 0
        assert counts["Compute"] == len(fn.plan.order)
        assert counts["Return"] == 1
        assert not fn.program.has_evict_path

    def test_no_evict_path_when_bound_fits_limit(self):
        """Guaranteed peak <= limit proves eviction impossible: the
        compile-time analysis strips the whole runtime remat machinery."""
        probe = optimize(train_step, *specs(),
                         dynamic_dims={"b": (1, 4), "s": (8, 64)})
        bound = probe.guaranteed_peak_bytes
        assert bound is not None
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 4), "s": (8, 64)},
                      memory_limit=bound)
        counts = fn.program.counts()
        assert counts["MaybeEvict"] == 0 and counts["Regen"] == 0

    def test_evict_path_under_pressure(self):
        fn = optimize(train_step, *specs(), memory_limit=1 << 20)
        counts = fn.program.counts()
        assert counts["MaybeEvict"] == len(fn.plan.order)
        assert counts["Regen"] > 0
        assert fn.program.regen, "recompute sub-programs must be exported"
        for sub in fn.program.regen.values():
            assert sub.n_temps >= 1 and sub.steps
            # the target is produced by the sub-program, not a source
            assert sub.target_reg not in sub.source_regs

    def test_registers_dense_and_frees_static(self):
        fn = optimize(train_step, *specs())
        prog = fn.program
        assert sorted(prog.reg_of.values()) == list(range(prog.n_regs))
        assert len(prog.vid_of) == prog.n_regs
        # every FreeSlot frees a distinct register, none of them outputs
        freed = [i.reg for i in prog.instructions if type(i).__name__ == "FreeSlot"]
        assert len(freed) == len(set(freed))
        assert not set(freed) & set(prog.out_regs)

    def test_donate_instructions_only_when_donating(self):
        plain = optimize(train_step, *specs())
        donating = optimize(train_step, *specs(), donate_inputs=True)
        assert plain.program.counts()["Donate"] == 0
        assert donating.program.counts()["Donate"] > 0

    def test_fast_stream_strips_guards(self):
        fn = optimize(train_step, *specs(), memory_limit=1 << 20)
        ops = {inst.op for inst in fn.program.fast_instructions}
        assert OP_MAYBE_EVICT not in ops and OP_REGEN not in ops
        assert OP_COMPUTE in ops


class TestVMExecution:
    def test_memory_limit_identical_numerics_and_evictions(self):
        vm = optimize(train_step, *specs())
        ref = optimize(train_step, *specs(), executor="reference")
        params = concrete_params()
        rng = np.random.RandomState(2)
        t = jnp.asarray(rng.randint(0, V, (6, 50)), jnp.int32)
        vm(params, t, t)
        free_peak = vm.last_report.stats.device_peak
        for frac in (0.8, 0.6):
            limit = int(free_peak * frac)
            lv, pv = vm.with_memory_limit(limit)(params, t, t)
            lr, pr = ref.with_memory_limit(limit)(params, t, t)
            _assert_trees_equal((lv, pv), (lr, pr))

    def test_vm_limit_respected_with_evictions(self):
        vm = optimize(train_step, *specs())
        params = concrete_params()
        rng = np.random.RandomState(3)
        t = jnp.asarray(rng.randint(0, V, (6, 50)), jnp.int32)
        vm(params, t, t)
        free_peak = vm.last_report.stats.device_peak
        limited = vm.with_memory_limit(int(free_peak * 0.6))
        limited(params, t, t)
        st = limited.last_report.stats
        assert st.device_peak <= int(free_peak * 0.6)
        assert st.evictions > 0

    def test_impossible_limit_raises(self):
        vm = optimize(train_step, *specs(), memory_limit=1000)
        params = concrete_params()
        t = jnp.zeros((2, 8), jnp.int32)
        with pytest.raises(MemoryLimitExceeded):
            vm(params, t, t)

    def test_offload_fallback(self):
        vm = optimize(train_step, *specs(), max_subgraph=1)
        params = concrete_params()
        rng = np.random.RandomState(4)
        t = jnp.asarray(rng.randint(0, V, (6, 50)), jnp.int32)
        vm(params, t, t)
        peak = vm.last_report.stats.device_peak
        limited = vm.with_memory_limit(int(peak * 0.6))
        limited(params, t, t)
        st = limited.last_report.stats
        assert st.offloads > 0 and st.reloads > 0

    def test_donated_run_matches_reference(self):
        vm = optimize(train_step, *specs(), donate_inputs=True)
        ref = optimize(train_step, *specs(), donate_inputs=True,
                       executor="reference")
        params = concrete_params()
        t = jnp.asarray(np.random.RandomState(5).randint(0, V, (3, 20)),
                        jnp.int32)
        ov = vm(params, t, t)
        orf = ref(params, t, t)
        _assert_trees_equal(ov, orf)
        assert vm.last_report.stats.device_peak \
            == ref.last_report.stats.device_peak
        assert vm.last_report.stats.donated_reuses \
            == ref.last_report.stats.donated_reuses

    def test_bad_executor_name_rejected(self):
        with pytest.raises(ValueError, match="executor"):
            optimize(train_step, *specs(), executor="jit")


class TestResolve:
    def test_resolved_program_cached_per_env(self):
        fn = optimize(train_step, *specs())
        prog = fn.program
        r1 = prog.resolve({"b": 2, "s": 16})
        r2 = prog.resolve({"b": 2, "s": 16})
        assert r1 is r2
        r3 = prog.resolve({"b": 2, "s": 17})
        assert r3 is not r1

    def test_resolve_produces_offsets_and_stats(self):
        fn = optimize(train_step, *specs())
        r = fn.program.resolve({"b": 2, "s": 16})
        assert r.fast_ok and r.stats_template is not None
        assert r.peak_bytes == r.stats_template.device_peak > 0
        assert r.value_offsets, "arena-served values must get offsets"
        assert all(off >= 0 for off in r.value_offsets.values())
        assert max(off + 1 for off in r.value_offsets.values()) \
            <= r.arena.packed_height
        # calling through the VM at this env reports the template's stats
        params = concrete_params()
        t = jnp.zeros((2, 16), jnp.int32)
        fn(params, t, t)
        assert fn.last_report.stats.device_peak == r.peak_bytes

    def test_program_surfaces_on_buckets(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 8), "s": (8, 64)},
                      buckets={"s": [16]})
        params = concrete_params()
        t = jnp.zeros((2, 12), jnp.int32)
        fn(params, t, t)
        bp = fn.specialization_table.peek(fn.last_bucket)
        assert bp.program is not None
        assert bp.n_instructions == bp.program.n_instructions > 0


class TestSharedCacheKeying:
    """Regression: a size/params cache shared across executors of two
    *different* graphs must never alias same-id nodes (ids restart at 0
    per graph).  Before the graph-uid namespacing, the second run below
    picked up the first graph's refined broadcast shape for node 0."""

    @staticmethod
    def _plan_for(fn, spec):
        g, _ = trace_to_graph(fn, spec)
        return build_plan(g, ScheduleResult(list(g.nodes), 0, 0),
                          ShapeGraph(), enable_remat=False)

    def test_interpreters_with_shared_caches_do_not_alias(self):
        n, = symbolic_dims("n")
        spec = jax.ShapeDtypeStruct((n,), jnp.float32)
        plan4 = self._plan_for(lambda x: jnp.broadcast_to(x, (4, x.shape[0])),
                               spec)
        plan8 = self._plan_for(lambda x: jnp.broadcast_to(x, (8, x.shape[0])),
                               spec)
        size_cache, params_cache = {}, {}
        i4 = PlanInterpreter(plan4, size_cache=size_cache,
                             params_cache=params_cache)
        i8 = PlanInterpreter(plan8, size_cache=size_cache,
                             params_cache=params_cache)
        x = jnp.arange(5, dtype=jnp.float32)
        (o4,), _ = i4.run([x])
        (o8,), _ = i8.run([x])     # same env {'n': 5}, different graph
        assert o4.shape == (4, 5)
        assert o8.shape == (8, 5)

    def test_vms_with_shared_caches_do_not_alias(self):
        n, = symbolic_dims("n")
        spec = jax.ShapeDtypeStruct((n,), jnp.float32)
        plan4 = self._plan_for(lambda x: jnp.broadcast_to(x, (4, x.shape[0])),
                               spec)
        plan8 = self._plan_for(lambda x: jnp.broadcast_to(x, (8, x.shape[0])),
                               spec)
        size_cache, params_cache = {}, {}
        v4 = ProgramVM(lower_plan(plan4), size_cache=size_cache,
                       params_cache=params_cache)
        v8 = ProgramVM(lower_plan(plan8), size_cache=size_cache,
                       params_cache=params_cache)
        x = jnp.arange(5, dtype=jnp.float32)
        (o4,), _ = v4.run([x])
        (o8,), _ = v8.run([x])
        assert o4.shape == (4, 5)
        assert o8.shape == (8, 5)
