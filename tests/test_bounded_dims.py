"""Value-dependent bounded dims: property tests over the cap contract.

A bounded dim ``b`` is introduced by an op whose output extent only the
input *values* decide (``masked_select`` et al.); the trace mints a fresh
symbol with a cap expression ``b <= f(input dims)``.  Three contracts are
exercised here (hypothesis, or the deterministic shim from
``conftest.py``):

* **cap monotonicity** — ``ShapeGraph.bounds_of`` answered through
  ``declare_bound`` is never tighter than any value the runtime can
  measure: every measured extent lies in ``[0, cap(env)]`` and the
  declared interval contains that whole span, at every env in range.
* **plan invariance under rebinding** — re-running the same declared env
  with different input values (hence different measured bounds) changes
  nothing about the compiled artifact: same plan, same reserve, same
  cached ``Program.resolve`` object, while each call's stats are tight
  for *its* measured value (the satellite-3 cache-alias regression).
* **measured == 0** — a bounded dim that measures empty allocates
  zero-byte buffers, frees them, and the slot is reusable by the next
  call at full occupancy.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import optimize, symbolic_dim
from repro.core.symbolic import Interval, ShapeGraph, SymbolicExpr
from repro.kernels import masked_select

V = SymbolicExpr.var


def _mask(n, occ, seed=0):
    if occ == 0.0:
        return jnp.zeros((n,), bool)
    if occ == 1.0:
        return jnp.ones((n,), bool)
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.rand(n) < occ)


def _select_fn():
    def f(x, mask):
        y, cnt = masked_select(x * 2.0, mask)
        return jnp.sum(y, axis=0), cnt
    return f


def _specs(cols=4):
    s = symbolic_dim("s")
    return (jax.ShapeDtypeStruct((s, cols), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.bool_))


# -- cap monotonicity ----------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(lo=st.integers(1, 8),
       span=st.integers(0, 60),
       shape=st.sampled_from(["n", "2n", "n+3", "3n+1"]),
       probe=st.integers(0, 7))
def test_declared_bounds_never_tighter_than_measurable(lo, span, shape,
                                                       probe):
    hi = lo + span
    cap = {"n": V("n"), "2n": V("n") * 2, "n+3": V("n") + 3,
           "3n+1": V("n") * 3 + 1}[shape]
    sg = ShapeGraph()
    sg.declare_range("n", lo, hi)
    sg.declare_bound("b", cap)

    blo, bhi = sg.bounds_of(V("b"))
    assert blo is not None and bhi is not None
    # pick an in-range env, then any measurable value m in [0, cap(env)]
    env = {"n": lo + probe % (span + 1)}
    cap_val = cap.evaluate(env)
    for m in (0, cap_val // 2, cap_val):
        assert blo <= m <= bhi, (
            f"measured {m} escapes declared [{blo}, {bhi}] "
            f"(cap {cap} at {env})")
    # the declared interval is exactly the measurable span at the widest env
    assert blo == 0
    assert bhi == cap.evaluate({"n": hi})
    # interval queries compose through the cap: a size expression over b
    # is bounded without b ever being user-declared
    iv = sg.interval_of(V("b") * 4 + 8)
    assert iv.lo == 8 and iv.hi == 4 * bhi + 8


def test_declare_bound_tightens_monotonically():
    """Re-declaring through a narrower cap can only shrink the interval
    (specialization re-derives caps after range narrowing)."""
    sg = ShapeGraph()
    sg.declare_range("n", 1, 100)
    sg.declare_bound("b", V("n"))
    assert sg.bounds_of(V("b")) == (0, 100)
    sub = sg.specialized({"n": Interval(1, 10)})
    assert sub.bounds_of(V("b")) == (0, 10)
    # the parent is untouched
    assert sg.bounds_of(V("b")) == (0, 100)


# -- plan invariance under rebinding (satellite-3 regression) ------------------

def test_rebinding_same_env_cannot_alias_caches():
    """Two calls with identical declared dims but different measured
    bounds must not alias each other's cached ``Program.resolve`` or the
    interpreter's per-env size cache: each call's peak is tight for its
    own occupancy, and the cached resolve keeps cap sizes throughout."""
    fn = optimize(_select_fn(), *_specs(), dynamic_dims={"s": (1, 64)})
    n = 16
    x = jnp.asarray(np.random.RandomState(0).randn(n, 4), jnp.float32)

    resolved_before = fn.program.resolve({"s": n})
    cap_nbytes = list(resolved_before.nbytes)

    peaks = {}
    for occ in (1.0, 0.0, 0.5):
        fn(x, _mask(n, occ))
        st_ = fn.last_report.stats
        peaks[occ] = st_.device_peak
        assert st_.measured_dims == {
            name: int(np.sum(np.asarray(_mask(n, occ))))
            for name in fn.plan.graph.bound_dims}

    # tight accounting per call: an empty selection peaks strictly below
    # a full one — a cache alias would make these equal
    assert peaks[0.0] < peaks[0.5] < peaks[1.0], peaks
    # the declared-env resolve cache still holds cap sizes (same object,
    # unmutated by the measured overlays)
    resolved_after = fn.program.resolve({"s": n})
    assert resolved_after is resolved_before
    assert list(resolved_after.nbytes) == cap_nbytes


def test_rebinding_shared_size_cache_bucketed():
    """The bucketed path injects one shared size/params cache across all
    bucket executors — measured bounds must not leak into it either."""
    fn = optimize(_select_fn(), *_specs(), dynamic_dims={"s": (1, 64)},
                  buckets="geometric")
    ref = optimize(_select_fn(), *_specs(), dynamic_dims={"s": (1, 64)},
                   buckets="geometric", executor="reference")
    n = 24
    x = jnp.asarray(np.random.RandomState(1).randn(n, 4), jnp.float32)
    for occ in (1.0, 0.0, 1.0):
        o_vm = fn(x, _mask(n, occ))
        o_ref = ref(x, _mask(n, occ))
        for a, b in zip(o_vm, o_ref):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        sv = fn.last_report.stats
        sr = ref.last_report.stats
        assert sv.measured_dims == sr.measured_dims
        assert sv.device_peak == sr.device_peak
        want = n if occ == 1.0 else 0
        assert list(sv.measured_dims.values()) == [want]


def test_plan_artifacts_invariant_under_rebinding():
    fn = optimize(_select_fn(), *_specs(), dynamic_dims={"s": (1, 64)})
    n = 12
    x = jnp.asarray(np.random.RandomState(2).randn(n, 4), jnp.float32)
    bound = fn.report.arena_bound_bytes
    prog = fn.program
    outs = []
    for occ in (0.5, 0.5):
        outs.append(fn(x, _mask(n, occ, seed=7)))
        assert fn.report.arena_bound_bytes == bound
        assert fn.program is prog
        assert fn.last_report.stats.arena_bytes <= bound
    for a, b in zip(*outs):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# -- measured == 0 -------------------------------------------------------------

def test_measured_zero_frees_and_reuses():
    """A 0%-fill call allocates a zero-byte bounded buffer, frees it, and
    the arena slot serves the next full-occupancy call unharmed."""
    fn = optimize(_select_fn(), *_specs(), dynamic_dims={"s": (1, 64)})
    ref = optimize(_select_fn(), *_specs(), dynamic_dims={"s": (1, 64)},
                   executor="reference")
    n = 10
    x = jnp.asarray(np.random.RandomState(3).randn(n, 4), jnp.float32)

    for occ in (0.0, 1.0, 0.0):
        o_vm, o_ref = fn(x, _mask(n, occ)), ref(x, _mask(n, occ))
        for a, b in zip(o_vm, o_ref):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        sv, sr = fn.last_report.stats, ref.last_report.stats
        assert sv.as_dict() == sr.as_dict()
        if occ == 0.0:
            assert list(sv.measured_dims.values()) == [0]
            # eager oracle agrees the selection is empty
            assert float(o_vm[1]) == 0.0
    # everything freed at the end of each call: no residual growth
    assert fn.last_report.stats.arena_growth_bytes == 0
