"""Shape-bucketed plan specialization & dispatch.

Covers the partition itself (geometric coverage, deterministic edge
dispatch), the SpecializationTable (lazy compile, LRU eviction +
recompile, hit path never re-planning), the per-bucket specialization
gain (cmp_stats symbolic fraction and arena_bound_bytes no worse than the
whole-range plan, strictly better on the small bucket), correctness of
dispatched execution, and the serve-path bucket batcher.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimize, symbolic_dims
from repro.core.dispatch import (BucketSpace, DimBuckets, SpecializationTable,
                                 build_bucket_space)
from repro.core.symbolic import Interval, ShapeGraph, declare_dim_ranges
from repro.launch.serve import BucketBatcher

B, S = symbolic_dims("b, s")
V, D, F = 300, 32, 64


def loss_fn(params, tokens, labels):
    emb = params["emb"][tokens]
    h = jax.nn.gelu(emb @ params["w1"])
    h2 = h @ params["w2"]
    logits = h2 @ params["emb"].T
    logp = jax.nn.log_softmax(logits)
    oh = jax.nn.one_hot(labels, logits.shape[-1])
    return -(oh * logp).sum() / (1.0 * tokens.shape[0] * tokens.shape[1])


def train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    return loss, jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)


def specs():
    p = {"emb": jax.ShapeDtypeStruct((V, D), jnp.float32),
         "w1": jax.ShapeDtypeStruct((D, F), jnp.float32),
         "w2": jax.ShapeDtypeStruct((F, D), jnp.float32)}
    t = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return p, t, t


def concrete_params(seed=0):
    rng = np.random.RandomState(seed)
    return {"emb": jnp.asarray(rng.randn(V, D), jnp.float32),
            "w1": jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
            "w2": jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)}


def tokens_of(b, s, seed=1):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)


@pytest.fixture(scope="module")
def bucketed_fn():
    return optimize(train_step, *specs(),
                    dynamic_dims={"b": (1, 16), "s": (8, 256)},
                    buckets={"s": [32, 64]})


# -- the partition ------------------------------------------------------------


class TestBucketSpace:
    def test_geometric_partition_covers_range_contiguously(self):
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"b": (1, 64), "s": (16, 4096)})
        space = build_bucket_space(sg.declared_ranges, "geometric")
        s_dim = next(d for d in space.dims if d.name == "s")
        assert s_dim.n == 4 and s_dim.uppers[-1] == 4096
        lo = 16
        for i in range(s_dim.n):
            iv = s_dim.range_of(i)
            assert iv.lo == lo            # contiguous, no gap and no overlap
            lo = iv.hi + 1
        # every in-range value lands in the bucket whose range contains it
        for v in [16, 63, 64, 65, 1000, 4096]:
            assert s_dim.range_of(s_dim.index_of(v)).contains(v)

    def test_edge_value_dispatches_to_lower_bucket(self):
        d = DimBuckets("s", 16, (64, 256, 1024))
        assert d.index_of(64) == 0        # edges are inclusive upper bounds
        assert d.index_of(65) == 1
        assert d.index_of(256) == 1
        assert d.index_of(257) == 2
        assert d.index_of(16) == 0

    def test_explicit_edges_and_unbucketed_dims(self):
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"b": (1, 16), "s": (8, 256)})
        space = build_bucket_space(sg.declared_ranges, {"s": [32, 64]})
        assert space.dim_names == ("b", "s")
        assert space.n_buckets == 3       # b keeps a single bucket
        assert space.key_of({"b": 5, "s": 32}) == (0, 0)
        assert space.key_of({"b": 5, "s": 33}) == (0, 1)
        ranges = space.ranges_of((0, 2))
        assert ranges["s"] == Interval(65, 256)
        assert ranges["b"] == Interval(1, 16)

    def test_open_range_gets_open_final_bucket(self):
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"s": ">=4"})
        space = build_bucket_space(sg.declared_ranges, {"s": [64]})
        s_dim = space.dims[0]
        assert s_dim.uppers == (64, None)
        assert s_dim.index_of(10_000_000) == 1
        assert space.ranges_of((1,))["s"] == Interval(65, None)

    def test_out_of_partition_value_raises_not_clamps(self):
        d = DimBuckets("s", 16, (64, 256, 1024))
        with pytest.raises(ValueError, match="outside the bucketed range"):
            d.index_of(15)                # below lo
        with pytest.raises(ValueError, match="outside the bucketed range"):
            d.index_of(1025)              # above the final edge
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"b": (1, 16), "s": (8, 256)})
        space = build_bucket_space(sg.declared_ranges, {"s": [32]})
        with pytest.raises(ValueError, match="outside the bucketed range"):
            space.key_of({"b": 2, "s": 5000})

    def test_bad_specs_raise(self):
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"s": (8, 256)})
        with pytest.raises(ValueError):
            build_bucket_space({}, "geometric")       # no declared ranges
        with pytest.raises(ValueError):
            build_bucket_space(sg.declared_ranges, {"nope": 4})
        with pytest.raises(ValueError):               # single bucket is no-op
            build_bucket_space(sg.declared_ranges, 1)
        with pytest.raises(ValueError):               # unbounded + geometric
            sg2 = ShapeGraph()
            declare_dim_ranges(sg2, {"s": ">=4"})
            build_bucket_space(sg2.declared_ranges, 4)


# -- specialization gain ------------------------------------------------------


class TestSpecializationGain:
    def test_per_bucket_no_worse_than_whole_range(self, bucketed_fn):
        fn = bucketed_fn
        mono = fn.report
        table = fn.specialization_table
        assert mono.arena_bound_bytes is not None
        small_bounds = []
        for key in table.space.keys():
            bp = table.get(key)
            # incremental specialization ran: verdicts were inherited from
            # the whole-range compile and the memo answered repeat queries
            # (per-query layer attribution is identical to a fresh compile —
            # see test_compile_cache — but the *set* of queries shrinks, so
            # the old mono-vs-bucket fraction comparison no longer applies)
            st = bp.report.cmp_stats
            assert st.get("inherited", 0) > 0
            assert st.get("cache_hit", 0) > 0
            # the bucket's guaranteed arena never exceeds whole-range
            assert bp.arena_bound_bytes <= mono.arena_bound_bytes
            small_bounds.append(bp.arena_bound_bytes)
        # the small-shape bucket is *strictly* cheaper — the whole point
        assert min(small_bounds) < mono.arena_bound_bytes

    def test_per_bucket_peak_bound_tightens(self, bucketed_fn):
        table = bucketed_fn.specialization_table
        bounds = [table.get(k).report.peak_bound_bytes
                  for k in table.space.keys()]
        assert all(b is not None for b in bounds)
        assert min(bounds) < bucketed_fn.report.peak_bound_bytes
        assert max(bounds) <= bucketed_fn.report.peak_bound_bytes


# -- dispatch behaviour -------------------------------------------------------


class TestDispatch:
    def test_call_dispatches_and_matches_reference(self, bucketed_fn):
        fn = bucketed_fn
        cp = concrete_params()
        for (b, s), key in [((2, 16), (0, 0)), ((2, 48), (0, 1)),
                            ((1, 200), (0, 2))]:
            tok = tokens_of(b, s)
            loss, _ = fn(cp, tok, tok)
            assert fn.last_bucket == key
            ref_loss, _ = train_step(cp, tok, tok)
            np.testing.assert_allclose(np.asarray(loss), np.asarray(ref_loss),
                                       rtol=2e-5)

    def test_boundary_env_dispatch_is_deterministic(self, bucketed_fn):
        fn = bucketed_fn
        cp = concrete_params()
        tok = tokens_of(2, 32)            # exactly on the first edge
        for _ in range(2):
            fn(cp, tok, tok)
            assert fn.last_bucket == (0, 0)   # inclusive edge: lower bucket
        tok = tokens_of(2, 33)
        fn(cp, tok, tok)
        assert fn.last_bucket == (0, 1)

    def test_hit_path_never_replans(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [64]})
        table = fn.specialization_table
        cp = concrete_params()
        tok = tokens_of(2, 16)
        fn(cp, tok, tok)
        assert table.specialize_count == 1 and table.hits == 0
        plan_before = table.peek(fn.last_bucket).plan
        for i in range(3):                # repeated same-bucket traffic
            loss, _ = fn(cp, tok, tok)
            st = fn.last_report.stats
            assert st.specialize_count == 1       # no re-planning on hits
            assert st.bucket_hits == i + 1
            assert st.last_dispatch_ns > 0
            assert st.dispatch_ns_total >= st.last_dispatch_ns
        assert table.peek(fn.last_bucket).plan is plan_before

    def test_lru_eviction_and_recompile(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [16, 32, 64]},   # 4 buckets
                      max_cached_plans=2)
        table = fn.specialization_table
        cp = concrete_params()
        fn(cp, tokens_of(2, 12), tokens_of(2, 12))     # bucket 0
        fn(cp, tokens_of(2, 30), tokens_of(2, 30))     # bucket 1
        fn(cp, tokens_of(2, 60), tokens_of(2, 60))     # bucket 2 -> evicts 0
        assert table.specialize_count == 3
        assert table.evictions == 1
        assert table.peek((0, 0)) is None              # bucket 0 gone
        assert len(table.compiled_keys) == 2
        loss, _ = fn(cp, tokens_of(2, 12), tokens_of(2, 12))  # recompile 0
        assert table.specialize_count == 4
        ref, _ = train_step(cp, tokens_of(2, 12), tokens_of(2, 12))
        np.testing.assert_allclose(np.asarray(loss), np.asarray(ref),
                                   rtol=2e-5)

    def test_bounds_survive_plan_eviction(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [16, 32, 64]},
                      max_cached_plans=2)
        table = fn.specialization_table
        bound0 = table.arena_bound_bytes((0, 0))     # compiles bucket 0
        table.get((0, 1))
        table.get((0, 2))                            # evicts bucket 0's plan
        assert table.peek((0, 0)) is None
        spec = table.specialize_count
        # the bound is still known — answered without recompiling
        assert table.arena_bound_bytes((0, 0)) == bound0
        assert table.specialize_count == spec

    def test_warmup_precompiles_so_first_call_hits(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [64]})
        keys = fn.warmup([{"b": 2, "s": 16}, {"b": 4, "s": 20},
                          {"b": 2, "s": 100}])
        assert keys == [(0, 0), (0, 1)]   # deduped, first-seen order
        table = fn.specialization_table
        assert table.specialize_count == 2 and table.hits == 0
        cp = concrete_params()
        fn(cp, tokens_of(2, 16), tokens_of(2, 16))
        assert table.hits == 1 and table.specialize_count == 2

    def test_out_of_range_env_raises_before_dispatch(self, bucketed_fn):
        cp = concrete_params()
        tok = tokens_of(2, 300)           # s beyond the declared 256
        with pytest.raises(ValueError, match="declared range"):
            bucketed_fn(cp, tok, tok)

    def test_unbucketed_function_has_no_table(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)})
        assert fn.specialization_table is None
        with pytest.raises(ValueError, match="buckets"):
            fn.warmup([{"b": 2, "s": 16}])

    def test_buckets_require_dynamic_dims(self):
        with pytest.raises(ValueError, match="dynamic_dims"):
            optimize(train_step, *specs(), buckets="geometric")

    def test_with_memory_limit_keeps_bucketing(self, bucketed_fn):
        capped = bucketed_fn.with_memory_limit(512 << 20)
        assert capped.specialization_table is not None
        cp = concrete_params()
        tok = tokens_of(2, 16)
        loss, _ = capped(cp, tok, tok)
        assert capped.last_bucket == (0, 0)
        ref, _ = train_step(cp, tok, tok)
        np.testing.assert_allclose(np.asarray(loss), np.asarray(ref),
                                   rtol=2e-5)


# -- the serve path -----------------------------------------------------------


class TestBucketBatcher:
    def test_groups_same_bucket_requests(self, bucketed_fn):
        batcher = BucketBatcher(bucketed_fn)
        for s in [16, 40, 16, 200, 24]:
            batcher.submit({"b": 2, "s": s}, payload=s)
        assert batcher.pending() == 5
        groups = batcher.drain()
        assert batcher.pending() == 0
        by_key = {g.key: g for g in groups}
        assert sorted(by_key) == [(0, 0), (0, 1), (0, 2)]
        assert sorted(by_key[(0, 0)].payloads) == [16, 16, 24]
        assert by_key[(0, 1)].payloads == [40]
        assert by_key[(0, 2)].payloads == [200]
        # largest group drains first
        assert groups[0].key == (0, 0)

    def test_admission_control_holds_heavy_buckets(self, bucketed_fn):
        table = bucketed_fn.specialization_table
        small = table.arena_bound_bytes((0, 0))
        big = table.arena_bound_bytes((0, 2))
        assert small < big
        batcher = BucketBatcher(bucketed_fn, memory_budget=(small + big) // 2)
        batcher.submit({"b": 2, "s": 16}, payload="small")
        batcher.submit({"b": 2, "s": 200}, payload="big")
        groups = batcher.drain()
        assert [g.payloads for g in groups] == [["small"]]
        assert batcher.pending() == 1     # heavy bucket held, not dropped
        assert batcher.pending_by_bucket() == {(0, 2): 1}
        # raising the budget releases it
        batcher.memory_budget = big
        groups = batcher.drain()
        assert [g.payloads for g in groups] == [["big"]]
        assert batcher.pending() == 0

    def test_group_bound_is_the_bucket_guarantee(self, bucketed_fn):
        batcher = BucketBatcher(bucketed_fn)
        batcher.submit({"b": 2, "s": 16})
        (group,) = batcher.drain()
        table = bucketed_fn.specialization_table
        assert group.arena_bound_bytes == table.arena_bound_bytes((0, 0))

    def test_requires_bucketed_function(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)})
        with pytest.raises(ValueError, match="buckets"):
            BucketBatcher(fn)

    def test_submit_rejects_out_of_range_env_at_intake(self, bucketed_fn):
        batcher = BucketBatcher(bucketed_fn)
        with pytest.raises(ValueError, match="outside the bucketed range"):
            batcher.submit({"b": 2, "s": 5000})
        assert batcher.pending() == 0
        with pytest.raises(ValueError, match="outside the bucketed range"):
            bucketed_fn.warmup([{"b": 2, "s": 5000}])

    def test_repeated_drains_do_not_recompile_held_buckets(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [64]})
        table = fn.specialization_table
        big = table.arena_bound_bytes((0, 1))
        batcher = BucketBatcher(fn, memory_budget=big - 1)
        batcher.submit({"b": 2, "s": 200})
        spec = table.specialize_count
        for _ in range(3):                # held, not recompiled per drain
            assert batcher.drain() == []
            assert batcher.pending() == 1
        assert table.specialize_count == spec
