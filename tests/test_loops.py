"""Rolled-vs-unrolled differential harness for symbolic control flow.

For each of the 4 benchmark archs a small autoregressive decode cell is
built from the arch's smoke config (its ``d_model`` and input mode); the
rolled form compiles the ``jax.lax.scan`` with a *symbolic* trip count
``t`` into a single ``Loop`` node, the oracle is the mechanically
unrolled DAG (a Python loop at static T) compiled through the identical
pipeline.  The harness asserts, at trip counts {1, 2, 17}:

  * rolled outputs are **bitwise identical** to the unrolled oracle;
  * the VM and the reference interpreter running the *same* rolled plan
    produce bitwise-identical outputs and identical memory stats
    (dispatch timing excluded — it is wall time), including under
    donation, a memory limit that forces eviction+regen across the
    loop, and a limit neither executor can satisfy (both must raise);
  * the lowered rolled ``Program`` is O(body): its instruction counts
    are independent of the declared trip-count range, and smaller than
    the unrolled Program at T=17;
  * the device peak is steady-state: past the first iterations it grows
    only by the t-scaled inputs/outputs (per-iteration temporaries and
    both carry generations live in trip-count-independent arena slots).

Plus the SPMD-stability regression for trip-count bucketing: two
``SpecializationTable``s built from the same spec must map every env in
range to the same bucket key (geometric edges are computed with exact
integer arithmetic, never float pow).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import optimize, symbolic_dim
from repro.core.dispatch import SpecializationTable, build_bucket_space
from repro.core.dispatch.buckets import _geometric_uppers, _nearest_nth_root
from repro.core.executor.memory import MemoryLimitExceeded
from repro.core.symbolic import ShapeGraph, declare_dim_ranges

ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]
TRIPS = [1, 2, 17]
T_RANGE = (1, 64)
B = 2          # static batch: only the trip count is dynamic here
V = 32         # toy vocab for token-mode archs


def _cell(arch):
    """Decode cell for one arch: (step, param_specs, xs_spec_fn).

    ``step(params, carry, x)`` is one decode step — the *same* function
    is scanned in the rolled form and repeated in the unrolled oracle,
    so any output divergence is the pipeline's fault, not the model's.
    """
    cfg = get_smoke_config(arch)
    d = cfg.d_model
    tokens = cfg.input_mode == "tokens"

    def step(params, c, x):
        e = params["emb"][x] if tokens else x @ params["wx"]
        h = jnp.tanh(c @ params["wh"] + e)
        return h, jnp.sum(h, axis=-1)

    p = {"wh": jax.ShapeDtypeStruct((d, d), jnp.float32),
         "wb": jax.ShapeDtypeStruct((d, d), jnp.float32),
         "h0": jax.ShapeDtypeStruct((B, d), jnp.float32)}
    if tokens:
        p["emb"] = jax.ShapeDtypeStruct((V, d), jnp.float32)
        xs_spec = lambda t: jax.ShapeDtypeStruct((t, B), jnp.int32)
    else:
        p["wx"] = jax.ShapeDtypeStruct((d, d), jnp.float32)
        xs_spec = lambda t: jax.ShapeDtypeStruct((t, B, d), jnp.float32)
    return step, p, xs_spec


def _rolled_fn(arch):
    step, _, _ = _cell(arch)

    def f(params, xs):
        # `big` is consumed both before and after the scan, so the
        # scheduler cannot sink it past the loop: it stays live across
        # the back-edge with an idle span covering the Loop node — the
        # eviction configs need exactly such a victim
        big = jnp.tanh(params["wb"])
        c0 = jnp.tanh(params["h0"] + big[0])
        cN, ys = jax.lax.scan(lambda c, x: step(params, c, x), c0, xs)
        return cN @ big, ys
    return f


def _unrolled_fn(arch, T):
    step, _, _ = _cell(arch)

    def f(params, xs):
        big = jnp.tanh(params["wb"])
        c = jnp.tanh(params["h0"] + big[0])
        ys = []
        for i in range(T):
            c, y = step(params, c, xs[i])
            ys.append(y)
        return c @ big, jnp.stack(ys)
    return f


def _concrete(arch, T, seed=0):
    _, p_specs, xs_spec = _cell(arch)
    rng = np.random.RandomState(seed)
    params = {}
    for k, s in p_specs.items():
        params[k] = jnp.asarray(rng.randn(*s.shape) * 0.2, s.dtype)
    xs = xs_spec(T)
    if np.issubdtype(xs.dtype, np.integer):
        xv = jnp.asarray(rng.randint(0, V, xs.shape), xs.dtype)
    else:
        xv = jnp.asarray(rng.randn(*xs.shape) * 0.2, xs.dtype)
    return params, xv


def _compile_rolled(arch, executor, **kw):
    t = symbolic_dim("t")
    _, p_specs, xs_spec = _cell(arch)
    return optimize(_rolled_fn(arch), p_specs, xs_spec(t),
                    dynamic_dims={"t": T_RANGE}, executor=executor, **kw)


def _compile_unrolled(arch, T, **kw):
    _, p_specs, xs_spec = _cell(arch)
    return optimize(_unrolled_fn(arch, T), p_specs, xs_spec(T), **kw)


def _stats(fn):
    d = fn.last_report.stats.as_dict()
    d.pop("last_dispatch_ns", None)     # wall time, not semantics
    d.pop("dispatch_ns_total", None)
    return d


def _assert_bitwise(a, b, msg):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), msg


def _per_step_io_bytes(arch):
    """Bytes of one xs slice + one stacked-y slice: the only t-scaled
    tensors a steady-state loop is allowed to grow the peak by."""
    _, _, xs_spec = _cell(arch)
    x1 = xs_spec(1)
    x_step = int(np.prod(x1.shape)) * x1.dtype.itemsize
    y_step = B * 4                      # per-step y is float32 (B,)
    return x_step + y_step


# -- rolled vs unrolled, VM vs interpreter ------------------------------------


class TestRolledVsUnrolled:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_bitwise_outputs_and_identical_stats(self, arch):
        ref = _compile_rolled(arch, "reference")
        vm = _compile_rolled(arch, "vm")
        for T in TRIPS:
            params, xs = _concrete(arch, T, seed=T)
            r_out = ref(params, xs)
            r_stats = _stats(ref)
            v_out = vm(params, xs)
            v_stats = _stats(vm)
            _assert_bitwise(r_out, v_out,
                            f"{arch} T={T}: VM != interpreter")
            assert r_stats == v_stats, \
                f"{arch} T={T}: stats diverge: " + str({
                    k: (r_stats[k], v_stats[k]) for k in r_stats
                    if r_stats[k] != v_stats[k]})
            oracle = _compile_unrolled(arch, T)
            o_out = oracle(params, xs)
            _assert_bitwise(r_out, o_out,
                            f"{arch} T={T}: rolled != unrolled oracle")

    @pytest.mark.parametrize("arch", ARCHS)
    def test_donate_inputs_differential(self, arch):
        ref = _compile_rolled(arch, "reference", donate_inputs=True)
        vm = _compile_rolled(arch, "vm", donate_inputs=True)
        base = _compile_rolled(arch, "vm")
        for T in (2, 17):
            params, xs = _concrete(arch, T, seed=T)
            b_out = base(params, xs)
            r_out = ref(params, xs)
            v_out = vm(params, xs)
            _assert_bitwise(r_out, v_out, f"{arch} T={T} donate: VM != ref")
            _assert_bitwise(r_out, b_out,
                            f"{arch} T={T}: donation changed outputs")
            assert _stats(ref) == _stats(vm)

    @pytest.mark.parametrize("arch", ARCHS)
    def test_memory_limit_regen_differential(self, arch):
        free = _compile_rolled(arch, "vm")
        params, xs = _concrete(arch, 17, seed=17)
        base_out = free(params, xs)
        peak = free.last_report.stats.device_peak
        # tight enough that `big` (idle across the loop) must be evicted
        # before the Loop's hoisted ensure, loose enough to succeed
        limit = peak - 512
        ref = _compile_rolled(arch, "reference", memory_limit=limit)
        vm = _compile_rolled(arch, "vm", memory_limit=limit)
        r_out = ref(params, xs)
        v_out = vm(params, xs)
        _assert_bitwise(r_out, v_out, f"{arch} limited: VM != interpreter")
        _assert_bitwise(r_out, base_out,
                        f"{arch}: eviction+regen changed outputs")
        r_stats, v_stats = _stats(ref), _stats(vm)
        assert r_stats == v_stats
        assert r_stats["evictions"] >= 1, \
            "limit was meant to force an eviction across the loop"
        assert r_stats["recomputes"] + r_stats["reloads"] >= 1

    @pytest.mark.parametrize("arch", ARCHS)
    def test_impossible_limit_raises_on_both(self, arch):
        params, xs = _concrete(arch, 17, seed=17)
        # below the un-evictable working set (inputs alone exceed it)
        limit = sum(int(np.asarray(v).nbytes) for v in params.values())
        for executor in ("reference", "vm"):
            fn = _compile_rolled(arch, executor, memory_limit=limit)
            with pytest.raises(MemoryLimitExceeded):
                fn(params, xs)


# -- plan size and steady-state memory ----------------------------------------


class TestLoopPlanShape:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_program_is_o_body_not_o_trip(self, arch):
        vm = _compile_rolled(arch, "vm")
        counts = vm.program.counts()
        assert counts["Loop"] == 1
        # widening the declared trip range must not change the program
        t = symbolic_dim("t")
        _, p_specs, xs_spec = _cell(arch)
        wide = optimize(_rolled_fn(arch), p_specs, xs_spec(t),
                        dynamic_dims={"t": (1, 4096)}, executor="vm")
        assert wide.program.counts() == counts
        # and the unrolled T=17 program really is O(T * body)
        unrolled = _compile_unrolled(arch, 17)
        assert (unrolled.program.counts()["Compute"]
                > 17 * max(1, counts["Compute"]))
        assert sum(counts.values()) < sum(unrolled.program.counts().values())

    @pytest.mark.parametrize("arch", ARCHS)
    def test_steady_state_peak_independent_of_trip(self, arch):
        vm = _compile_rolled(arch, "vm")
        peaks = {}
        for T in (2, 17, 33):
            params, xs = _concrete(arch, T, seed=1)
            vm(params, xs)
            peaks[T] = vm.last_report.stats.device_peak
        step = _per_step_io_bytes(arch)
        # past the first iterations the peak grows ONLY by the t-scaled
        # xs input and stacked-y output — the loop's internal arena
        # (temporaries + both carry generations) is trip-count-independent
        assert peaks[17] - peaks[2] == 15 * step
        assert peaks[33] - peaks[17] == 16 * step


# -- SPMD-stable trip-count dispatch ------------------------------------------


class TestTripCountDispatchSPMDStable:
    def _table(self, ranges):
        space = build_bucket_space(ranges, "geometric")
        return SpecializationTable(space, lambda key, rng: None)

    def test_two_tables_same_bucket_for_every_env(self):
        # two replicas each build their own table from the same spec —
        # every in-range trip count must land in the same bucket on both,
        # or SPMD programs silently diverge at the dispatch boundary
        for hi in (64, 4096, 100_000):
            sg1, sg2 = ShapeGraph(), ShapeGraph()
            declare_dim_ranges(sg1, {"t": (1, hi)})
            declare_dim_ranges(sg2, {"t": (1, hi)})
            t1 = self._table(sg1.declared_ranges)
            t2 = self._table(sg2.declared_ranges)
            probe = range(1, hi + 1) if hi <= 4096 else \
                list(range(1, 1000)) + list(range(1, hi + 1, 997)) + [hi]
            for v in probe:
                assert t1.key_of({"t": v}) == t2.key_of({"t": v})

    def test_geometric_edges_are_exact_integer_roots(self):
        # the documented contract: edge k is the nearest integer to
        # (lo^(n-k) * hi^k)^(1/n), decided by exact integer comparisons
        for lo, hi, n in [(1, 64, 4), (16, 4096, 4), (1, 10**9, 8),
                          (3, 7, 4), (5, 5_000_000, 6)]:
            uppers = _geometric_uppers(lo, hi, n)
            assert uppers[-1] == hi
            assert all(a < b for a, b in zip(uppers, uppers[1:]))
            prev = max(lo, 1) - 1
            expect = []
            for k in range(1, n):
                u = _nearest_nth_root(max(lo, 1) ** (n - k) * hi ** k, n)
                if u <= prev or u >= hi:
                    continue
                expect.append(u)
                prev = u
            assert uppers == tuple(expect) + (hi,)

    def test_nearest_nth_root_is_exact(self):
        for p, n in [(0, 3), (1, 5), (8, 3), (9, 2), (26, 3), (27, 3),
                     (28, 3), (10**18, 6), (10**18 + 1, 6), (2, 2)]:
            r = _nearest_nth_root(p, n)
            # r is within half a unit of the real root: the two exact
            # integer inequalities that define "nearest"
            assert (2 * r - 1) ** n <= 2 ** n * p if r > 0 else p == 0
            assert 2 ** n * p < (2 * r + 1) ** n
