"""Unit + property tests for the symbolic shape system (paper §2.1)."""
import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax import export

from repro.core.symbolic import (Cmp, ShapeGraph, SymbolicExpr, dim_to_expr,
                                 size_of)


def V(n):
    return SymbolicExpr.var(n)


class TestExprAlgebra:
    def test_constants(self):
        assert SymbolicExpr.constant(3) + 4 == SymbolicExpr.constant(7)
        assert SymbolicExpr.constant(3) * 4 == SymbolicExpr.constant(12)
        assert (SymbolicExpr.constant(3) - 3).constant_value() == 0

    def test_polynomial_identity(self):
        a, b = V("a"), V("b")
        assert (a + b) * (a - b) == a * a - b * b

    def test_evaluate(self):
        a, b = V("a"), V("b")
        e = 3 * a * a * b - 2 * b + 7
        assert e.evaluate({"a": 5, "b": 2}) == 3 * 25 * 2 - 4 + 7

    def test_floordiv_exact_stays_polynomial(self):
        a = V("a")
        assert (12 * a).floordiv(4) == 3 * a

    def test_floordiv_opaque_evaluates(self):
        a = V("a")
        e = (a + 1).floordiv(2)
        assert e.evaluate({"a": 5}) == 3
        assert e.evaluate({"a": 4}) == 2

    def test_mod(self):
        a = V("a")
        assert (8 * a).mod(4).constant_value() == 0
        assert (a + 1).mod(3).evaluate({"a": 4}) == 2

    def test_max_min(self):
        a = V("a")
        assert SymbolicExpr.max_of(a, a) == a
        assert SymbolicExpr.max_of(3, 5).constant_value() == 5
        e = SymbolicExpr.min_of(a, 10)
        assert e.evaluate({"a": 3}) == 3
        assert e.evaluate({"a": 30}) == 10

    def test_size_of(self):
        a, b = V("a"), V("b")
        assert size_of((a, 4, b)) == 4 * a * b


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 50), st.integers(1, 50), st.integers(-20, 20),
       st.integers(-20, 20))
def test_property_eval_homomorphism(x, y, c1, c2):
    a, b = V("a"), V("b")
    e1 = c1 * a * b + c2 * b
    e2 = c2 * a - c1
    env = {"a": x, "b": y}
    assert (e1 + e2).evaluate(env) == e1.evaluate(env) + e2.evaluate(env)
    assert (e1 * e2).evaluate(env) == e1.evaluate(env) * e2.evaluate(env)
    assert (e1 - e2).evaluate(env) == e1.evaluate(env) - e2.evaluate(env)


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 1000), st.integers(1, 1000))
def test_property_compare_soundness(x, y):
    """If the shape graph claims an order, concrete evaluation agrees."""
    g = ShapeGraph()
    a, b = V("a"), V("b")
    e1 = 3 * a + 2 * b
    e2 = 2 * a + 2 * b + 5
    c = g.compare(e1, e2)
    env = {"a": x, "b": y}
    v1, v2 = e1.evaluate(env), e2.evaluate(env)
    if c is Cmp.LT:
        assert v1 < v2
    elif c is Cmp.GT:
        assert v1 > v2
    elif c in (Cmp.LE,):
        assert v1 <= v2
    elif c in (Cmp.GE,):
        assert v1 >= v2


class TestShapeGraph:
    def test_paper_listing1(self):
        """@S0 = 12*@S1; 11008*@S1 < 1024*@S0 (paper §2.1 example)."""
        g = ShapeGraph()
        g.add_equality("S0", 12 * V("S1"))
        expr1 = 11008 * V("S1")
        expr2 = 1024 * V("S0")
        assert g.canonicalize(expr2) == 12288 * V("S1")
        assert g.compare(expr1, expr2) is Cmp.LT

    def test_paper_scheduling_example(self):
        """DotOp impact 10996*S1 vs reshape impact 4096*S0 (paper §2.2)."""
        g = ShapeGraph()
        g.add_equality("S0", 12 * V("S1"))
        dot_impact = 11008 * V("S1") - 12 * V("S1")
        reshape_impact = 4096 * V("S0")
        assert g.compare(reshape_impact, dot_impact) is Cmp.GT

    def test_unknown_then_bounded(self):
        g = ShapeGraph()
        a, b = V("a"), V("b")
        assert g.compare(a, b) is Cmp.UNKNOWN
        g.set_bounds("a", hi=10)
        g.set_bounds("b", lo=11)
        assert g.compare(a, b) is Cmp.LT

    def test_chained_equalities(self):
        g = ShapeGraph()
        g.add_equality("x", 2 * V("y"))
        g.add_equality("y", 3 * V("z"))
        assert g.canonicalize(V("x")) == 6 * V("z")

    def test_default_lower_bound(self):
        g = ShapeGraph()  # dims >= 1
        a = V("a")
        assert g.compare(a + 1, 1) is Cmp.GT
        assert g.compare(a, 0) is Cmp.GT

    def test_interval_of_respects_equalities(self):
        g = ShapeGraph()
        g.add_equality("S0", 12 * V("S1"))
        g.declare_range("S1", lo=2, hi=10)
        iv = g.interval_of(V("S0") + 5)
        assert (iv.lo, iv.hi) == (29, 125)
        assert g.bounds_of(V("S0")) == (24, 120)

    def test_declare_range_merges_sides(self):
        g = ShapeGraph()
        g.declare_range("a", hi=10)
        g.declare_range("a", lo=3)   # keeps the earlier upper bound
        assert g.bounds_of(V("a")) == (3, 10)

    def test_cmp_stats_layers(self):
        g = ShapeGraph()
        g.declare_range("a", hi=4)
        g.compare(SymbolicExpr.constant(1), 2)      # constant layer
        g.compare(V("a"), 100)                      # interval layer
        g.compare(V("a"), V("zzz"))                 # unresolved
        for k, v in {"const": 1, "interval": 1, "unknown": 1,
                     "cache_hit": 0, "cache_miss": 3}.items():
            assert g.cmp_stats[k] == v, k
        # repeating a query hits the memo but still counts its layer
        g.compare(V("a"), 100)
        assert g.cmp_stats["cache_hit"] == 1
        assert g.cmp_stats["interval"] == 2
        # narrowing the consulted dim invalidates exactly that entry
        g.declare_range("a", hi=2)
        g.compare(V("a"), 100)
        assert g.cmp_stats["cache_miss"] == 4


class TestFromJax:
    def test_roundtrip_polynomial(self):
        b, s = export.symbolic_shape("b, s")
        e = dim_to_expr(12 * b + s * s - 3)
        assert e.evaluate({"b": 4, "s": 10}) == 48 + 100 - 3

    def test_floordiv_dim(self):
        b, s = export.symbolic_shape("b, s")
        e = dim_to_expr((b * s) // 128)
        assert e.evaluate({"b": 4, "s": 256}) == 8

    def test_exact_division_simplifies(self):
        b, = export.symbolic_shape("b")
        assert dim_to_expr((b * 128) // 128) == V("b")

    def test_int_passthrough(self):
        assert dim_to_expr(7).constant_value() == 7
