"""Test-suite bootstrap: a minimal ``hypothesis`` fallback.

The property-based tests use `hypothesis <https://hypothesis.works>`_ when
it is installed (the declared dev dependency — see ``pyproject.toml`` and
CI).  Some execution environments ship only the runtime deps; rather than
failing at collection, this conftest installs a tiny API-compatible shim
that drives each ``@given`` test with deterministic pseudo-random examples.
The shim covers exactly the subset this suite uses: ``given``, ``settings``
and the ``integers`` / ``sampled_from`` / ``booleans`` / ``lists`` /
``tuples`` strategies.  Real hypothesis, when present, always wins.
"""
from __future__ import annotations

import random
import sys
import types

try:  # pragma: no cover - prefer the real package
    import hypothesis  # noqa: F401
except ImportError:
    _SEED = 0xB1ADED15C  # deterministic: same examples on every run

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rnd: rnd.choice(elements))

    def booleans():
        return _Strategy(lambda rnd: rnd.random() < 0.5)

    def lists(elem, min_size=0, max_size=8):
        return _Strategy(
            lambda rnd: [elem.draw(rnd)
                         for _ in range(rnd.randint(min_size, max_size))])

    def tuples(*elems):
        return _Strategy(lambda rnd: tuple(e.draw(rnd) for e in elems))

    def given(*strategies, **kw_strategies):
        def deco(fn):
            def wrapper():
                rnd = random.Random(_SEED)
                for _ in range(getattr(wrapper, "_max_examples", 50)):
                    args = [s.draw(rnd) for s in strategies]
                    kwargs = {k: s.draw(rnd) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)
            # NB: no __wrapped__ — pytest would unwrap to fn's signature and
            # mistake the example parameters for fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = 50
            return wrapper
        return deco

    def settings(max_examples=50, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.sampled_from = sampled_from
    _st.booleans = booleans
    _st.lists = lists
    _st.tuples = tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
