"""Property-based pipeline fuzzer: VM ≡ interpreter and rolled ≡ unrolled.

Hypothesis (or the deterministic shim from ``conftest.py`` when the real
package is absent) generates small random graphs — an op-chain drawn from
a fixed vocabulary, random declared trip-count ranges, random loop bodies
with one or two carries, optional passthrough carries, kept or dropped
stacked outputs, optional input donation — and every example is pushed
through the *whole* pipeline four ways:

  * the rolled ``scan`` under the ProgramVM,
  * the rolled ``scan`` under the reference interpreter,
  * the mechanically unrolled DAG (Python loop at the concrete trip
    count) under the ProgramVM,

asserting the two rolled executors agree bitwise *and* on memory stats,
and that the rolled form equals the unrolled oracle bitwise.  A second
loop-free fuzzer covers plain DAG chains the same way.  Failures print
the drawn spec, which is the whole reproducer.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import optimize, symbolic_dim
from repro.kernels import (masked_select, nonzero_pad, topk_dynamic,
                           unique_bounded)

R = 3          # fixed leading dim of the carry block


def _apply_op(oc, h, params, x):
    """One vocabulary op; every op preserves the (R, d) carry shape."""
    if oc == 0:
        return jnp.tanh(h)
    if oc == 1:
        return h * params["w"]
    if oc == 2:
        return h + x
    if oc == 3:
        return h @ params["wm"]
    return h - 0.25 * h * h


def _build_fns(opcodes, two_carry, passthrough, return_ys, T):
    """(rolled_fn, unrolled_fn) tracing the identical op sequence."""

    def body(params, c, x):
        c1, c2 = c
        h = c1
        for oc in opcodes:
            h = _apply_op(oc, h, params, x)
        if two_carry:
            n2 = c2 if passthrough else c2 * 0.9 + h * 0.1
        else:
            n2 = c2
        return (h, n2), h * 2.0

    def rolled(params, c1, c2, xs):
        def f(c, x):
            return body(params, c, x)
        (h, n2), ys = jax.lax.scan(f, (c1, c2), xs)
        outs = (h, n2) if two_carry else (h,)
        return outs + ((ys,) if return_ys else ())

    def unrolled(params, c1, c2, xs):
        c = (c1, c2)
        ys = []
        for i in range(T):
            c, y = body(params, c, xs[i])
            ys.append(y)
        outs = (c[0], c[1]) if two_carry else (c[0],)
        return outs + ((jnp.stack(ys),) if return_ys else ())

    return rolled, unrolled


def _specs(d, t):
    p = {"w": jax.ShapeDtypeStruct((d,), jnp.float32),
         "wm": jax.ShapeDtypeStruct((d, d), jnp.float32)}
    c = jax.ShapeDtypeStruct((R, d), jnp.float32)
    xs = jax.ShapeDtypeStruct((t, R, d), jnp.float32)
    return p, c, c, xs


def _concrete(d, T, seed):
    rng = np.random.RandomState(seed)
    arr = lambda *s: jnp.asarray(rng.randn(*s) * 0.1, jnp.float32)
    params = {"w": arr(d), "wm": arr(d, d)}
    return params, arr(R, d), arr(R, d), arr(T, R, d)


def _assert_bitwise(a, b, spec):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            f"bitwise divergence for {spec}"


def _stats(fn):
    d = fn.last_report.stats.as_dict()
    d.pop("last_dispatch_ns", None)
    d.pop("dispatch_ns_total", None)
    return d


@settings(max_examples=12, deadline=None)
@given(opcodes=st.lists(st.integers(0, 4), min_size=1, max_size=4),
       d=st.integers(2, 5),
       hi=st.sampled_from([4, 16, 64]),
       T=st.integers(1, 5),
       two_carry=st.booleans(),
       passthrough=st.booleans(),
       return_ys=st.booleans(),
       donate=st.booleans())
def test_rolled_loop_pipeline_fuzz(opcodes, d, hi, T, two_carry,
                                   passthrough, return_ys, donate):
    T = min(T, hi)
    spec = dict(opcodes=opcodes, d=d, hi=hi, T=T, two_carry=two_carry,
                passthrough=passthrough, return_ys=return_ys, donate=donate)
    rolled, unrolled = _build_fns(opcodes, two_carry, passthrough,
                                  return_ys, T)
    t = symbolic_dim("t")
    kw = dict(donate_inputs=True) if donate else {}
    vm = optimize(rolled, *_specs(d, t), dynamic_dims={"t": (1, hi)},
                  executor="vm", **kw)
    ref = optimize(rolled, *_specs(d, t), dynamic_dims={"t": (1, hi)},
                   executor="reference", **kw)
    oracle = optimize(unrolled, *_specs(d, T), **kw)

    args = _concrete(d, T, seed=sum(opcodes) + d + T)
    v_out = vm(*args)
    v_stats = _stats(vm)
    r_out = ref(*args)
    r_stats = _stats(ref)
    o_out = oracle(*args)

    _assert_bitwise(v_out, r_out, spec)
    assert v_stats == r_stats, f"stats diverge for {spec}: " + str({
        k: (v_stats[k], r_stats[k]) for k in v_stats
        if v_stats[k] != r_stats[k]})
    _assert_bitwise(v_out, o_out, spec)
    # the rolled program must contain the loop as a single instruction
    assert vm.program.counts()["Loop"] == 1, spec


@settings(max_examples=20, deadline=None)
@given(opcodes=st.lists(st.integers(0, 4), min_size=1, max_size=6),
       d=st.integers(2, 6),
       hi=st.sampled_from([8, 64, 512]),
       donate=st.booleans())
def test_plain_dag_vm_vs_interpreter_fuzz(opcodes, d, hi, donate):
    spec = dict(opcodes=opcodes, d=d, hi=hi, donate=donate)

    def f(params, a, x):
        h = jnp.tanh(a)
        for oc in opcodes:
            h = _apply_op(oc, h, params, x)
        return h, jnp.sum(h, axis=-1)

    s = symbolic_dim("s")
    p = {"w": jax.ShapeDtypeStruct((d,), jnp.float32),
         "wm": jax.ShapeDtypeStruct((d, d), jnp.float32)}
    a = jax.ShapeDtypeStruct((s, d), jnp.float32)
    kw = dict(donate_inputs=True) if donate else {}
    vm = optimize(f, p, a, a, dynamic_dims={"s": (1, hi)},
                  executor="vm", **kw)
    ref = optimize(f, p, a, a, dynamic_dims={"s": (1, hi)},
                   executor="reference", **kw)

    n = min(hi, 1 + sum(opcodes))
    rng = np.random.RandomState(d + n)
    arr = lambda *shape: jnp.asarray(rng.randn(*shape) * 0.1, jnp.float32)
    args = ({"w": arr(d), "wm": arr(d, d)}, arr(n, d), arr(n, d))
    v_out = vm(*args)
    v_stats = _stats(vm)
    r_out = ref(*args)
    r_stats = _stats(ref)
    _assert_bitwise(v_out, r_out, spec)
    assert v_stats == r_stats, f"stats diverge for {spec}"


# -- value-dependent bounded dims ----------------------------------------------
#
# Random DAGs mixing the SoD² op classes: *introduce* ops mint a fresh
# bounded dim whose extent only the input values decide (masked_select /
# nonzero_pad / topk_dynamic / unique_bounded), *propagate* ops (the
# elementwise vocabulary) carry it along.  Occupancy is driven through a
# value threshold so the 0%-fill and 100%-fill edges are exact.  Contract
# per drawn program, at every probed env:
#
#   * ProgramVM ≡ PlanInterpreter bitwise on outputs,
#   * memory stats identical dict-for-dict (measured_dims included),
#   * the runtime arena (tight, measured sizes) never exceeds the plan's
#     ``arena_bound_bytes`` reserve computed from the caps.

# threshold on h > t realizes the drawn occupancy exactly at the edges
_OCC_THRESHOLD = {0.0: 1e9, 0.5: 0.0, 1.0: -1e9}


def _build_bounded_fn(opcodes, occ):
    t = _OCC_THRESHOLD[occ]

    def f(x, k):
        h = x
        total = k * 0
        for oc in opcodes:
            if oc == 0:
                h = jnp.tanh(h)
            elif oc == 1:
                h = h * 2.0 + 0.25
            elif oc == 2:
                h = h - 0.5 * h * h
            elif oc == 3:
                h, c = masked_select(h, h > t)
                total = total + c
            elif oc == 4:
                idx, c = nonzero_pad(h)
                h = idx.astype(jnp.float32)
                total = total + c
            elif oc == 5:
                h, c = topk_dynamic(h, k)
                total = total + c
            else:
                h, c = unique_bounded(h)
                total = total + c
        return jnp.sum(h), total

    return f


@settings(max_examples=10, deadline=None)
@given(opcodes=st.lists(st.integers(0, 6), min_size=1, max_size=5),
       occ=st.sampled_from([0.0, 0.5, 1.0]),
       n=st.sampled_from([4, 13, 32]),
       hi=st.sampled_from([32, 64]))
def test_value_dependent_bounded_fuzz(opcodes, occ, n, hi):
    n = min(n, hi)
    spec = dict(opcodes=opcodes, occ=occ, n=n, hi=hi)
    f = _build_bounded_fn(opcodes, occ)
    s = symbolic_dim("s")
    specs = (jax.ShapeDtypeStruct((s,), jnp.float32),
             jax.ShapeDtypeStruct((), jnp.int32))
    vm = optimize(f, *specs, dynamic_dims={"s": (1, hi)}, executor="vm")
    ref = optimize(f, *specs, dynamic_dims={"s": (1, hi)},
                   executor="reference")

    n_intro = sum(1 for oc in opcodes if oc >= 3)
    assert len(vm.plan.graph.bound_dims) == n_intro, spec
    assert vm.program.counts()["BindDim"] == n_intro, spec

    for env_n in (n, max(1, n // 2)):
        rng = np.random.RandomState(env_n + sum(opcodes))
        x = jnp.asarray(rng.randn(env_n), jnp.float32)
        k = jnp.int32(max(1, env_n // 3))
        v_out = vm(x, k)
        v_stats = _stats(vm)
        r_out = ref(x, k)
        r_stats = _stats(ref)
        _assert_bitwise(v_out, r_out, spec)
        assert v_stats == r_stats, f"stats diverge for {spec}: " + str({
            kk: (v_stats[kk], r_stats[kk]) for kk in v_stats
            if v_stats[kk] != r_stats.get(kk)})
        assert len(v_stats["measured_dims"]) == n_intro, spec
        # tight runtime accounting must stay under the cap-sized reserve
        bound = vm.report.arena_bound_bytes
        if bound is not None:
            assert v_stats["arena_bytes"] <= bound, (spec, env_n)
        # oracle: the eager impls compute the exact same padded values
        _assert_bitwise(v_out, f(x, k), spec)
