"""Kill-mid-serve checkpoint/restore: the elastic-restart scenario.

Drives the functions of ``examples/elastic_restart.py`` (imported from
the example file, so the documented scenario *is* the tested one):
a worker serving a deterministic request stream through the hardened
batcher loop is killed mid-serve, a fresh worker restores the latest
checkpoint, and the resumed run must be exact-once and bit-exact —
every request processed exactly once across the crash, the combined
loss sequence and the final parameters identical to an uninterrupted
run.
"""
import importlib.util
import pathlib

import jax
import numpy as np
import pytest

from repro.checkpoint import Checkpointer

_EXAMPLE = (pathlib.Path(__file__).resolve().parents[1]
            / "examples" / "elastic_restart.py")


@pytest.fixture(scope="module")
def ex():
    spec = importlib.util.spec_from_file_location("elastic_restart_example",
                                                  _EXAMPLE)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def reference(ex, tmp_path_factory):
    """Uninterrupted run over the shared stream."""
    requests = ex.request_stream(8)
    ck = Checkpointer(str(tmp_path_factory.mktemp("ref_ck")))
    params, losses = ex.serve(requests, ck, ex.init_params(),
                              ckpt_every=3)
    return requests, params, losses


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(x, y) for x, y in zip(la, lb))


class TestElasticRestart:
    def test_kill_restore_resume_is_bit_exact(self, ex, reference,
                                              tmp_path):
        requests, ref_params, ref_losses = reference
        ck = Checkpointer(str(tmp_path / "ck"))
        with pytest.raises(ex.WorkerKilled):
            ex.serve(requests, ck, ex.init_params(), ckpt_every=3,
                     kill_at=7)
        # the crash landed after the cursor-6 checkpoint
        assert ck.latest_step() == 6
        res_params, res_losses = ex.resume(requests, ck, ckpt_every=3)
        # exact-once: the resumed worker replays 6..7, nothing twice
        assert [i for i, _ in res_losses] == [6, 7]
        # bit-exact: resumed losses and final params match the
        # uninterrupted reference
        ref_by_idx = dict(ref_losses)
        for i, loss in res_losses:
            assert np.array_equal(ref_by_idx[i], loss), \
                f"request {i}: resumed loss diverged"
        assert _leaves_equal(ref_params, res_params)

    def test_kill_before_any_checkpoint_is_structured(self, ex, reference,
                                                      tmp_path):
        requests, _, _ = reference
        ck = Checkpointer(str(tmp_path / "ck"))
        with pytest.raises(ex.WorkerKilled):
            ex.serve(requests, ck, ex.init_params(), ckpt_every=3,
                     kill_at=2)
        with pytest.raises(FileNotFoundError):
            ex.resume(requests, ck)

    def test_checkpoint_cursor_roundtrip(self, ex, reference, tmp_path):
        requests, _, _ = reference
        ck = Checkpointer(str(tmp_path / "ck"))
        params, _ = ex.serve(requests[:3], ck, ex.init_params(),
                             ckpt_every=3)
        cursor, state, extra = ck.restore()
        assert cursor == 3 and extra["cursor"] == 3
        assert _leaves_equal(state["params"], params)
