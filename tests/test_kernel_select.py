"""Per-bucket kernel-variant selection: cost model + wiring contracts.

Four contract groups:

* **cost-model properties** — VMEM footprints are monotone in block size
  and pipeline depth; shrinking the VMEM budget only ever *shrinks* the
  valid variant set (the reference implementation never leaves it); an
  unbounded dim a Pallas footprint depends on rules every Pallas variant
  out.  Property-tested (hypothesis): over random shape ranges —
  unbounded corners included — the selected variant is always valid at
  the range's upper corner, so the whole-range fallback can never adopt
  a variant some in-range shape would overflow.
* **ref-vs-pallas crossovers** — the tiny-``d`` rmsnorm regression: the
  cost model sends sub-tile feature dims to the unfused reference path
  (pad/unpad copy traffic swamps the fused kernel) and tile-aligned fat
  dims to Pallas, and the eager auto-dispatch path actually routes there.
* **differential** — with selection on, the ProgramVM and the reference
  interpreter agree *bitwise* and on memory stats in every bucket, on
  the plain path, through value-dependent bounded dims, and inside
  rolled ``scan`` bodies; memory stats are identical across variant
  choices (selection changes kernel params, never the memory plan).
* **measured fallback** — ``remeasure_kernels`` wall-times the valid
  candidates, swaps the plan (bucket recompile or monolithic rebuild),
  marks the selections ``measured``, logs ``kernel-measure`` decisions,
  and only ever forces winners that stay valid over the whole target
  range.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import optimize, symbolic_dim, symbolic_dims
from repro.kernels import flash_attention, masked_select, rmsnorm
from repro.kernels.hw_model import DEFAULT_HW
from repro.kernels.ref import reference_attention, reference_rmsnorm
from repro.kernels.variants import (default_variant, node_bounds,
                                    registered_kernels, select_eager,
                                    select_variant, variant_valid,
                                    variant_vmem_bytes, variants_for)

# tiny bench-like geometry: small enough for interpret-mode Pallas
HQ, HKV, HD, D = 2, 1, 16, 64
B_RANGE, S_RANGE, EDGES = (1, 4), (1, 512), [64]
SMALL_ENV, LARGE_ENV = (2, 16), (1, 128)


def _fwd(impl=None):
    def fwd(q, k, v, x, scale):
        o = flash_attention(q, k, v, causal=True, impl=impl)
        h = rmsnorm(x, scale, impl=impl)
        return o, h
    return fwd


def _specs():
    B, S = symbolic_dims("b, s")
    f32 = jnp.float32
    return (jax.ShapeDtypeStruct((B, HQ, S, HD), f32),
            jax.ShapeDtypeStruct((B, HKV, S, HD), f32),
            jax.ShapeDtypeStruct((B, HKV, S, HD), f32),
            jax.ShapeDtypeStruct((B, S, D), f32),
            jax.ShapeDtypeStruct((D,), f32))


def _args(b, s, seed=0):
    rng = np.random.default_rng(seed)
    f = lambda *sh: jnp.asarray(rng.standard_normal(sh, dtype=np.float32))
    return (f(b, HQ, s, HD), f(b, HKV, s, HD), f(b, HKV, s, HD),
            f(b, s, D), f(D,))


def _compile(executor="vm", impl=None, **kw):
    return optimize(_fwd(impl), *_specs(),
                    dynamic_dims={"b": B_RANGE, "s": S_RANGE},
                    buckets={"s": EDGES}, executor=executor, **kw)


def _stats(fn):
    d = fn.last_report.stats.as_dict()
    d.pop("last_dispatch_ns", None)
    d.pop("dispatch_ns_total", None)
    return d


def _bucket_plan(fn, env):
    table = fn.specialization_table
    return table.peek(table.key_of(env)).plan


# -- cost-model properties -----------------------------------------------------

def test_flash_vmem_monotone_in_block_size():
    hi = {"s": 4096, "t": 4096, "hd": 64}
    names = ["pallas_64x64", "pallas_128x128", "pallas_256x256",
             "pallas_512x256"]
    by_name = {v.name: v for v in variants_for("flash_attention")}
    fps = [variant_vmem_bytes("flash_attention", by_name[n], hi, 4)
           for n in names]
    assert all(a <= b for a, b in zip(fps, fps[1:])), dict(zip(names, fps))
    # halved pipelining shrinks the footprint at the same block size
    assert (variant_vmem_bytes("flash_attention",
                               by_name["pallas_128x128_d1"], hi, 4)
            < variant_vmem_bytes("flash_attention",
                                 by_name["pallas_128x128"], hi, 4))
    # the reference path is HBM-resident: zero VMEM working set
    assert variant_vmem_bytes("flash_attention", by_name["ref_dense"],
                              hi, 4) == 0


def test_rmsnorm_vmem_monotone_in_block_rows():
    hi = {"n": 1 << 16, "d": 1024}
    by_name = {v.name: v for v in variants_for("rmsnorm")}
    fps = [variant_vmem_bytes("rmsnorm", by_name[n], hi, 4)
           for n in ("pallas_r64", "pallas_r256", "pallas_r1024")]
    assert fps[0] <= fps[1] <= fps[2], fps
    assert (variant_vmem_bytes("rmsnorm", by_name["pallas_r256_d1"], hi, 4)
            < variant_vmem_bytes("rmsnorm", by_name["pallas_r256"], hi, 4))


@pytest.mark.parametrize("prim", ["flash_attention", "rmsnorm"])
def test_valid_set_shrinks_with_vmem_budget(prim):
    """A smaller VMEM budget can only remove variants, and the reference
    implementation (footprint 0) survives every budget."""
    hi = ({"s": 4096, "t": 4096, "hd": 128} if prim == "flash_attention"
          else {"n": 1 << 16, "d": 4096})
    budgets = [DEFAULT_HW.vmem_bytes, 4 << 20, 1 << 20, 256 << 10,
               32 << 10, 1]
    prev = None
    for budget in budgets:
        hw = DEFAULT_HW.with_vmem(budget)
        valid = {v.name for v in variants_for(prim)
                 if variant_valid(prim, v, hi, 4, hw)}
        ref = {v.name for v in variants_for(prim) if v.impl == "ref"}
        assert ref <= valid
        if prev is not None:
            assert valid <= prev, (budget, valid - prev)
        prev = valid


def test_unbounded_footprint_dim_rules_out_pallas():
    """A dim the Pallas footprint cannot self-bound (the head dim / the
    feature dim) being unbounded invalidates every Pallas variant; the
    selector falls back to the reference implementation."""
    bounds = {"b": (1, None), "hq": (4, 4), "s": (1, None), "t": (1, None),
              "hd": (1, None)}
    variant, _scores, _probes, invalid = select_variant(
        "flash_attention", bounds, 4, {"causal": True})
    assert variant.impl == "ref"
    assert set(invalid) == {v.name for v in variants_for("flash_attention")
                            if v.impl == "pallas"}


def _rand_bounds(prim, rng):
    def one(lo_hi, unbounded_ok=True):
        lo = int(rng.integers(1, lo_hi))
        if unbounded_ok and rng.random() < 0.25:
            return (lo, None)
        return (lo, lo + int(rng.integers(0, 8192)))
    if prim == "flash_attention":
        return {"b": one(16), "hq": one(16), "s": one(64), "t": one(64),
                "hd": one(256)}
    return {"n": one(64), "d": one(4096)}


@settings(max_examples=40, deadline=None)
@given(prim=st.sampled_from(["flash_attention", "rmsnorm"]),
       itemsize=st.sampled_from([2, 4]),
       seed=st.integers(0, 10**6))
def test_whole_range_fallback_never_selects_invalid(prim, itemsize, seed):
    """Acceptance property: over arbitrary shape ranges — unbounded
    corners included — selection succeeds and the winner's footprint fits
    VMEM at the range's upper corner, so no in-range shape can overflow
    it (footprints are monotone in every dim)."""
    bounds = _rand_bounds(prim, np.random.default_rng(seed))
    variant, scores, _probes, invalid = select_variant(
        prim, bounds, itemsize, {})
    hi = {k: h for k, (_lo, h) in bounds.items()}
    assert variant_valid(prim, variant, hi, itemsize)
    assert variant.name in scores
    for name in invalid:
        bad = next(v for v in variants_for(prim) if v.name == name)
        assert not variant_valid(prim, bad, hi, itemsize)
        assert name not in scores


# -- ref-vs-pallas crossovers (the tiny-d rmsnorm regression) ------------------

def test_rmsnorm_tiny_d_crossover_in_the_model():
    for d in (8, 16, 32):
        v = select_eager("rmsnorm", {"n": 256, "d": d}, 4, {})
        assert v.impl == "ref", (d, v.name)
    for d in (512, 2048):
        v = select_eager("rmsnorm", {"n": 256, "d": d}, 4, {})
        assert v.impl == "pallas", (d, v.name)


def test_rmsnorm_tiny_d_eager_call_routes_to_ref():
    """The no-impl eager call actually dispatches where the model points:
    bitwise equal to the explicit ref call at tiny d, to the explicit
    Pallas call at fat d."""
    rng = np.random.default_rng(3)
    x8 = jnp.asarray(rng.standard_normal((16, 8), dtype=np.float32))
    s8 = jnp.asarray(rng.standard_normal((8,), dtype=np.float32))
    auto = rmsnorm(x8, s8)
    assert np.array_equal(np.asarray(auto), np.asarray(
        rmsnorm(x8, s8, impl="ref")))

    x2k = jnp.asarray(rng.standard_normal((16, 2048), dtype=np.float32))
    s2k = jnp.asarray(rng.standard_normal((2048,), dtype=np.float32))
    auto = rmsnorm(x2k, s2k)
    assert np.array_equal(np.asarray(auto), np.asarray(
        rmsnorm(x2k, s2k, impl="pallas")))


def test_flash_small_seq_crossover_in_the_model():
    """Degenerate sequence lengths route attention to the dense reference
    path (launch overhead + on-chip score matrix), long ones to Pallas."""
    small = {"b": 2, "hq": 4, "s": 16, "t": 16, "hd": 64}
    large = {"b": 2, "hq": 4, "s": 2048, "t": 2048, "hd": 64}
    assert select_eager("flash_attention", small, 4, {}).impl == "ref"
    assert select_eager("flash_attention", large, 4, {}).impl == "pallas"


# -- differential: selection wiring, per bucket --------------------------------

def test_per_bucket_selection_and_explain():
    fn = _compile()
    fn(*_args(*SMALL_ENV))
    small = {s.prim_name: s.variant
             for s in _bucket_plan(fn, {"b": SMALL_ENV[0],
                                        "s": SMALL_ENV[1]}).kernel_selections.values()}
    fn(*_args(*LARGE_ENV))
    large = {s.prim_name: s.variant
             for s in _bucket_plan(fn, {"b": LARGE_ENV[0],
                                        "s": LARGE_ENV[1]}).kernel_selections.values()}
    # the small bucket crosses attention over to the dense reference path;
    # the large bucket stays on (bigger-block) Pallas — buckets genuinely
    # specialize kernels, not just memory plans
    assert small["flash_attention"].impl == "ref"
    assert large["flash_attention"].impl == "pallas"
    assert small["flash_attention"].name != large["flash_attention"].name
    # the whole-range fallback plan carries its own selections
    assert fn.plan.kernel_selections
    # decisions + explain surface the choices
    kinds = {d.kind for d in fn.decisions.entries()}
    assert "kernel-select" in kinds
    report = fn.explain()
    assert "kernel selection" in report
    assert small["flash_attention"].name in report
    assert large["flash_attention"].name in report


def test_vm_matches_interpreter_bitwise_per_bucket():
    fn_vm = _compile("vm")
    fn_ref = _compile("reference")
    for b, s in (SMALL_ENV, LARGE_ENV):
        args = _args(b, s, seed=b + s)
        out_vm, out_ref = fn_vm(*args), fn_ref(*args)
        for x, y in zip(jax.tree_util.tree_leaves(out_vm),
                        jax.tree_util.tree_leaves(out_ref)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (b, s)
        assert _stats(fn_vm) == _stats(fn_ref), (b, s)


def test_memory_stats_identical_across_variants():
    """Variant choice changes kernel params only — the memory plan, the
    arena, and the guaranteed bounds are byte-identical whether the node
    runs ref, default Pallas, or the selected variant."""
    fns = [_compile(impl=None, kernel_select=True),
           _compile(impl="pallas", kernel_select=False),
           _compile(impl="ref", kernel_select=False)]
    assert len({fn.guaranteed_peak_bytes for fn in fns}) == 1
    assert len({fn.arena_bound_bytes for fn in fns}) == 1
    for b, s in (SMALL_ENV, LARGE_ENV):
        stats = []
        for fn in fns:
            fn(*_args(b, s))
            stats.append(_stats(fn))
        assert stats[0] == stats[1] == stats[2], (b, s)


def test_bounded_dims_path_vm_eq_interpreter():
    """Kernels downstream of a value-dependent bounded dim still agree
    bitwise across executors (the row count is decided by input values)."""
    def f(x, mask, scale):
        y, cnt = masked_select(x, mask)
        return jnp.sum(rmsnorm(y, scale), axis=0), cnt

    s = symbolic_dim("s")
    specs = (jax.ShapeDtypeStruct((s, D), jnp.float32),
             jax.ShapeDtypeStruct((s,), jnp.bool_),
             jax.ShapeDtypeStruct((D,), jnp.float32))
    kw = dict(dynamic_dims={"s": (1, 64)})
    vm = optimize(f, *specs, executor="vm", **kw)
    ref = optimize(f, *specs, executor="reference", **kw)
    rng = np.random.RandomState(0)
    n = 24
    x = jnp.asarray(rng.randn(n, D), jnp.float32)
    scale = jnp.asarray(rng.randn(D), jnp.float32)
    for occ in (1.0, 0.5):
        mask = jnp.asarray(rng.rand(n) < occ)
        for a, b in zip(jax.tree_util.tree_leaves(vm(x, mask, scale)),
                        jax.tree_util.tree_leaves(ref(x, mask, scale))):
            assert np.array_equal(np.asarray(a), np.asarray(b)), occ
        assert _stats(vm) == _stats(ref), occ


def test_rolled_scan_body_kernels_vm_eq_interpreter():
    """A kernel inside a rolled scan body auto-selects eagerly at the
    concrete per-step shape — identically under both executors."""
    def f(xs, scale):
        def body(c, x):
            h = rmsnorm(x, scale)
            return c + h, h
        out, ys = jax.lax.scan(body, jnp.zeros((8, D), jnp.float32), xs)
        return out, ys

    t = symbolic_dim("t")
    specs = (jax.ShapeDtypeStruct((t, 8, D), jnp.float32),
             jax.ShapeDtypeStruct((D,), jnp.float32))
    kw = dict(dynamic_dims={"t": (1, 16)})
    vm = optimize(f, *specs, executor="vm", **kw)
    ref = optimize(f, *specs, executor="reference", **kw)
    rng = np.random.RandomState(1)
    for steps in (1, 5):
        xs = jnp.asarray(rng.randn(steps, 8, D), jnp.float32)
        scale = jnp.asarray(rng.randn(D), jnp.float32)
        for a, b in zip(jax.tree_util.tree_leaves(vm(xs, scale)),
                        jax.tree_util.tree_leaves(ref(xs, scale))):
            assert np.array_equal(np.asarray(a), np.asarray(b)), steps
        assert _stats(vm) == _stats(ref), steps


# -- measured fallback ---------------------------------------------------------

def _oracle(args):
    q, k, v, x, scale = args
    return (reference_attention(q, k, v, causal=True),
            reference_rmsnorm(x, scale))


def test_remeasure_swaps_monolithic_plan():
    fn = optimize(_fwd(None), *_specs(),
                  dynamic_dims={"b": (1, 2), "s": (1, 64)})
    args = _args(1, 32)
    fn(*args)
    forced = fn.remeasure_kernels(repeats=1)
    assert set(forced) == set(fn.plan.kernel_selections)
    assert all(s.measured for s in fn.plan.kernel_selections.values())
    kinds = {d.kind for d in fn.decisions.entries()}
    assert "kernel-measure" in kinds
    # the swapped plan still computes attention + rmsnorm
    out = fn(*args)
    for got, want in zip(out, _oracle(args)):
        assert np.allclose(np.asarray(got), np.asarray(want), atol=5e-2)
    assert "[measured" in fn.explain()


def test_remeasure_bucketed_recompiles_bucket_only():
    fn = _compile()
    env = {"b": SMALL_ENV[0], "s": SMALL_ENV[1]}
    args = _args(*SMALL_ENV)
    fn(*args)
    forced = fn.remeasure_kernels(repeats=1)
    bp_plan = _bucket_plan(fn, env)
    assert all(s.measured for s in bp_plan.kernel_selections.values())
    # the whole-range fallback plan keeps its model-based selections
    assert not any(s.measured for s in fn.plan.kernel_selections.values())
    # fallback safety survives measurement: every forced winner fits VMEM
    # at the bucket range's upper corner
    table = fn.specialization_table
    sg = fn.plan.shape_graph.specialized(
        table.space.ranges_of(table.key_of(env)))
    by_prim = {}
    for nid, name in forced.items():
        node = fn.plan.node_by_id[nid]
        hi = {k: h for k, (_lo, h) in node_bounds(node, sg).items()}
        variant = next(v for v in variants_for(node.prim_name)
                       if v.name == name)
        assert variant_valid(node.prim_name, variant, hi,
                             int(node.invals[0].dtype.itemsize))
        by_prim[node.prim_name] = name
    assert set(by_prim) == set(registered_kernels())
    out = fn(*args)
    for got, want in zip(out, _oracle(args)):
        assert np.allclose(np.asarray(got), np.asarray(want), atol=5e-2)


def test_kernel_remeasure_after_autotriggers_once():
    fn = _compile(kernel_remeasure_after=2)
    env = {"b": SMALL_ENV[0], "s": SMALL_ENV[1]}
    args = _args(*SMALL_ENV)
    fn(*args)
    assert not any(s.measured
                   for s in _bucket_plan(fn, env).kernel_selections.values())
    fn(*args)
    fn.drain_specializations()
    assert all(s.measured
               for s in _bucket_plan(fn, env).kernel_selections.values())
    n_measure = sum(1 for d in fn.decisions.entries()
                    if d.kind == "kernel-measure")
    # fires once per bucket, not per call
    fn(*args)
    fn.drain_specializations()
    assert sum(1 for d in fn.decisions.entries()
               if d.kind == "kernel-measure") == n_measure
