"""Interval-bounds layer: soundness, exactness, and compiler integration.

The tentpole property: for any expression and any env within the declared
dim ranges, ``lo <= expr.evaluate(env) <= hi``.  Plus: the bounds-fallback
``Cmp`` never contradicts the polynomial ``Cmp`` or concrete evaluation,
``simulate_peak_bound`` dominates every simulated peak, and the remat
layer's compile-time static decisions agree with the runtime cost model.
"""
import random

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import optimize, symbolic_dims
from repro.core.ir import trace_to_graph
from repro.core.remat.planner import build_plan
from repro.core.remat.search import (OFFLOAD_COST_PER_BYTE,
                                     RECOMPUTE_COST_PER_FLOP,
                                     RELOAD_COST_PER_BYTE, CandidateInfo,
                                     static_regen_method)
from repro.core.scheduling import (schedule_graph, simulate_peak,
                                   simulate_peak_bound)
from repro.core.symbolic import (BoundEnv, Cmp, Interval, ShapeGraph,
                                 SymbolicExpr, declare_dim_ranges,
                                 parse_range_spec)


# declared ranges used by the random-expression properties
RANGES = {"a": (1, 9), "b": (2, 12), "c": (1, 100)}


def V(n):
    return SymbolicExpr.var(n)


def random_expr(rnd: random.Random, depth: int = 0) -> SymbolicExpr:
    """A random SymbolicExpr over the RANGES vars, all ops included."""
    if depth >= 3 or rnd.random() < 0.3:
        if rnd.random() < 0.7:
            return V(rnd.choice(list(RANGES)))
        return SymbolicExpr.constant(rnd.randint(-5, 20))
    op = rnd.choice(["add", "sub", "mul", "floordiv", "mod", "max", "min"])
    x = random_expr(rnd, depth + 1)
    if op == "add":
        return x + random_expr(rnd, depth + 1)
    if op == "sub":
        return x - random_expr(rnd, depth + 1)
    if op == "mul":
        return x * random_expr(rnd, depth + 1)
    # divisor must be positive: a constant or a var (all vars are >= 1)
    d = SymbolicExpr.constant(rnd.randint(2, 7)) if rnd.random() < 0.5 \
        else V(rnd.choice(list(RANGES)))
    if op == "floordiv":
        return x.floordiv(d)
    if op == "mod":
        return x.mod(d)
    if op == "max":
        return SymbolicExpr.max_of(x, d)
    return SymbolicExpr.min_of(x, d)


def random_env(rnd: random.Random) -> dict:
    return {k: rnd.randint(lo, hi) for k, (lo, hi) in RANGES.items()}


@settings(max_examples=300, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_property_interval_soundness(seed):
    """lo <= expr.evaluate(env) <= hi for every env within declared ranges."""
    rnd = random.Random(seed)
    e = random_expr(rnd)
    lo, hi = e.bounds(RANGES)
    for _ in range(5):
        v = e.evaluate(random_env(rnd))
        assert lo is None or lo <= v, (e, lo, v)
        assert hi is None or v <= hi, (e, hi, v)


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_property_cmp_agrees_with_evaluation(seed):
    """A bounds-resolved Cmp claim holds at every env within the ranges."""
    rnd = random.Random(seed)
    e1, e2 = random_expr(rnd), random_expr(rnd)
    sg = ShapeGraph()
    declare_dim_ranges(sg, RANGES)
    c = sg.compare(e1, e2)
    for _ in range(5):
        env = random_env(rnd)
        v1, v2 = e1.evaluate(env), e2.evaluate(env)
        if c is Cmp.LT:
            assert v1 < v2
        elif c is Cmp.LE:
            assert v1 <= v2
        elif c is Cmp.EQ:
            assert v1 == v2
        elif c is Cmp.GE:
            assert v1 >= v2
        elif c is Cmp.GT:
            assert v1 > v2


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_property_bounds_fallback_never_contradicts_polynomial(seed):
    """Declaring ranges only refines UNKNOWNs; it never flips a strict
    polynomial verdict."""
    rnd = random.Random(seed)
    e1, e2 = random_expr(rnd), random_expr(rnd)
    plain, ranged = ShapeGraph(), ShapeGraph()
    declare_dim_ranges(ranged, RANGES)
    c1, c2 = plain.compare(e1, e2), ranged.compare(e1, e2)
    strict = {Cmp.LT: -1, Cmp.GT: 1, Cmp.EQ: 0}
    if c1 in strict and c2 in strict:
        # LT can refine LE-style claims but never become GT (and vice versa)
        assert strict[c1] * strict[c2] >= 0, (e1, e2, c1, c2)
    if c1 is Cmp.LT:
        assert c2 in (Cmp.LT, Cmp.LE)
    if c1 is Cmp.GT:
        assert c2 in (Cmp.GT, Cmp.GE)


class TestIntervalExactRules:
    """Brute-force exactness of the non-polynomial op rules."""

    def _check(self, op, a, b):
        vals = [op(x, y) for x in range(a.lo, a.hi + 1)
                for y in range(b.lo, b.hi + 1) if y != 0]
        return min(vals), max(vals)

    def test_floordiv_positive_denominator(self):
        for alo in (-7, 0, 3):
            a = Interval(alo, alo + 6)
            b = Interval(2, 5)
            lo, hi = self._check(lambda x, y: x // y, a, b)
            iv = a.floordiv(b)
            assert (iv.lo, iv.hi) == (lo, hi)

    def test_floordiv_unbounded_denominator(self):
        # d -> +inf: quotient tends to 0 from above for n>0, to -1 for n<0
        assert Interval(2, 5).floordiv(Interval(1, None)) == Interval(0, 5)
        assert Interval(-5, -2).floordiv(Interval(1, None)) == Interval(-5, -1)
        assert Interval(-5, 5).floordiv(Interval(3, None)) == Interval(-2, 1)
        # d -> -inf with n>0: quotient in [n//-1, -1]
        assert Interval(2, 5).floordiv(Interval(None, -1)) == Interval(-5, -1)

    def test_floordiv_default_dims_nonnegative(self):
        # the seed resolved a//b >= 0 for dims >= 1; must not regress
        g = ShapeGraph()
        e = V("a").floordiv(V("b"))
        assert g.compare(e, 0) in (Cmp.GE, Cmp.GT)

    def test_floordiv_mixed_denominator_is_sound(self):
        a, b = Interval(-4, 9), Interval(-3, 3)
        iv = a.floordiv(b)
        lo, hi = self._check(lambda x, y: x // y, a, b)
        assert iv.lo <= lo and hi <= iv.hi

    def test_mod_constant_denominator_residue_window(self):
        # numerator within one residue window -> exact [5%4, 6%4]
        assert Interval(5, 6).mod(Interval(4, 4)) == Interval(1, 2)
        # window wraps -> falls back to [0, d-1]
        assert Interval(3, 6).mod(Interval(4, 4)) == Interval(0, 3)

    def test_mod_is_sound(self):
        for dlo, dhi in ((1, 5), (2, 2), (-5, -2)):
            a, b = Interval(-9, 9), Interval(dlo, dhi)
            lo, hi = self._check(lambda x, y: x % y, a, b)
            iv = a.mod(b)
            assert iv.lo <= lo and hi <= iv.hi

    def test_max_min(self):
        a, b = Interval(1, 10), Interval(4, 6)
        assert a.max_(b) == Interval(4, 10)
        assert a.min_(b) == Interval(1, 6)
        assert Interval(1, None).max_(Interval(5, 9)) == Interval(5, None)
        assert Interval(1, None).min_(Interval(5, 9)) == Interval(1, 9)

    def test_mul_corners_with_negatives(self):
        a, b = Interval(-3, 4), Interval(-5, 2)
        vals = [x * y for x in range(-3, 5) for y in range(-5, 3)]
        assert (a * b) == Interval(min(vals), max(vals))

    def test_unbounded_sides(self):
        assert (Interval(1, None) + Interval(2, 3)) == Interval(3, None)
        assert (-Interval(1, None)) == Interval(None, -1)
        assert (Interval(0, None) * Interval(2, 4)) == Interval(0, None)

    def test_power_even_tightens_at_zero(self):
        assert Interval(-3, 2).power(2) == Interval(0, 9)
        assert Interval(-3, 2).power(3) == Interval(-27, 8)


class TestRangeSpecs:
    def test_parse_forms(self):
        assert parse_range_spec((2, 8)) == (2, 8)
        assert parse_range_spec((None, 8)) == (None, 8)
        assert parse_range_spec(25) == (1, 25)          # torch_xla-style <=25
        assert parse_range_spec("<=4096") == (1, 4096)
        assert parse_range_spec(">=16") == (16, None)
        assert parse_range_spec("16..4096") == (16, 4096)
        assert parse_range_spec("..128") == (None, 128)
        assert parse_range_spec("8..") == (8, None)

    def test_bad_specs_raise(self):
        with pytest.raises(ValueError):
            parse_range_spec("whatever")
        with pytest.raises((TypeError, ValueError)):
            parse_range_spec(object())

    def test_declare_on_shape_graph(self):
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"s": "<=4096", "b": (1, 64)})
        assert sg.declared_ranges["s"] == Interval(1, 4096)
        assert sg.compare(V("s"), 5000) is Cmp.LT
        # range + equality compose: S0 = 12*S1, S1 <= 10 -> S0 <= 120
        sg.add_equality("S0", 12 * V("S1"))
        declare_dim_ranges(sg, {"S1": (1, 10)})
        assert sg.compare(V("S0"), 121) is Cmp.LT

    def test_bound_env_defaults(self):
        env = BoundEnv({"a": (2, 5)})
        assert env.lookup("a") == Interval(2, 5)
        assert env.lookup("zzz") == Interval(1, None)  # dims >= 1 by default


# -- compiler integration -----------------------------------------------------

B, S = symbolic_dims("pb, ps")
D, F = 16, 48


def _step(w1, w2, x):
    def loss(w1, w2, x):
        h = jax.nn.gelu(x @ w1)
        return ((h @ w2) ** 2).mean()
    l, g = jax.value_and_grad(loss, argnums=(0, 1))(w1, w2, x)
    return l, g


def _specs():
    return (jax.ShapeDtypeStruct((D, F), jnp.float32),
            jax.ShapeDtypeStruct((F, D), jnp.float32),
            jax.ShapeDtypeStruct((B, S, D), jnp.float32))


class TestPeakBound:
    def test_bound_dominates_all_envs_in_range(self):
        g, _ = trace_to_graph(_step, *_specs())
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"pb": (1, 6), "ps": (4, 64)})
        res = schedule_graph(g, sg)
        lo, hi = simulate_peak_bound(g, res.order, sg)
        assert hi is not None and lo is not None and 0 < lo <= hi
        worst = 0
        for b in (1, 3, 6):
            for s in (4, 33, 64):
                tl = simulate_peak(g, res.order, {"pb": b, "ps": s})
                assert tl.peak_bytes <= hi
                worst = max(worst, tl.peak_bytes)
        assert lo <= worst  # the lower bound is achievable-or-below

    def test_unbounded_dim_gives_no_upper_bound(self):
        g, _ = trace_to_graph(_step, *_specs())
        sg = ShapeGraph()  # no ranges declared
        _, hi = simulate_peak_bound(g, g.nodes, sg)
        assert hi is None

    def test_simulate_peak_attaches_bound(self):
        g, _ = trace_to_graph(_step, *_specs())
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"pb": 6, "ps": 64})
        tl = simulate_peak(g, g.nodes, {"pb": 2, "ps": 16}, shape_graph=sg)
        assert tl.peak_bound_bytes is not None
        assert tl.peak_bytes <= tl.peak_bound_bytes

    def test_optimize_reports_guaranteed_peak(self):
        opt = optimize(_step, *_specs(), dynamic_dims={"pb": (1, 6),
                                                       "ps": "<=64"})
        assert opt.guaranteed_peak_bytes is not None
        import numpy as np
        rng = np.random.RandomState(0)
        w1 = jnp.asarray(rng.randn(D, F) * 0.1, jnp.float32)
        w2 = jnp.asarray(rng.randn(F, D) * 0.1, jnp.float32)
        for (b, s) in [(1, 4), (6, 64), (2, 40)]:
            x = jnp.asarray(rng.randn(b, s, D), jnp.float32)
            opt(w1, w2, x)
            assert opt.last_report.stats.device_peak <= \
                opt.guaranteed_peak_bytes

    def test_declared_ranges_are_enforced(self):
        # unknown dim names rejected at compile time
        with pytest.raises(ValueError, match="not symbolic dims"):
            optimize(_step, *_specs(), dynamic_dims={"typo": (1, 4)})
        # out-of-range concrete dims rejected at run time
        opt = optimize(_step, *_specs(), dynamic_dims={"pb": (1, 2),
                                                       "ps": (1, 16)})
        import numpy as np
        x = jnp.asarray(np.zeros((4, 8, D)), jnp.float32)  # pb=4 > 2
        w1 = jnp.zeros((D, F), jnp.float32)
        w2 = jnp.zeros((F, D), jnp.float32)
        with pytest.raises(ValueError, match="outside its declared range"):
            opt(w1, w2, x)


class TestSchedulerWithBounds:
    def test_declared_ranges_do_not_reduce_symbolic_fraction(self):
        g, _ = trace_to_graph(_step, *_specs())
        plain = schedule_graph(g, ShapeGraph())
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"pb": (1, 6), "ps": (4, 64)})
        ranged = schedule_graph(g, sg)
        assert ranged.decision_symbolic_fraction >= \
            plain.decision_symbolic_fraction
        g.validate_order(ranged.order)

    def test_interval_resolves_cross_symbol_comparison(self):
        """The worked example from docs/architecture.md: incomparable
        polynomials become ordered once ranges are declared."""
        plain, ranged = ShapeGraph(), ShapeGraph()
        declare_dim_ranges(ranged, {"b": (1, 64), "s": (16, 4096)})
        lhs = 64 * V("b")                 # one op's memory impact
        rhs = 4096 * V("b") * V("s")      # the other's
        assert plain.compare(lhs, rhs) is Cmp.UNKNOWN
        assert ranged.compare(lhs, rhs) is Cmp.LT


class TestStaticRegen:
    def _cand(self, flops_iv, bytes_iv):
        # value/recompute contents are irrelevant to the decision
        from repro.core.remat.search import RecomputePlan
        from repro.core.symbolic import ZERO
        plan = RecomputePlan(target=None, node_ids=(), source_ids=(),
                             impact=ZERO, flops=ZERO,
                             flops_interval=flops_iv)
        return CandidateInfo(value=None, recompute=plan,
                             bytes_interval=bytes_iv)

    def test_cheap_recompute_fixed_statically(self):
        per_byte = RELOAD_COST_PER_BYTE + OFFLOAD_COST_PER_BYTE
        # worst-case recompute cost below best-case transfer cost
        flops_hi = int(1000 * per_byte / RECOMPUTE_COST_PER_FLOP) - 1
        cand = self._cand(Interval(1, flops_hi), Interval(1000, 2000))
        assert static_regen_method(cand) == "recompute"

    def test_expensive_recompute_fixed_statically(self):
        per_byte = RELOAD_COST_PER_BYTE + OFFLOAD_COST_PER_BYTE
        flops_lo = int(2000 * per_byte / RECOMPUTE_COST_PER_FLOP) + 1
        cand = self._cand(Interval(flops_lo, None), Interval(1000, 2000))
        assert static_regen_method(cand) == "offload"

    def test_overlapping_costs_stay_dynamic(self):
        cand = self._cand(Interval(1, None), Interval(1000, None))
        assert static_regen_method(cand) is None

    def test_no_recompute_plan_is_offload(self):
        cand = CandidateInfo(value=None, recompute=None,
                             bytes_interval=Interval(1, 10))
        assert static_regen_method(cand) == "offload"

    def test_plan_records_static_decisions(self):
        g, _ = trace_to_graph(_step, *_specs())
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"pb": (1, 6), "ps": (4, 64)})
        res = schedule_graph(g, sg)
        plan = build_plan(g, res, sg)
        assert plan.n_static_regen >= 0
        for vid, m in plan.static_methods.items():
            assert m in ("recompute", "offload")
            assert vid in plan.candidates
