"""IR tracing, scheduling, remat search, and the runtime interpreter."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import optimize, symbolic_dims
from repro.core.executor.memory import MemoryLimitExceeded
from repro.core.ir import solve_env, trace_to_graph
from repro.core.remat.search import RecomputeSearcher
from repro.core.scheduling import schedule_graph, simulate_peak
from repro.core.symbolic import Cmp, ShapeGraph, SymbolicExpr


B, S = symbolic_dims("b, s")
V, D, F = 300, 32, 64


def loss_fn(params, tokens, labels):
    emb = params["emb"][tokens]
    h = jax.nn.gelu(emb @ params["w1"])
    h2 = h @ params["w2"]
    logits = h2 @ params["emb"].T
    logp = jax.nn.log_softmax(logits)
    oh = jax.nn.one_hot(labels, logits.shape[-1])
    return -(oh * logp).sum() / (1.0 * tokens.shape[0] * tokens.shape[1])


def train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    return loss, jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)


def specs():
    p = {"emb": jax.ShapeDtypeStruct((V, D), jnp.float32),
         "w1": jax.ShapeDtypeStruct((D, F), jnp.float32),
         "w2": jax.ShapeDtypeStruct((F, D), jnp.float32)}
    t = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return p, t, t


def concrete_params(seed=0):
    rng = np.random.RandomState(seed)
    return {"emb": jnp.asarray(rng.randn(V, D), jnp.float32),
            "w1": jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
            "w2": jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)}


class TestTracing:
    def test_graph_wellformed(self):
        g, _ = trace_to_graph(train_step, *specs())
        g.validate_order(g.nodes)
        assert g.free_symbols() == frozenset({"b", "s"})
        assert len(g.nodes) > 30

    def test_solve_env(self):
        g, _ = trace_to_graph(train_step, *specs())
        flat = [np.zeros((V, D), np.float32), np.zeros((D, F), np.float32),
                np.zeros((F, D), np.float32), np.zeros((3, 17), np.int32),
                np.zeros((3, 17), np.int32)]
        assert solve_env(g, flat) == {"b": 3, "s": 17}

    def test_solve_env_inconsistent(self):
        g, _ = trace_to_graph(train_step, *specs())
        flat = [np.zeros((V, D), np.float32), np.zeros((D, F), np.float32),
                np.zeros((F, D), np.float32), np.zeros((3, 17), np.int32),
                np.zeros((4, 17), np.int32)]
        with pytest.raises(AssertionError):
            solve_env(g, flat)


class TestScheduler:
    def test_valid_topo_order(self):
        g, _ = trace_to_graph(train_step, *specs())
        res = schedule_graph(g, ShapeGraph())
        g.validate_order(res.order)  # raises on violation

    def test_symbolic_decisions_dominate(self):
        g, _ = trace_to_graph(train_step, *specs())
        res = schedule_graph(g, ShapeGraph())
        assert res.decision_symbolic_fraction > 0.3

    def test_memsim_consistent_across_envs(self):
        g, _ = trace_to_graph(train_step, *specs())
        res = schedule_graph(g, ShapeGraph())
        for env in ({"b": 2, "s": 16}, {"b": 8, "s": 200}):
            tl = simulate_peak(g, res.order, env)
            assert tl.peak_bytes > tl.base_bytes > 0


class TestRematSearch:
    def test_paper_listing1_impacts(self):
        """Reproduce the paper's §2.3 walkthrough: for %4 = reduce(dot(
        reshape(arg0), arg1)), subgraph impacts are -11007·S1, -11·S1,
        +1·S1 and the full subgraph is chosen."""
        s1, = symbolic_dims("s1")

        def fn(arg0, arg1):
            x2 = arg0.reshape(-1, 12)            # (S1, 12)
            x3 = x2 @ arg1                        # (S1, 11008)
            x4 = x3.sum(axis=1)                   # (S1,)
            return (x4 * 2.0, x4 + 1.0)           # two later consumers

        a0 = jax.ShapeDtypeStruct((12 * s1,), jnp.float32)  # @S0 = 12*@S1
        a1 = jax.ShapeDtypeStruct((12, 11008), jnp.float32)
        g, _ = trace_to_graph(fn, a0, a1)
        sg = ShapeGraph()
        searcher = RecomputeSearcher(g, sg)
        # find the reduce node's output (%4)
        red = [n for n in g.nodes if n.prim_name == "reduce_sum"][0]
        target = red.outvals[0]
        plan = searcher.search(target)
        assert plan is not None, "beneficial recompute subgraph must be found"
        # paper walkthrough: impact = +1*S1 elements (+4*S1 bytes for f32)
        assert sg.compare(plan.impact, 0) is Cmp.GT
        assert plan.impact == 4 * SymbolicExpr.var("s1")
        # the chosen subgraph includes reshape+dot+reduce (3 nodes)
        assert len(plan.node_ids) == 3

    def test_candidates_found(self):
        g, _ = trace_to_graph(train_step, *specs())
        res = schedule_graph(g, ShapeGraph())
        cands = RecomputeSearcher(g, ShapeGraph()).explore(res.order)
        assert len(cands) > 10
        assert any(c.recompute is not None for c in cands.values())


class TestInterpreterEndToEnd:
    def test_numerics_multiple_shapes(self):
        opt = optimize(train_step, *specs())
        params = concrete_params()
        rng = np.random.RandomState(1)
        for (b, s) in [(2, 9), (5, 33), (1, 64)]:
            t = jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)
            l = jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)
            loss1, p1 = opt(params, t, l)
            loss2, p2 = train_step(params, t, l)
            assert np.allclose(loss1, loss2, rtol=1e-5)
            for k in params:
                assert np.allclose(p1[k], p2[k], rtol=1e-4, atol=1e-6)

    def test_memory_limit_respected_with_identical_numerics(self):
        opt = optimize(train_step, *specs())
        params = concrete_params()
        rng = np.random.RandomState(2)
        t = jnp.asarray(rng.randint(0, V, (6, 50)), jnp.int32)
        l = jnp.asarray(rng.randint(0, V, (6, 50)), jnp.int32)
        opt(params, t, l)
        free_peak = opt.last_report.stats.device_peak
        ref_loss, ref_p = train_step(params, t, l)
        for frac in (0.8, 0.65, 0.55):
            limited = opt.with_memory_limit(int(free_peak * frac))
            loss, p = limited(params, t, l)
            st_ = limited.last_report.stats
            assert st_.device_peak <= int(free_peak * frac)
            assert st_.evictions > 0
            assert np.allclose(loss, ref_loss, rtol=1e-5)
            for k in params:
                assert np.allclose(p[k], ref_p[k], rtol=1e-4, atol=1e-6)

    def test_impossible_limit_raises(self):
        opt = optimize(train_step, *specs(), memory_limit=1000)
        params = concrete_params()
        t = jnp.zeros((2, 8), jnp.int32)
        with pytest.raises(MemoryLimitExceeded):
            opt(params, t, t)

    def test_offload_path_used_when_recompute_disabled(self):
        """With recompute plans disabled, eviction falls back to host
        offload (reload is always available — paper §2.3)."""
        opt = optimize(train_step, *specs(), max_subgraph=1)
        params = concrete_params()
        rng = np.random.RandomState(3)
        t = jnp.asarray(rng.randint(0, V, (6, 50)), jnp.int32)
        opt(params, t, t)
        peak = opt.last_report.stats.device_peak
        limited = opt.with_memory_limit(int(peak * 0.6))
        loss, _ = limited(params, t, t)
        st_ = limited.last_report.stats
        assert st_.offloads > 0 and st_.reloads > 0
        ref, _ = train_step(params, t, t)
        assert np.allclose(loss, ref, rtol=1e-5)

    def test_scheduling_flag_off(self):
        opt = optimize(train_step, *specs(), enable_scheduling=False,
                       enable_remat=False)
        params = concrete_params()
        t = jnp.zeros((2, 8), jnp.int32)
        loss, _ = opt(params, t, t)
        ref, _ = train_step(params, t, t)
        assert np.allclose(loss, ref, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(4, 48))
def test_property_any_shape_one_trace(b, s):
    """One symbolic trace serves every concrete shape (no retrace)."""
    opt = test_property_any_shape_one_trace._opt
    params = test_property_any_shape_one_trace._params
    rng = np.random.RandomState(b * 100 + s)
    t = jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)
    l = jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)
    loss1, _ = opt(params, t, l)
    loss2, _ = train_step(params, t, l)
    assert np.allclose(loss1, loss2, rtol=1e-5)


test_property_any_shape_one_trace._opt = optimize(train_step, *specs())
test_property_any_shape_one_trace._params = concrete_params()
