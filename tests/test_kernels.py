"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention, rmsnorm
from repro.kernels.ref import reference_attention, reference_rmsnorm

RNG = np.random.RandomState(0)


def _tol(dt):
    return 5e-2 if dt == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("b,hq,hkv,s,hd", [
    (2, 4, 2, 256, 64),    # GQA
    (1, 8, 1, 128, 128),   # MQA, MXU-aligned head
    (2, 4, 4, 100, 64),    # MHA, ragged seq (padding path)
    (1, 6, 2, 384, 32),    # narrow head
    (3, 2, 1, 64, 64),     # small batch of rows
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(b, hq, hkv, s, hd, dtype):
    q = jnp.asarray(RNG.randn(b, hq, s, hd), dtype)
    k = jnp.asarray(RNG.randn(b, hkv, s, hd), dtype)
    v = jnp.asarray(RNG.randn(b, hkv, s, hd), dtype)
    o = flash_attention(q, k, v, causal=True, interpret=True)
    r = reference_attention(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    assert err < _tol(dtype), err


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.randn(1, 2, 128, 64), jnp.float32)
    k = jnp.asarray(RNG.randn(1, 2, 128, 64), jnp.float32)
    v = jnp.asarray(RNG.randn(1, 2, 128, 64), jnp.float32)
    o = flash_attention(q, k, v, causal=False, interpret=True)
    r = reference_attention(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(o - r))) < 2e-4


def test_flash_attention_block_shape_sweep():
    q = jnp.asarray(RNG.randn(1, 2, 256, 64), jnp.float32)
    k = jnp.asarray(RNG.randn(1, 2, 256, 64), jnp.float32)
    v = jnp.asarray(RNG.randn(1, 2, 256, 64), jnp.float32)
    r = reference_attention(q, k, v, causal=True)
    for bq, bkv in [(64, 64), (128, 64), (64, 128), (128, 128)]:
        o = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bkv,
                            interpret=True)
        assert float(jnp.max(jnp.abs(o - r))) < 2e-4, (bq, bkv)


@pytest.mark.parametrize("n,d", [(64, 256), (100, 300), (32, 2048), (7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_reference(n, d, dtype):
    x = jnp.asarray(RNG.randn(n, d), dtype)
    s = jnp.asarray(RNG.randn(d) * 0.1, dtype)
    o = rmsnorm(x, s, interpret=True)
    r = reference_rmsnorm(x, s)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    assert err < _tol(dtype), err


def test_rmsnorm_3d_input():
    x = jnp.asarray(RNG.randn(2, 33, 160), jnp.float32)
    s = jnp.asarray(RNG.randn(160) * 0.1, jnp.float32)
    o = rmsnorm(x, s, interpret=True)
    r = reference_rmsnorm(x, s)
    assert o.shape == x.shape
    assert float(jnp.max(jnp.abs(o - r))) < 1e-4
