"""Observability contracts: tracing, telemetry, timelines, exports.

Four contract families over :mod:`repro.core.obs`:

  * **tracing** — ``optimize`` records a span forest (phases nested under
    their parent, attributes attached) and a decision log; the
    Chrome-trace export is valid Trace Event JSON with children inside
    their parent's time window;
  * **telemetry** — the ring is an exact bounded FIFO (property test over
    capacity x push-count), per-call records carry the stats split
    (``last_dispatch_ns`` per call, ``dispatch_ns_total`` cumulative),
    and the *disabled* hot path allocates nothing from obs code — the
    structural form of the <=2% overhead contract (its wall-clock form
    lives in ``benchmarks/obs_bench.py``);
  * **timelines** — the replayed per-instruction occupancy agrees with
    the compile-time plan: actual arena under the guaranteed bound, zero
    unexplained allocations, device peak exactly the plan's prediction —
    including across a rolled ``lax.scan`` loop;
  * **serve surfaces** — admission control emits structured events and
    the Prometheus export renders well-formed metric families.
"""
import json
import os
import threading
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.core.obs as obs_pkg
from repro.core import optimize, symbolic_dim, symbolic_dims
from repro.core.obs import (CallRecord, DecisionLog, NullTracer,
                            TelemetryRing, Tracer, chrome_trace,
                            chrome_trace_json, prometheus_text)
from repro.launch.serve import BucketBatcher


# -- shared compiled functions (compile once per module) -----------------------

@pytest.fixture(scope="module")
def chain_fn():
    n, = symbolic_dims("n")

    def chain(x):
        for _ in range(8):
            x = jnp.tanh(x * 1.5 + 0.25)
        return x.sum()

    return optimize(chain, jax.ShapeDtypeStruct((n, 4), jnp.float32),
                    dynamic_dims={"n": (2, 256)})


@pytest.fixture(scope="module")
def bucketed_fn():
    b, = symbolic_dims("b")

    def f(w, x):
        h = jnp.tanh(x @ w)
        return (h * h).sum()

    return optimize(f,
                    jax.ShapeDtypeStruct((8, 8), jnp.float32),
                    jax.ShapeDtypeStruct((b, 8), jnp.float32),
                    dynamic_dims={"b": (1, 512)},
                    buckets={"b": [8, 64, 512]})


@pytest.fixture(scope="module")
def loop_fn():
    t = symbolic_dim("t")

    def f(h0, xs):
        c0 = jnp.tanh(h0)
        cN, ys = jax.lax.scan(lambda c, x: (jnp.tanh(c + x), c.sum()),
                              c0, xs)
        return cN.sum() + ys.sum()

    return optimize(f,
                    jax.ShapeDtypeStruct((4,), jnp.float32),
                    jax.ShapeDtypeStruct((t, 4), jnp.float32),
                    dynamic_dims={"t": (1, 64)})


# -- tracing -------------------------------------------------------------------

class TestTracer:
    def test_nesting_and_attrs(self):
        tr = Tracer()
        with tr.span("outer", kind="test") as o:
            with tr.span("inner") as i:
                i.attrs["n"] = 3
            o.attrs["done"] = True
        assert [r.name for r in tr.roots] == ["outer"]
        assert [s.name for s in tr.spans()] == ["outer", "inner"]
        outer = tr.roots[0]
        assert outer.attrs == {"kind": "test", "done": True}
        assert [c.name for c in outer.children] == ["inner"]
        inner = outer.children[0]
        assert inner.attrs["n"] == 3
        # children close inside their parent's window
        assert outer.t0_ns <= inner.t0_ns <= inner.t1_ns <= outer.t1_ns
        assert tr.find("inner") == [inner]

    def test_thread_spans_are_separate_roots(self):
        tr = Tracer()

        def work():
            with tr.span("bg"):
                pass

        th = threading.Thread(target=work, name="specialize_0")
        with tr.span("fg"):
            th.start()
            th.join()
        names = {s.name for s in tr.spans()}
        assert names == {"fg", "bg"}
        bg, = tr.find("bg")
        assert bg.thread_name == "specialize_0"

    def test_null_tracer_absorbs(self):
        tr = NullTracer()
        with tr.span("x", a=1) as sp:
            sp.attrs["b"] = 2          # must not raise
        assert tr.spans() == []

    def test_optimize_records_phases(self, chain_fn):
        names = [s.name for s in chain_fn.trace.spans()]
        assert "trace" in names
        # find() searches the whole span forest, nested or not
        for phase in ("schedule", "remat", "memplan", "lower"):
            assert chain_fn.trace.find(phase), phase
        mem = chain_fn.trace.find("memplan")[0]
        assert mem.attrs["n_slots"] >= 1
        assert mem.duration_ns >= 0

    def test_decision_log_records_slot_pack(self, chain_fn):
        kinds = {d.kind for d in chain_fn.decisions.entries()}
        assert "slot-pack" in kinds
        packs = chain_fn.decisions.entries(kind="slot-pack")
        assert all(d.kind == "slot-pack" for d in packs)

    def test_bucketed_compile_spans_and_decisions(self, bucketed_fn):
        bucketed_fn(np.ones((8, 8), np.float32),
                    np.ones((4, 8), np.float32))
        spans = [s for s in _walk_all(bucketed_fn.trace)
                 if s.name == "specialize"]
        assert spans, "bucket compile recorded no specialize span"
        assert any("bucket" in s.attrs for s in spans)


def _walk_all(tracer):
    out = []
    for r in tracer.spans():
        out.extend(r.walk())
    return out


class TestChromeTrace:
    def test_export_is_valid_and_nested(self, chain_fn):
        text = chrome_trace_json(chain_fn.trace)
        data = json.loads(text)
        events = data["traceEvents"]
        spans = [e for e in events if e["ph"] == "X"]
        assert spans
        for e in spans:
            assert e["dur"] >= 0
            assert isinstance(e["name"], str)
            for v in e["args"].values():   # JSON-safe attrs only
                assert isinstance(v, (int, float, str, bool, type(None)))

    def test_counter_events_from_timelines(self, chain_fn):
        diff = chain_fn.memory_timeline({"n": 8})
        data = chrome_trace(chain_fn.trace, timelines=[(0, diff.actual)])
        counters = [e for e in data["traceEvents"] if e["ph"] == "C"]
        assert len(counters) == len(diff.actual.points)
        assert counters[0]["args"]["device_used"] >= 0


# -- telemetry -----------------------------------------------------------------

def _rec(seq):
    return CallRecord(seq=seq, bucket_key=None, env=(("n", 8),),
                      wall_s=0.0, dispatch_ns=0, device_peak=0,
                      arena_bytes=0, evictions=0, recomputes=0,
                      reloads=0, donated_reuses=0, loop_trips=())


# module-level: the conftest hypothesis shim drives @given tests without
# pytest fixtures, so property tests cannot take self
@settings(max_examples=40, deadline=None)
@given(cap=st.integers(1, 8), n=st.integers(0, 30))
def test_ring_is_exact_bounded_fifo(cap, n):
    ring = TelemetryRing(cap)
    for i in range(n):
        ring.push(_rec(i))
    recs = ring.records()
    assert len(ring) == min(n, cap)
    assert ring.total_pushed == n
    assert ring.dropped == max(0, n - cap)
    # exactly the newest min(n, cap) records, oldest first
    assert [r.seq for r in recs] == list(range(max(0, n - cap), n))


def test_ring_rejects_zero_capacity():
    with pytest.raises(ValueError):
        TelemetryRing(0)


class TestTelemetry:
    def test_enable_record_disable(self, bucketed_fn):
        w = np.ones((8, 8), np.float32)
        tel = bucketed_fn.enable_telemetry(capacity=4,
                                           sample_timeline_every=2)
        try:
            for b in (2, 2, 30):
                bucketed_fn(w, np.ones((b, 8), np.float32))
            assert tel.n_calls == 3
            recs = tel.ring.records()
            assert [r.seq for r in recs] == [0, 1, 2]
            assert recs[0].env == (("b", 2),)
            assert recs[2].env == (("b", 30),)
            assert recs[0].bucket_key is not None
            assert recs[0].bucket_key == recs[1].bucket_key
            assert recs[2].bucket_key != recs[0].bucket_key
            # every-2nd-call sampling: calls 0 and 2
            assert [seq for seq, _tl in tel.timelines] == [0, 2]
            assert tel.summary()["n_calls"] == 3
        finally:
            got = bucketed_fn.disable_telemetry()
        assert got is tel
        assert bucketed_fn.telemetry is None

    def test_stats_split_semantics(self, bucketed_fn):
        w = np.ones((8, 8), np.float32)
        x = np.ones((2, 8), np.float32)
        bucketed_fn(w, x)
        st1 = bucketed_fn.last_report.stats
        total1 = st1.dispatch_ns_total
        assert st1.last_dispatch_ns > 0
        assert total1 >= st1.last_dispatch_ns
        bucketed_fn(w, x)
        st2 = bucketed_fn.last_report.stats
        assert st2.dispatch_ns_total >= total1 + st2.last_dispatch_ns
        d = st2.as_dict()
        assert "last_dispatch_ns" in d and "dispatch_ns_total" in d
        assert "dispatch_ns" not in d

    def test_loop_trips_recorded(self, loop_fn):
        tel = loop_fn.enable_telemetry()
        try:
            loop_fn(np.ones(4, np.float32), np.ones((5, 4), np.float32))
            recs = tel.ring.records()
            assert recs[-1].loop_trips == (5,)
        finally:
            loop_fn.disable_telemetry()

    def test_disabled_path_allocates_nothing_from_obs(self, chain_fn):
        """The structural <=2% contract: with telemetry off, a call
        touches no obs code at all (one attribute test, no allocation)."""
        obs_dir = os.path.dirname(obs_pkg.__file__)
        x = np.ones((4, 4), np.float32)
        chain_fn(x)                               # warm every cache
        flt = tracemalloc.Filter(True, os.path.join(obs_dir, "*"))
        tracemalloc.start(5)
        try:
            before = tracemalloc.take_snapshot().filter_traces([flt])
            for _ in range(5):
                chain_fn(x)
            after = tracemalloc.take_snapshot().filter_traces([flt])
        finally:
            tracemalloc.stop()
        diff = after.compare_to(before, "lineno")
        grew = [d for d in diff if d.size_diff > 0]
        assert not grew, f"obs code allocated on the disabled path: {grew}"


# -- timelines -----------------------------------------------------------------

class TestTimeline:
    def test_plan_vs_actual_agree(self, chain_fn):
        for n in (2, 32, 256):
            diff = chain_fn.memory_timeline({"n": n})
            assert diff.ok, diff.summary()
            assert diff.unexplained == []
            assert diff.within_bound
            # the fast stream's traffic is fully determined by the env,
            # so the replayed peak must hit the plan's prediction exactly
            assert diff.actual.peak_device == diff.predicted_peak_device
            assert len(diff.actual.points) > 0

    def test_loop_timeline_audits_clean(self, loop_fn):
        for t in (1, 5, 64):
            diff = loop_fn.memory_timeline({"t": t})
            assert diff.ok, diff.summary()
            assert diff.unexplained == []
            opnames = {p.opname for p in diff.actual.points}
            assert "Loop" in opnames

    def test_bounded_dims_timeline_stays_ok(self):
        """With value-dependent bounded ops in the graph the plan-vs-actual
        diff still audits clean: the replay completes missing bound dims to
        their caps, every allocation is explained by a planned liveness
        interval, and a measured env (from a real call) reconstructs that
        call's tight curve — still within the cap-sized reserve."""
        from repro.kernels import masked_select
        s = symbolic_dim("s")

        def f(x, mask):
            y, cnt = masked_select(jnp.tanh(x), mask)
            return (y * y).sum(), cnt

        fn = optimize(f, jax.ShapeDtypeStruct((s, 4), jnp.float32),
                      jax.ShapeDtypeStruct((s,), jnp.bool_),
                      dynamic_dims={"s": (1, 64)})
        for s_val in (2, 16, 64):
            diff = fn.memory_timeline({"s": s_val})
            assert diff.ok, diff.summary()
            assert diff.unexplained == []
            assert "BindDim" in {p.opname for p in diff.actual.points}
        # a real call's measured env replays the tight curve
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(16, 4), jnp.float32)
        fn(x, jnp.asarray(rng.rand(16) < 0.5))
        rep = fn.last_report
        assert rep.stats.measured_dims
        tight = fn.memory_timeline(rep.env)
        assert tight.ok, tight.summary()
        assert tight.actual.peak_device == rep.stats.device_peak
        cap = fn.memory_timeline({"s": 16})
        assert tight.actual.peak_device < cap.actual.peak_device
        # explain() reports reserved-cap vs measured-size per bounded slot
        text = fn.explain(env=rep.env)
        assert "value-dependent bounded dims" in text
        assert "measured" in text and "reserved" in text

    def test_bucketed_timeline_uses_resident_bucket(self, bucketed_fn):
        w = np.ones((8, 8), np.float32)
        bucketed_fn(w, np.ones((4, 8), np.float32))
        diff = bucketed_fn.memory_timeline({"b": 4})
        assert diff.ok, diff.summary()
        # the bucket plan's bound (b<=8), far below the whole range's
        assert diff.arena_bound_bytes is not None
        mono = bucketed_fn.arena_bound_bytes
        assert diff.arena_bound_bytes <= mono

    def test_reference_executor_has_no_timeline(self):
        n, = symbolic_dims("n")
        fn = optimize(lambda x: (x * x).sum(),
                      jax.ShapeDtypeStruct((n,), jnp.float32),
                      dynamic_dims={"n": (2, 16)}, executor="reference")
        with pytest.raises(ValueError):
            fn.memory_timeline({"n": 4})


# -- explain + serve surfaces --------------------------------------------------

class TestExplain:
    def test_report_sections(self, bucketed_fn):
        w = np.ones((8, 8), np.float32)
        bucketed_fn(w, np.ones((4, 8), np.float32))
        text = bucketed_fn.explain(env={"b": 4})
        for needle in ("compile phases", "decisions", "arena slots",
                       "rematerialization", "bucket dispatch",
                       "plan vs actual", "verdict: OK"):
            assert needle in text, f"explain() lacks {needle!r}"

    def test_explain_without_env(self, chain_fn):
        text = chain_fn.explain()
        assert "compile phases" in text
        assert "plan vs actual" not in text


class TestServeSurfaces:
    def test_admission_events(self, bucketed_fn):
        bat = BucketBatcher(bucketed_fn, memory_budget=1)
        bat.submit({"b": 2})
        bat.submit({"b": 2})
        bat.submit({"b": 100})
        assert bat.drain() == []
        assert bat.held_count == 2                 # two distinct groups held
        assert bat.pending() == 3                  # requests stay queued
        evs = list(bat.admission_events)
        assert len(evs) == 2
        by_depth = {e.queue_depth for e in evs}
        assert by_depth == {1, 2}
        for e in evs:
            assert e.required_bytes > e.available_bytes
            assert "b" in e.label
        bat.memory_budget = None                   # lift the budget
        groups = bat.drain()
        assert sum(len(g) for g in groups) == 3
        assert bat.pending() == 0

    def test_prometheus_text(self, bucketed_fn):
        w = np.ones((8, 8), np.float32)
        bucketed_fn(w, np.ones((4, 8), np.float32))
        bat = BucketBatcher(bucketed_fn, memory_budget=1)
        bat.submit({"b": 2})
        bat.drain()
        text = bat.metrics_text()
        lines = [ln for ln in text.splitlines() if ln]
        families = {}
        for ln in lines:
            if ln.startswith("# TYPE"):
                _, _, name, kind = ln.split()
                families[name] = kind
            elif not ln.startswith("#"):
                name = ln.split("{")[0].split(" ")[0]
                assert name in families, f"sample before TYPE: {ln}"
                float(ln.rsplit(" ", 1)[1])        # value parses
        assert families["repro_bucket_hits_total"] == "counter"
        assert families["repro_batcher_held_total"] == "counter"
        assert families["repro_bucket_arena_bound_bytes"] == "gauge"

    def test_prometheus_with_telemetry(self, bucketed_fn):
        tel = bucketed_fn.enable_telemetry()
        try:
            bucketed_fn(np.ones((8, 8), np.float32),
                        np.ones((4, 8), np.float32))
            text = prometheus_text(fn=bucketed_fn)
            assert "repro_calls_total 1" in text
            assert "repro_dispatch_ns_total" in text
        finally:
            bucketed_fn.disable_telemetry()
        assert tel.n_calls == 1
