"""Fault-tolerant serving runtime: chaos, ladder, quarantine, hardening.

Contract families over :mod:`repro.core.resilience` and its wiring:

  * **fault plans** — seeded schedules are reproducible, per-attempt
    arming spends the firing budget exactly once, and every firing lands
    in the ``fired`` audit record;
  * **circuit breaker** — the closed / open / half-open state machine on
    an injectable clock: threshold trips, backoff gating, the single
    half-open probe, exponential re-open growth, full reset on success;
  * **degradation ladder** — transient faults retry in place, memory
    pressure and quarantined compiles retry on the whole-range fallback
    (bitwise-identical outputs), retries are bounded with exponential
    backoff, exhaustion raises a structured ``RequestFailed``, malformed
    requests never retry;
  * **chaos** — randomized fault schedules across the bench archs: no
    uncaught exception escapes, surviving requests match the fault-free
    run bitwise, every fired fault maps to a structured event/error or a
    breaker transition, quarantined buckets heal after faults clear, and
    arena occupancy stays under the active plan's guaranteed bound;
  * **zero overhead disabled** — with resilience off, a call allocates
    nothing from resilience code (the telemetry tracemalloc discipline);
  * **thread safety** — telemetry counters and the specialization table
    survive concurrent request threads plus background swaps;
  * **serve hardening** — bounded queue shed policies, deadlines,
    held-group aging/backoff (the unbounded-requeue bugfix), and the
    structured ``process`` loop.
"""
import os
import threading
import time
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import tree_util

import repro.core.resilience as res_pkg
from repro.core import optimize, symbolic_dims
from repro.core.resilience import (BreakerConfig, BucketQuarantined,
                                   CircuitBreaker, FaultPlan, FaultSpec,
                                   RequestFailed, RequestRejected,
                                   ResilienceConfig, RetryPolicy)
from repro.core.resilience.degrade import ResilienceController
from repro.launch.serve import BucketBatcher

B, S = symbolic_dims("b, s")
V, D, F = 300, 32, 64


def loss_fn(params, tokens, labels):
    emb = params["emb"][tokens]
    h = jax.nn.gelu(emb @ params["w1"])
    h2 = h @ params["w2"]
    logits = h2 @ params["emb"].T
    logp = jax.nn.log_softmax(logits)
    oh = jax.nn.one_hot(labels, logits.shape[-1])
    return -(oh * logp).sum() / (1.0 * tokens.shape[0] * tokens.shape[1])


def train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    return loss, jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)


def specs():
    p = {"emb": jax.ShapeDtypeStruct((V, D), jnp.float32),
         "w1": jax.ShapeDtypeStruct((D, F), jnp.float32),
         "w2": jax.ShapeDtypeStruct((F, D), jnp.float32)}
    t = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return p, t, t


def concrete_params(seed=0):
    rng = np.random.RandomState(seed)
    return {"emb": jnp.asarray(rng.randn(V, D), jnp.float32),
            "w1": jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
            "w2": jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)}


def tokens_of(b, s, seed=1):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)


def _flat(tree):
    return [np.asarray(x) for x in tree_util.tree_leaves(tree)]


def _trees_equal(a, b):
    fa, fb = _flat(a), _flat(b)
    return len(fa) == len(fb) and all(
        np.array_equal(x, y) for x, y in zip(fa, fb))


FAST_RETRY = RetryPolicy(max_retries=2, backoff_base_s=0.0)


@pytest.fixture()
def resilient_fn():
    """Whole-range fn with the ladder attached (fresh per test — the
    controller and fault bookkeeping are the object under test)."""
    return optimize(train_step, *specs(),
                    dynamic_dims={"b": (1, 16), "s": (8, 256)},
                    resilience=ResilienceConfig(retry=FAST_RETRY))


@pytest.fixture()
def bucketed_resilient_fn():
    return optimize(train_step, *specs(),
                    dynamic_dims={"b": (1, 16), "s": (8, 256)},
                    buckets={"s": [32, 256]},
                    resilience=ResilienceConfig(
                        retry=FAST_RETRY,
                        breaker=BreakerConfig(backoff_s=0.02),
                        enforce_arena_bound=True))


# -- fault plans ---------------------------------------------------------------


class TestFaultPlan:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultSpec("meteor-strike")
        with pytest.raises(ValueError):
            FaultSpec("kernel", times=0)

    def test_random_is_reproducible(self):
        a = FaultPlan.random(7, buckets=[(0,), (1,)])
        b = FaultPlan.random(7, buckets=[(0,), (1,)])
        assert [vars(s) for s in a.specs] == [vars(s) for s in b.specs]
        c = FaultPlan.random(8, buckets=[(0,), (1,)])
        assert [vars(s) for s in a.specs] != [vars(s) for s in c.specs]

    def test_arm_call_matches_ordinal(self):
        fp = FaultPlan([FaultSpec("kernel", call=3)])
        assert fp.arm_call(0) is None
        armed = fp.arm_call(3)
        assert armed is not None and not armed.needs_memory

    def test_budget_spent_once(self):
        fp = FaultPlan([FaultSpec("kernel", call=0, step=0, times=1)])
        armed = fp.arm_call(0)
        from repro.core.resilience import TransientKernelError
        with pytest.raises(TransientKernelError):
            armed.before_compute()
        assert fp.remaining() == 0
        # re-arming after the budget is spent: nothing left to fire
        assert fp.arm_call(0) is None
        assert [f.kind for f in fp.fired] == ["kernel"]
        assert fp.fired[0].call == 0 and fp.fired[0].seq == 0

    def test_compile_fault_targets_bucket(self):
        from repro.core.resilience import CompileFault
        fp = FaultPlan([FaultSpec("compile", bucket=(1,))])
        fp.check_compile((0,))          # other bucket: nothing fires
        with pytest.raises(CompileFault):
            fp.check_compile((1,))
        fp.check_compile((1,))          # budget spent
        assert fp.fired[0].bucket == (1,)


# -- the circuit breaker -------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestCircuitBreaker:
    def test_trip_backoff_halfopen_close(self):
        clk = FakeClock()
        br = CircuitBreaker(BreakerConfig(backoff_s=1.0), clock=clk)
        key = (0,)
        assert br.allow(key)
        br.record_failure(key, RuntimeError("boom"))
        assert br.state(key) == "open"
        assert not br.allow(key)                  # inside the backoff
        assert br.retry_in_s(key) == pytest.approx(1.0)
        clk.t = 1.5
        assert br.allow(key)                      # open -> half-open probe
        assert br.state(key) == "half-open"
        assert not br.allow(key)                  # one probe at a time
        br.record_success(key)
        assert br.state(key) == "closed"
        assert br.allow(key)
        assert br.quarantined_keys() == []

    def test_failed_probe_reopens_with_doubled_backoff(self):
        clk = FakeClock()
        br = CircuitBreaker(BreakerConfig(backoff_s=1.0, backoff_factor=2.0,
                                          max_backoff_s=3.0), clock=clk)
        key = (1,)
        br.record_failure(key, RuntimeError("one"))
        clk.t = 1.0
        assert br.allow(key)
        br.record_failure(key, RuntimeError("two"))   # probe fails
        assert br.state(key) == "open"
        assert br.retry_in_s(key) == pytest.approx(2.0)
        clk.t = 3.0
        assert br.allow(key)
        br.record_failure(key, RuntimeError("three"))
        # growth capped at max_backoff_s
        assert br.retry_in_s(key) == pytest.approx(3.0)

    def test_failure_threshold(self):
        br = CircuitBreaker(BreakerConfig(failure_threshold=3),
                            clock=FakeClock())
        key = (2,)
        br.record_failure(key, RuntimeError("a"))
        br.record_failure(key, RuntimeError("b"))
        assert br.state(key) == "closed" and br.allow(key)
        br.record_failure(key, RuntimeError("c"))
        assert br.state(key) == "open"

    def test_transition_log_and_stats(self):
        clk = FakeClock()
        br = CircuitBreaker(BreakerConfig(backoff_s=1.0), clock=clk)
        br.record_failure((0,), RuntimeError("x"))
        clk.t = 2.0
        br.allow((0,))
        br.record_success((0,))
        states = [t["state"] for t in br.transitions if t["key"] == (0,)]
        assert states == ["open", "half-open", "closed"]
        assert br.stats()["by_state"] == {"closed": 1}


# -- the degradation ladder ----------------------------------------------------


class TestDegradationLadder:
    def test_no_fault_path_matches_plain(self, resilient_fn):
        plain = optimize(train_step, *specs(),
                         dynamic_dims={"b": (1, 16), "s": (8, 256)})
        args = (concrete_params(), tokens_of(4, 32), tokens_of(4, 32))
        assert _trees_equal(resilient_fn(*args), plain(*args))
        assert resilient_fn.resilience.counters()["degraded_calls"] == 0

    def test_transient_kernel_fault_retries_in_place(self, resilient_fn):
        args = (concrete_params(), tokens_of(4, 32), tokens_of(4, 32))
        ref = resilient_fn(*args)
        fp = FaultPlan([FaultSpec("kernel", call=1, step=2)])
        with resilient_fn.inject_faults(fp) as res:
            out = resilient_fn(*args)
        assert _trees_equal(out, ref)
        c = res.counters()
        assert c["retries_transient"] == 1 and c["degraded_calls"] == 1
        assert c["failures"] == 0
        evs = list(res.events)
        assert [e.rung for e in evs] == ["retry-transient"]
        assert "kernel" in evs[0].cause
        assert [f.kind for f in fp.fired] == ["kernel"]

    def test_alloc_fault_falls_back_bitwise(self, bucketed_resilient_fn):
        fn = bucketed_resilient_fn
        args = (concrete_params(), tokens_of(2, 24), tokens_of(2, 24))
        ref = fn(*args)
        assert fn.last_bucket is not None
        fp = FaultPlan([FaultSpec("alloc", call=1, step=0)])
        with fn.inject_faults(fp) as res:
            out = fn(*args)
        assert _trees_equal(out, ref)       # fallback is bitwise-identical
        c = res.counters()
        assert c["retries_fallback"] == 1 and c["failures"] == 0
        assert [e.rung for e in res.events] == ["retry-fallback"]

    def test_backoff_is_exponential_and_injectable(self, resilient_fn):
        slept = []
        res = resilient_fn.enable_resilience(ResilienceConfig(
            retry=RetryPolicy(max_retries=2, backoff_base_s=0.01,
                              backoff_factor=4.0)))
        res.sleep = slept.append
        resilient_fn._fault_ref.plan = FaultPlan(
            [FaultSpec("kernel", call=0, step=0, times=2)])
        args = (concrete_params(), tokens_of(2, 16), tokens_of(2, 16))
        resilient_fn(*args)
        assert slept == [pytest.approx(0.01), pytest.approx(0.04)]

    def test_retries_exhausted_raises_structured(self, resilient_fn):
        args = (concrete_params(), tokens_of(3, 16), tokens_of(3, 16))
        fp = FaultPlan([FaultSpec("kernel", call=0, step=0, times=5)])
        with resilient_fn.inject_faults(fp) as res:
            with pytest.raises(RequestFailed) as ei:
                resilient_fn(*args)
        e = ei.value
        assert e.attempts == 3               # max_retries=2 -> 3 attempts
        assert e.env == {"b": 3, "s": 16}
        assert e.cause is not None and "kernel" in repr(e.cause)
        assert [ev.rung for ev in e.events] == \
            ["retry-transient", "retry-transient", "reject"]
        assert [ev.attempt for ev in e.events] == [0, 1, 2]
        assert res.counters()["failures"] == 1
        # the next call is healthy again (budget spent on the failed one)
        resilient_fn(*args)
        assert res.counters()["failures"] == 1

    def test_malformed_request_rejected_without_retry(self, resilient_fn):
        args = (concrete_params(), tokens_of(2, 16), tokens_of(2, 16))
        fp = FaultPlan([FaultSpec("malformed-env", call=0)])
        with resilient_fn.inject_faults(fp) as res:
            with pytest.raises(RequestFailed) as ei:
                resilient_fn(*args)
        assert ei.value.attempts == 0
        assert [ev.rung for ev in ei.value.events] == ["reject-malformed"]
        c = res.counters()
        assert c["malformed"] == 1 and c["failures"] == 1
        assert c["retries_transient"] == 0 and c["retries_fallback"] == 0

    def test_degrade_events_land_in_decision_log(self, resilient_fn):
        fp = FaultPlan([FaultSpec("kernel", call=0, step=0)])
        args = (concrete_params(), tokens_of(2, 16), tokens_of(2, 16))
        with resilient_fn.inject_faults(fp):
            resilient_fn(*args)
        degrades = resilient_fn.decisions.entries("degrade")
        assert len(degrades) == 1
        assert degrades[0].choice == "retry-transient"

    def test_enable_disable_roundtrip(self, resilient_fn):
        res = resilient_fn.disable_resilience()
        assert res is not None and resilient_fn.resilience is None
        args = (concrete_params(), tokens_of(2, 16), tokens_of(2, 16))
        resilient_fn(*args)                 # plain path works
        res2 = resilient_fn.enable_resilience()
        assert resilient_fn.resilience is res2


# -- quarantined specialization ------------------------------------------------


class TestQuarantine:
    def test_compile_fault_quarantines_then_heals(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [32, 256]},
                      resilience=ResilienceConfig(
                          retry=FAST_RETRY,
                          breaker=BreakerConfig(backoff_s=0.05)))
        args = (concrete_params(), tokens_of(2, 24), tokens_of(2, 24))
        ref = optimize(train_step, *specs(),
                       dynamic_dims={"b": (1, 16), "s": (8, 256)})(*args)
        table = fn.specialization_table
        fp = FaultPlan([FaultSpec("compile")])
        with fn.inject_faults(fp) as res:
            out = fn(*args)                 # compile fails -> fallback
            assert _trees_equal(out, ref)
            assert res.counters()["retries_fallback"] == 1
            key = fp.fired[0].bucket
            assert table.breaker.state(key) == "open"
            assert table.quarantined() == [key]
            # while quarantined: served by the fallback, no new compile
            out2 = fn(*args)
            assert _trees_equal(out2, ref)
            assert table.stats()["specialize_count"] == 0
        # faults cleared; after the backoff the next miss re-probes
        time.sleep(0.06)
        out3 = fn(*args)
        assert _trees_equal(out3, ref)
        assert table.breaker.state(key) == "closed"
        assert table.quarantined() == []
        assert table.stats()["specialize_count"] == 1
        assert table.peek(key) is not None

    def test_compile_timeout_detected_and_quarantined(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [32, 256]},
                      resilience=ResilienceConfig(
                          retry=FAST_RETRY,
                          breaker=BreakerConfig(backoff_s=5.0),
                          compile_timeout_s=0.001))
        args = (concrete_params(), tokens_of(2, 24), tokens_of(2, 24))
        fp = FaultPlan([FaultSpec("compile-timeout", delay_s=0.01)])
        with fn.inject_faults(fp) as res:
            fn(*args)                       # slow compile -> fallback
        table = fn.specialization_table
        key = fp.fired[0].bucket
        assert table.breaker.state(key) == "open"
        cause = table.breaker.cause(key)
        from repro.core.resilience import CompileTimeout
        assert isinstance(cause, CompileTimeout)
        assert res.counters()["retries_fallback"] == 1

    def test_quarantine_visible_in_exports(self):
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [32, 256]},
                      resilience=ResilienceConfig(
                          retry=FAST_RETRY,
                          breaker=BreakerConfig(backoff_s=5.0)))
        args = (concrete_params(), tokens_of(2, 24), tokens_of(2, 24))
        with fn.inject_faults(FaultPlan([FaultSpec("compile")])):
            fn(*args)
        from repro.core.obs import prometheus_text
        text = prometheus_text(fn=fn)
        assert "repro_quarantined_buckets 1" in text
        assert "repro_retries_total" in text
        report = fn.explain()
        assert "resilience" in report and "quarantined" in report


# -- chaos ---------------------------------------------------------------------

BENCH_ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]
CHAOS_SEEDS = [0, 1, 2]
CHAOS_ENVS = [{"b": 1, "s": 16}, {"b": 2, "s": 40}, {"b": 3, "s": 64}]


@pytest.fixture(scope="module")
def chaos_arch_fn():
    """Per-arch compiled pair: (resilient bucketed fn, concrete args per
    env, fault-free reference outputs per env).  Compiled once per arch —
    the three chaos seeds reuse it with fresh controllers."""
    from benchmarks.memplan_bench import _step_and_specs, concretize_spec
    cache = {}

    def build(arch):
        if arch in cache:
            return cache[arch]
        r = _step_and_specs(arch)
        assert r is not None, f"{arch} missing from the bench arch set"
        step, args = r
        fn = optimize(step, *args,
                      dynamic_dims={"b": (1, 4), "s": (8, 64)},
                      buckets={"s": [16, 64]},
                      resilience=ResilienceConfig(
                          retry=RetryPolicy(max_retries=3,
                                            backoff_base_s=0.0),
                          breaker=BreakerConfig(backoff_s=0.01),
                          enforce_arena_bound=True))
        flat_specs, treedef = tree_util.tree_flatten((args, {}))
        rng = np.random.RandomState(0)
        calls, refs = {}, {}
        for env in CHAOS_ENVS:
            flat = [concretize_spec(s, env, rng) for s in flat_specs]
            cargs, _ = tree_util.tree_unflatten(treedef, flat)
            calls[tuple(sorted(env.items()))] = cargs
        # fault-free reference pass (also makes bucket plans resident)
        for env in CHAOS_ENVS:
            k = tuple(sorted(env.items()))
            refs[k] = fn(*calls[k])
        cache[arch] = (fn, calls, refs)
        return cache[arch]

    return build


@pytest.mark.parametrize("arch", BENCH_ARCHS)
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_schedule_no_crash_and_bitwise_survivors(
        chaos_arch_fn, arch, seed):
    """The acceptance chaos property, per (arch, seed): a randomized
    fault schedule crashes nothing, surviving requests match the
    fault-free run bitwise, every fired fault maps to a structured
    event/error or breaker transition, quarantined buckets heal after
    the schedule clears, and arena occupancy respects the active bound.
    """
    fn, calls, refs = chaos_arch_fn(arch)
    table = fn.specialization_table
    keys = sorted({table.key_of(env) for env in CHAOS_ENVS})
    # evict resident plans so compile faults have compiles to hit
    # (bounds survive eviction; the next miss recompiles)
    with table._lock:
        for key in keys:
            table._plans.pop(key, None)
    res = fn.enable_resilience(ResilienceConfig(
        retry=RetryPolicy(max_retries=3, backoff_base_s=0.0),
        breaker=BreakerConfig(backoff_s=0.01),
        enforce_arena_bound=True))
    plan = FaultPlan.random(seed, n_faults=4, max_call=8, max_step=3,
                            buckets=keys, timeout_delay_s=0.0)
    failures = []
    with fn.inject_faults(plan):
        for i in range(8):
            env = CHAOS_ENVS[i % len(CHAOS_ENVS)]
            k = tuple(sorted(env.items()))
            try:
                out = fn(*calls[k])
            except RequestFailed as e:
                failures.append((i, e))     # structured: fine
                continue
            # survivor: bitwise-identical to the fault-free run
            assert _trees_equal(out, refs[k]), \
                f"{arch} seed {seed} call {i}: outputs diverged"
            bound = fn.last_arena_bound
            if bound is not None:
                assert fn.last_report.stats.arena_bytes <= bound
    # every failure is structured and self-describing
    for i, e in failures:
        assert isinstance(e, RequestFailed)
        assert e.events, f"failure at call {i} carries no events"
    # every fired fault maps to a structured record
    evs = list(res.events)
    for f in plan.fired:
        if f.kind in ("compile", "compile-timeout"):
            assert any(t["key"] == f.bucket and t["state"] == "open"
                       for t in table.breaker.transitions), \
                f"compile fault on {f.bucket} left no breaker transition"
        else:
            assert any(e.seq == f.call for e in evs), \
                f"{f.kind} fault on call {f.call} left no event"
    # recovery: schedule cleared -> every bucket heals once its breaker
    # backoff elapses and the next miss re-probes
    deadline = time.monotonic() + 5.0
    while table.quarantined() and time.monotonic() < deadline:
        time.sleep(0.02)
        for env in CHAOS_ENVS:
            k = tuple(sorted(env.items()))
            out = fn(*calls[k])
            assert _trees_equal(out, refs[k])
    assert table.quarantined() == [], \
        f"{arch} seed {seed}: buckets still quarantined after recovery"
    for env in CHAOS_ENVS:
        k = tuple(sorted(env.items()))
        assert _trees_equal(fn(*calls[k]), refs[k])
        assert table.peek(table.key_of(env)) is not None, \
            "bucket did not return to its specialized plan"


def test_chaos_through_serve_loop():
    """The serve loop itself: RequestFailed becomes a structured outcome,
    nothing escapes ``process``."""
    fn = optimize(train_step, *specs(),
                  dynamic_dims={"b": (1, 16), "s": (8, 256)},
                  buckets={"s": [32, 256]},
                  resilience=ResilienceConfig(retry=FAST_RETRY))
    bat = BucketBatcher(fn)
    args = (concrete_params(), tokens_of(2, 24), tokens_of(2, 24))
    ref = fn(*args)
    for _ in range(3):
        bat.submit({"b": 2, "s": 24}, payload=args)
    # the reference call above was resilient seq 0; the three queued
    # requests dispatch as seqs 1..3 — fault the middle one
    fp = FaultPlan([FaultSpec("malformed-env", call=2)])
    with fn.inject_faults(fp):
        outcomes = bat.process()
    assert [o["ok"] for o in outcomes] == [True, False, True]
    for o in outcomes:
        if o["ok"]:
            assert _trees_equal(o["value"], ref)
            assert o["report"] is not None
        else:
            assert isinstance(o["error"], RequestFailed)
            assert o["error"].attempts == 0


# -- zero overhead when disabled -----------------------------------------------


class TestZeroOverheadDisabled:
    def test_disabled_path_allocates_nothing_from_resilience(self):
        """The structural <=2% contract (wall-clock form lives in
        ``benchmarks/resilience_bench.py``): with resilience off, a call
        touches no resilience code at all."""
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)})
        assert fn.resilience is None
        res_dir = os.path.dirname(res_pkg.__file__)
        args = (concrete_params(), tokens_of(2, 16), tokens_of(2, 16))
        fn(*args)                                 # warm every cache
        flt = tracemalloc.Filter(True, os.path.join(res_dir, "*"))
        tracemalloc.start(5)
        try:
            before = tracemalloc.take_snapshot().filter_traces([flt])
            for _ in range(5):
                fn(*args)
            after = tracemalloc.take_snapshot().filter_traces([flt])
        finally:
            tracemalloc.stop()
        diff = after.compare_to(before, "lineno")
        grew = [d for d in diff if d.size_diff > 0]
        assert not grew, \
            f"resilience code allocated on the disabled path: {grew}"


# -- thread safety -------------------------------------------------------------


class TestThreadSafety:
    def test_telemetry_ring_concurrent_pushes(self):
        from repro.core.obs import CallRecord, TelemetryRing
        ring = TelemetryRing(capacity=64)
        N, T = 500, 8

        def rec(i):
            return CallRecord(seq=i, bucket_key=None, env=(), wall_s=0.0,
                              dispatch_ns=0, device_peak=0, arena_bytes=0,
                              evictions=0, recomputes=0, reloads=0,
                              donated_reuses=0, loop_trips=())

        def work():
            for i in range(N):
                ring.push(rec(i))

        threads = [threading.Thread(target=work) for _ in range(T)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # no lost increments: the monotonic write index moved atomically
        assert ring.total_pushed == N * T
        assert len(ring.records()) == 64

    def test_concurrent_calls_lose_no_counts(self):
        """Satellite regression: many request threads + background swaps
        hammer telemetry counters and the table at once."""
        fn = optimize(train_step, *specs(),
                      dynamic_dims={"b": (1, 16), "s": (8, 256)},
                      buckets={"s": [32, 256]})
        tel = fn.enable_telemetry(capacity=1024)
        envs = [(2, 24), (3, 48), (2, 16)]
        per_thread, T = 6, 6
        errs = []

        def work(tid):
            try:
                for i in range(per_thread):
                    b, s = envs[(tid + i) % len(envs)]
                    fn(concrete_params(), tokens_of(b, s), tokens_of(b, s))
            except Exception as e:        # surface, don't deadlock
                errs.append(e)

        threads = [threading.Thread(target=work, args=(t,))
                   for t in range(T)]
        for t in threads:
            t.start()
        # concurrent background churn: recompiles swap plans mid-traffic
        for key in list(fn.specialization_table._plans):
            fn.specialization_table.recompile(key)
        for t in threads:
            t.join()
        fn.specialization_table.drain_background()
        assert errs == []
        total = per_thread * T
        assert tel.n_calls == total
        assert sum(tel.calls_by_bucket.values()) == total
        assert tel.ring.total_pushed == total
        st = fn.specialization_table.stats()
        assert st["hits"] + st["misses"] == total


# -- serve hardening -----------------------------------------------------------


@pytest.fixture(scope="module")
def serve_fn():
    return optimize(train_step, *specs(),
                    dynamic_dims={"b": (1, 16), "s": (8, 256)},
                    buckets={"s": [32, 256]})


class TestBatcherHardening:
    def test_defaults_preserve_plain_behavior(self, serve_fn):
        """Knobs off: held groups persist forever, nothing sheds (the
        pre-hardening contract ``test_dispatch.py`` pins)."""
        bat = BucketBatcher(serve_fn, memory_budget=1)
        bat.submit({"b": 2, "s": 24})
        for _ in range(5):
            assert bat.drain() == []
        assert bat.pending() == 1 and bat.shed_count == 0

    def test_aged_group_is_shed_structurally(self, serve_fn):
        """The drain bugfix: an over-budget group ages out after
        ``max_hold_cycles`` instead of re-enqueueing indefinitely."""
        bat = BucketBatcher(serve_fn, memory_budget=1, max_hold_cycles=2)
        bat.submit({"b": 2, "s": 24}, payload="r0")
        bat.submit({"b": 3, "s": 24}, payload="r1")
        assert bat.drain() == [] and bat.pending() == 2   # hold 1
        assert bat.drain() == [] and bat.pending() == 2   # hold 2
        assert bat.drain() == []                          # aged out
        assert bat.pending() == 0
        assert bat.held_count == 2
        assert bat.shed_count == 2
        assert bat.shed_by_outcome == {"shed-aged": 2}
        shed = bat.take_shed()
        assert [p for _, _, p, _ in shed] == ["r0", "r1"]
        assert all(o == "shed-aged" for _, _, _, o in shed)
        assert bat.take_shed() == []                      # drained once
        evs = [e for e in bat.admission_events if e.outcome == "shed-aged"]
        assert len(evs) == 1 and evs[0].queue_depth == 2
        assert evs[0].required_bytes > evs[0].available_bytes

    def test_hold_backoff_skips_rechecks(self, serve_fn):
        clk = FakeClock()
        bat = BucketBatcher(serve_fn, memory_budget=1, hold_backoff_s=10.0,
                            clock=clk)
        bat.submit({"b": 2, "s": 24})
        assert bat.drain() == [] and bat.held_count == 1
        clk.t = 5.0                        # inside the backoff window
        assert bat.drain() == []
        assert bat.held_count == 1         # silent: no re-check, no event
        clk.t = 11.0                       # window over: re-check happens
        assert bat.drain() == []
        assert bat.held_count == 2
        # second consecutive hold: window doubles (10 * 2**1)
        clk.t = 30.0
        bat.memory_budget = None
        groups = bat.drain()
        assert sum(len(g) for g in groups) == 1

    def test_bounded_queue_reject_new(self, serve_fn):
        bat = BucketBatcher(serve_fn, max_queue=2)
        bat.submit({"b": 2, "s": 24}, payload="a")
        bat.submit({"b": 3, "s": 24}, payload="b")
        with pytest.raises(RequestRejected) as ei:
            bat.submit({"b": 4, "s": 24}, payload="c")
        assert ei.value.reason == "shed-capacity"
        assert ei.value.env == {"b": 4, "s": 24}
        assert bat.pending() == 2
        assert bat.shed_by_outcome == {"shed-capacity": 1}
        evs = [e for e in bat.admission_events
               if e.outcome == "shed-capacity"]
        assert len(evs) == 1

    def test_bounded_queue_drop_oldest(self, serve_fn):
        bat = BucketBatcher(serve_fn, max_queue=2,
                            shed_policy="drop-oldest")
        bat.submit({"b": 2, "s": 24}, payload="a")
        bat.submit({"b": 3, "s": 24}, payload="b")
        bat.submit({"b": 4, "s": 24}, payload="c")   # evicts "a"
        assert bat.pending() == 2
        shed = bat.take_shed()
        assert len(shed) == 1 and shed[0][2] == "a"
        assert shed[0][3] == "shed-capacity"
        groups = bat.drain()
        payloads = sorted(p for g in groups for p in g.payloads)
        assert payloads == ["b", "c"]

    def test_invalid_shed_policy_rejected(self, serve_fn):
        with pytest.raises(ValueError):
            BucketBatcher(serve_fn, shed_policy="yolo")

    def test_deadline_expired_requests_shed(self, serve_fn):
        clk = FakeClock()
        bat = BucketBatcher(serve_fn, clock=clk)
        bat.submit({"b": 2, "s": 24}, payload="slow", deadline_s=1.0)
        bat.submit({"b": 2, "s": 24}, payload="patient")
        clk.t = 2.0
        groups = bat.drain()
        assert [p for g in groups for p in g.payloads] == ["patient"]
        shed = bat.take_shed()
        assert len(shed) == 1 and shed[0][2] == "slow"
        assert shed[0][3] == "shed-deadline"
        assert bat.shed_by_outcome == {"shed-deadline": 1}

    def test_default_deadline_applies(self, serve_fn):
        clk = FakeClock()
        bat = BucketBatcher(serve_fn, default_deadline_s=1.0, clock=clk)
        bat.submit({"b": 2, "s": 24})
        clk.t = 2.0
        assert bat.drain() == []
        assert bat.shed_by_outcome == {"shed-deadline": 1}
        assert bat.pending() == 0

    def test_intake_validation_still_at_submit(self, serve_fn):
        bat = BucketBatcher(serve_fn, max_queue=1)
        with pytest.raises(ValueError):
            bat.submit({"b": 2, "s": 10_000})

    def test_shed_metrics_exported(self, serve_fn):
        bat = BucketBatcher(serve_fn, memory_budget=1, max_hold_cycles=1)
        bat.submit({"b": 2, "s": 24})
        bat.drain()
        bat.drain()
        text = bat.metrics_text()
        assert 'repro_batcher_shed_total{outcome="shed-aged"} 1' in text
