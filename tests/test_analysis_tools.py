"""Tests for the perf-analysis tooling: trip-count-scaled HLO analysis,
the exchange post-pass, and roofline derivation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import symbolic_dims
from repro.core.ir import trace_to_graph
from repro.core.scheduling import schedule_graph, simulate_peak
from repro.core.scheduling.exchange import exchange_pass
from repro.core.symbolic import ShapeGraph
from repro.launch.hlo_analysis import HLOAnalyzer, _shape_nbytes


class TestHLOAnalysis:
    def test_shape_bytes(self):
        assert _shape_nbytes("f32[2,3]{1,0}") == 24
        assert _shape_nbytes("bf16[128]") == 256
        assert _shape_nbytes("(f32[2], s32[4])") == 8 + 16
        assert _shape_nbytes("pred[8]") == 8

    def test_scan_trip_scaling(self):
        """A scanned matmul's flops must be counted x trips."""
        w = jnp.ones((64, 64), jnp.float32)

        def f(x):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=17)
            return y

        compiled = jax.jit(f).lower(jnp.ones((64, 64), jnp.float32)).compile()
        res = HLOAnalyzer(compiled.as_text()).analyze()
        expect = 2 * 64 * 64 * 64 * 17
        assert res["flops"] >= expect * 0.9, (res["flops"], expect)
        assert res["flops"] <= expect * 1.5

    def test_nested_scan_scaling(self):
        w = jnp.ones((32, 32), jnp.float32)

        def f(x):
            def inner(c, _):
                return c @ w, None

            def outer(c, _):
                y, _ = jax.lax.scan(inner, c, None, length=5)
                return y, None
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y

        compiled = jax.jit(f).lower(jnp.ones((32, 32), jnp.float32)).compile()
        res = HLOAnalyzer(compiled.as_text()).analyze()
        expect = 2 * 32 ** 3 * 15
        assert res["flops"] >= expect * 0.9
        assert res["flops"] <= expect * 1.5

    def test_no_warnings_on_model_graph(self):
        """Trip counts must resolve for real scanned models."""
        def f(x):
            def body(c, _):
                return jnp.tanh(c) * 1.01, None
            y, _ = jax.lax.scan(body, x, None, length=9)
            return y.sum()

        compiled = jax.jit(f).lower(jnp.ones((128,), jnp.float32)).compile()
        an = HLOAnalyzer(compiled.as_text())
        an.analyze()
        assert not an.warnings


class TestExchangePass:
    def test_preserves_validity_and_never_regresses(self):
        B, S = symbolic_dims("b, s")

        def fn(w1, w2, x):
            a = jax.nn.relu(x @ w1)
            b = jax.nn.relu(x @ w2)
            return (a.sum(-1) * b.sum(-1)).sum()

        g, _ = trace_to_graph(
            jax.grad(fn, argnums=(0, 1)),
            jax.ShapeDtypeStruct((64, 512), jnp.float32),
            jax.ShapeDtypeStruct((64, 512), jnp.float32),
            jax.ShapeDtypeStruct((B, S, 64), jnp.float32))
        res = schedule_graph(g, ShapeGraph())
        envs = [{"b": 2, "s": 32}, {"b": 8, "s": 128}]
        refined = exchange_pass(g, res.order, envs)
        g.validate_order(refined)  # raises on violation
        for env in envs + [{"b": 5, "s": 77}]:
            before = simulate_peak(g, res.order, env).peak_bytes
            after = simulate_peak(g, refined, env).peak_bytes
            assert after <= before


class TestRooflineDerivation:
    def test_model_flops(self):
        from benchmarks.roofline import model_flops
        mf = model_flops("granite_8b", "train_4k")
        # 6 * ~8e9 * 1.05e6 tokens
        assert 4e16 < mf < 7e16, mf
        dec = model_flops("granite_8b", "decode_32k")
        assert dec < mf / 1e4

    def test_analyze_record_terms(self):
        from benchmarks.roofline import analyze_record
        rec = {
            "status": "ok", "arch": "granite_8b", "shape": "train_4k",
            "mesh": "16x16",
            "scaled": {"flops": 1.97e14, "hbm_bytes": 8.19e11,
                       "collective_bytes": 5e10},
            "memory": {"total_per_device_bytes": 8 << 30},
        }
        row = analyze_record(rec)
        assert abs(row["compute_s"] - 1.0) < 1e-6
        assert abs(row["memory_s"] - 1.0) < 1e-6
        assert abs(row["collective_s"] - 1.0) < 1e-6
        assert row["fits_hbm"] is True
