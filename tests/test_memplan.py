"""Memory planner: liveness, symbolic slot assignment, runtime arena."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimize, symbolic_dims
from repro.core.ir import trace_to_graph
from repro.core.memplan import analyze_liveness, build_arena_plan
from repro.core.scheduling import schedule_graph, simulate_peak
from repro.core.symbolic import ShapeGraph, declare_dim_ranges


B, S = symbolic_dims("b, s")
V, D, F = 300, 32, 64


def loss_fn(params, tokens, labels):
    emb = params["emb"][tokens]
    h = jax.nn.gelu(emb @ params["w1"])
    h2 = h @ params["w2"]
    logits = h2 @ params["emb"].T
    logp = jax.nn.log_softmax(logits)
    oh = jax.nn.one_hot(labels, logits.shape[-1])
    return -(oh * logp).sum() / (1.0 * tokens.shape[0] * tokens.shape[1])


def train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    return loss, jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)


def specs():
    p = {"emb": jax.ShapeDtypeStruct((V, D), jnp.float32),
         "w1": jax.ShapeDtypeStruct((D, F), jnp.float32),
         "w2": jax.ShapeDtypeStruct((F, D), jnp.float32)}
    t = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return p, t, t


def concrete_params(seed=0):
    rng = np.random.RandomState(seed)
    return {"emb": jnp.asarray(rng.randn(V, D), jnp.float32),
            "w1": jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
            "w2": jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)}


@pytest.fixture(scope="module")
def traced():
    g, _ = trace_to_graph(train_step, *specs())
    sg = ShapeGraph()
    declare_dim_ranges(sg, {"b": (1, 16), "s": (8, 256)})
    res = schedule_graph(g, sg)
    return g, sg, res


class TestLiveness:
    def test_intervals_wellformed(self, traced):
        g, sg, res = traced
        live = analyze_liveness(g, res.order)
        pos = {n.id: i for i, n in enumerate(res.order)}
        out_ids = {v.id for v in g.outputs}
        horizon = len(res.order)
        by_id = {v.id: v for v in g.values}
        for iv in live.values():
            v = by_id[iv.vid]
            assert iv.start <= iv.end
            if iv.external:
                assert iv.start == -1
                assert iv.end == horizon  # no donation: caller buffers stay
            else:
                assert iv.start == pos[v.producer.id]
                if iv.vid in out_ids:
                    assert iv.end == horizon
                else:
                    assert iv.end == max(pos[c.id] for c in v.consumers)

    def test_transients_are_not_planned(self, traced):
        g, sg, res = traced
        live = analyze_liveness(g, res.order)
        out_ids = {v.id for v in g.outputs}
        for v in g.values:
            if not v.is_materialized_input() and not v.consumers \
                    and v.id not in out_ids:
                assert v.id not in live

    def test_donation_frees_inputs_at_last_use(self, traced):
        g, sg, res = traced
        live = analyze_liveness(g, res.order, donate_inputs=True)
        pos = {n.id: i for i, n in enumerate(res.order)}
        horizon = len(res.order)
        donated_early = 0
        for v in list(g.inputs) + list(g.consts):
            iv = live[v.id]
            uses = [pos[c.id] for c in v.consumers if c.id in pos]
            if uses and v.id not in {o.id for o in g.outputs}:
                assert iv.end == max(uses)
                donated_early += iv.end < horizon
            else:
                assert iv.end == horizon
        assert donated_early > 0


class TestAssignment:
    def test_slot_members_never_overlap(self, traced):
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg)
        for s in plan.slots:
            ivs = sorted((plan.liveness[vid].start, plan.liveness[vid].end)
                         for vid in s.members)
            for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
                assert e1 < s2, f"slot {s.sid}: members overlap"

    def test_every_planned_value_assigned(self, traced):
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg)
        assert set(plan.assignment) == set(plan.liveness)
        assert plan.n_assigned == sum(1 for iv in plan.liveness.values()
                                      if not iv.external)

    def test_provable_fits_hold_numerically(self, traced):
        """Hard reuse is hard: a provably-fitting member never exceeds its
        slot's capacity at any in-range env."""
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg)
        checked = 0
        for env in ({"b": 1, "s": 8}, {"b": 3, "s": 100}, {"b": 16, "s": 256}):
            caps = plan.slot_capacities(env)
            for vid, asg in plan.assignment.items():
                if not asg.provable:
                    continue
                need = plan.liveness[vid].nbytes_expr.evaluate(env)
                assert need <= caps[asg.sid]
                checked += 1
        assert checked > 0

    def test_slot_size_expr_matches_capacity(self, traced):
        """The per-slot symbolic size (max over the candidate set) is the
        expression whose evaluation the runtime capacities come from."""
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg)
        for env in ({"b": 2, "s": 16}, {"b": 16, "s": 256}):
            caps = plan.slot_capacities(env)
            for s in plan.slots:
                assert s.size_expr.evaluate(env) == caps[s.sid]

    def test_reuse_exists_and_mostly_provable(self, traced):
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg)
        assert plan.planned_reuse_ratio > 0.5
        assert plan.n_provable_reuses > plan.n_checked_reuses

    def test_external_slots_only_take_provable_members(self, traced):
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg, donate_inputs=True)
        for vid, asg in plan.assignment.items():
            if asg.donated and not plan.liveness[vid].external:
                assert asg.provable  # caller buffers cannot grow


class TestArenaSizing:
    def test_reuse_never_loses_vs_logical_peak(self, traced):
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg)
        for env in ({"b": 1, "s": 8}, {"b": 4, "s": 64}, {"b": 16, "s": 256}):
            peak = simulate_peak(g, res.order, env).peak_bytes
            assert plan.arena_bytes(env) <= peak

    def test_arena_bound_is_sound(self, traced):
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg)
        assert plan.arena_bound_bytes is not None
        rng = np.random.RandomState(1)
        for _ in range(12):
            env = {"b": int(rng.randint(1, 17)), "s": int(rng.randint(8, 257))}
            assert plan.arena_bytes(env) <= plan.arena_bound_bytes
            assert plan.arena_bytes(env) >= plan.arena_bound_lo

    def test_unbounded_dims_have_no_bound(self, traced):
        g, _, res = traced
        plan = build_arena_plan(g, res.order, ShapeGraph())
        assert plan.arena_bound_bytes is None
        # arena still evaluates fine per env
        assert plan.arena_bytes({"b": 2, "s": 32}) > 0

    def test_donation_never_widens_the_arena(self, traced):
        g, sg, res = traced
        plan = build_arena_plan(g, res.order, sg)
        plan_d = build_arena_plan(g, res.order, sg, donate_inputs=True)
        assert plan_d.n_donated_reuses > 0
        for env in ({"b": 2, "s": 16}, {"b": 16, "s": 256}):
            assert plan_d.arena_bytes(env) <= plan.arena_bytes(env)


class TestRuntimeArena:
    def test_runtime_matches_plan_and_numerics_unchanged(self):
        opt = optimize(train_step, *specs(),
                       dynamic_dims={"b": (1, 16), "s": (8, 256)})
        opt_none = optimize(train_step, *specs(), memory_plan="none")
        params = concrete_params()
        rng = np.random.RandomState(0)
        for (b, s) in [(2, 17), (8, 128), (16, 256)]:
            tok = jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)
            loss, _ = opt(params, tok, tok)
            loss_n, _ = opt_none(params, tok, tok)
            assert abs(float(loss) - float(loss_n)) < 1e-6
            st = opt.last_report.stats
            env = {"b": b, "s": s}
            assert st.arena_bytes == opt.arena_plan.arena_bytes(env)
            assert st.slots > 0
            assert st.reuse_ratio > 0
            assert st.fragmentation_bytes >= 0
            assert st.arena_growth_bytes == 0  # no churn in a free run
            assert st.arena_bytes <= st.device_peak
            assert st.arena_bytes <= opt.arena_bound_bytes

    def test_memory_plan_none_disables_arena(self):
        opt = optimize(train_step, *specs(), memory_plan="none")
        assert opt.arena_plan is None
        assert opt.arena_bound_bytes is None
        params = concrete_params()
        tok = jnp.zeros((2, 16), jnp.int32)
        opt(params, tok, tok)
        st = opt.last_report.stats
        assert st.arena_bytes == 0 and st.slots == 0 and st.reuse_ratio == 0

    def test_invalid_memory_plan_rejected(self):
        with pytest.raises(ValueError, match="memory_plan"):
            optimize(train_step, *specs(), memory_plan="slab")

    def test_arena_cooperates_with_remat_eviction(self):
        opt = optimize(train_step, *specs())
        params = concrete_params()
        rng = np.random.RandomState(0)
        tok = jnp.asarray(rng.randint(0, V, (6, 64)), jnp.int32)
        loss_free, _ = opt(params, tok, tok)
        peak = opt.last_report.stats.device_peak
        capped = opt.with_memory_limit(int(peak * 0.6))
        loss_c, _ = capped(params, tok, tok)
        st = capped.last_report.stats
        assert st.evictions > 0
        assert abs(float(loss_c) - float(loss_free)) < 1e-5
        assert st.arena_bytes > 0 and st.reuse_ratio > 0

    def test_repeated_shapes_hit_resolve_cache(self):
        opt = optimize(train_step, *specs())
        params = concrete_params()
        tok = jnp.zeros((3, 24), jnp.int32)
        opt(params, tok, tok)
        first = opt.last_report.stats.arena_bytes
        opt(params, tok, tok)
        assert opt.last_report.stats.arena_bytes == first
        assert len(opt.arena_plan._resolve_cache) == 1


class TestDonateInputsEndToEnd:
    """Satellite: donation agrees across interpreter, memsim, and arena."""

    def test_interpreter_frees_donated_inputs(self):
        opt = optimize(train_step, *specs(), donate_inputs=True)
        opt_keep = optimize(train_step, *specs())
        params = concrete_params()
        rng = np.random.RandomState(0)
        tok = jnp.asarray(rng.randint(0, V, (4, 48)), jnp.int32)
        opt(params, tok, tok)
        opt_keep(params, tok, tok)
        don, keep = opt.last_report.stats, opt_keep.last_report.stats
        assert don.device_peak <= keep.device_peak
        # donated inputs were released: less is resident at the end
        assert don.device_used < keep.device_used

    def test_memsim_donation_agrees_with_interpreter_peak(self):
        opt = optimize(train_step, *specs(), donate_inputs=True)
        params = concrete_params()
        rng = np.random.RandomState(0)
        for (b, s) in [(2, 16), (5, 100)]:
            tok = jnp.asarray(rng.randint(0, V, (b, s)), jnp.int32)
            opt(params, tok, tok)
            st = opt.last_report.stats
            tl = simulate_peak(opt.plan.graph, opt.plan.order, {"b": b, "s": s},
                               donate_inputs=True)
            # memsim also charges transient (dead) outputs at their step;
            # the interpreter never materializes those, so it can only be
            # at or below the simulated peak
            assert st.device_peak <= tl.peak_bytes
            assert st.device_peak >= tl.peak_bytes - tl.base_bytes

    def test_donated_slots_are_reused_by_the_arena(self):
        opt = optimize(train_step, *specs(), donate_inputs=True)
        params = concrete_params()
        rng = np.random.RandomState(0)
        tok = jnp.asarray(rng.randint(0, V, (4, 48)), jnp.int32)
        opt(params, tok, tok)
        st = opt.last_report.stats
        assert opt.arena_plan.n_donated_reuses > 0
        assert st.donated_reuses > 0
        # updated params land in donated param buffers: smaller arena than
        # the keep-inputs plan
        opt_keep = optimize(train_step, *specs())
        opt_keep(params, tok, tok)
        assert st.arena_bytes <= opt_keep.last_report.stats.arena_bytes
