"""Fast compile path: hash-consed exprs, memoized comparisons, incremental
bucket specialization, background specialization.

The contracts here are equivalence contracts: every cache layer must be
*invisible* except for speed —

* interned ``SymbolicExpr``s are equal / hash-equal iff their canonical
  polynomial forms match (property test);
* a ``ShapeGraph`` with warm memo tables (including verdicts inherited
  through ``specialized()``) answers ``compare`` exactly like a freshly
  built, never-queried graph, across randomized range narrowings
  (property test);
* the incremental ``_compile_pipeline`` (parent artifacts, per-candidate
  remat reuse, schedule post-pass reuse) produces plans equivalent to a
  cold compile of the same narrowed graph;
* ``background_specialize=True`` produces bitwise-identical outputs and
  the same ``specialize_count`` endpoint as synchronous specialization,
  with ``warmup``/``drain_specializations`` as the deterministic join.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import optimize, symbolic_dims
from repro.core.api import _compile_pipeline
from repro.core.ir.trace import trace_to_graph
from repro.core.scheduling.scheduler import OpScheduler
from repro.core.symbolic import Cmp, ShapeGraph, SymbolicExpr, \
    declare_dim_ranges

V = SymbolicExpr.var


# -- hash-consing -------------------------------------------------------------


# fixed monomial basis over three dims: a coefficient vector is a canonical
# polynomial, so two vectors match iff the canonical forms match
def _poly(coeffs):
    names = ["b", "s", "k"]
    e = SymbolicExpr.constant(coeffs[0])
    for name, c in zip(names, coeffs[1:4]):
        e = e + c * V(name)
    e = e + coeffs[4] * V("b") * V("s")
    e = e + coeffs[5] * V("s") * V("s")
    return e


def _poly_shuffled(coeffs, order):
    """The same polynomial assembled in a different association order."""
    names = ["b", "s", "k"]
    terms = [SymbolicExpr.constant(coeffs[0])]
    terms += [c * V(n) for n, c in zip(names, coeffs[1:4])]
    terms += [coeffs[4] * V("b") * V("s"), coeffs[5] * V("s") * V("s")]
    acc = SymbolicExpr.constant(0)
    for i in sorted(range(len(terms)),
                    key=lambda i: order[i % len(order)] if order else i):
        acc = acc + terms[i]
    return acc


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-6, 6), min_size=6, max_size=6),
       st.lists(st.integers(0, 50), min_size=0, max_size=6))
def test_interned_equal_iff_same_canonical_form(coeffs, order):
    a = _poly(coeffs)
    b = _poly_shuffled(coeffs, order)
    # same canonical polynomial -> interned to the same object
    assert a == b
    assert hash(a) == hash(b)
    assert a is b, "equal canonical forms must intern to one object"
    assert a.uid == b.uid
    # different canonical polynomial -> not equal
    bumped = list(coeffs)
    bumped[len(order) % 6] += 1
    c = _poly(bumped)
    assert a != c and c != a
    assert a is not c and a.uid != c.uid


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(-4, 4), min_size=6, max_size=6),
       st.integers(-8, 8))
def test_algebra_fast_paths_stay_canonical(coeffs, k):
    e = _poly(coeffs)
    assert (e + 0) is e
    assert (e * 1) is e
    assert (e * 0) == 0
    assert (e - e) == 0
    assert e + k == k + e
    assert e * k == k * e
    # scaling then evaluating == evaluating then scaling
    env = {"b": 3, "s": 5, "k": 7}
    assert (e * k).evaluate(env) == e.evaluate(env) * k


def test_interning_survives_opatoms():
    b, s = V("b"), V("s")
    f1 = (b * s + 3).floordiv(s)
    f2 = (3 + s * b).floordiv(s)
    assert f1 is f2
    assert SymbolicExpr.max_of(f1, f2) is f1


# -- memoized comparisons vs fresh graphs -------------------------------------


_DIMS = ["b", "s", "k"]
_EXPR_POOL = [
    V("b") * V("s"), V("b") * V("s") * 64, V("s") * V("s"),
    V("b") * 4096, V("s") + 12, SymbolicExpr.constant(2048),
    V("k") * V("s"), V("b") * V("s") - V("k"), 12 * V("k"),
    V("s") * V("s") * V("b"),
]


def _fresh_graph(ranges, with_equality):
    g = ShapeGraph()
    if with_equality:
        g.add_equality("k", 12 * V("b"))
    for name, (lo, hi) in ranges.items():
        g.declare_range(name, lo, hi)
    return g


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 9)),
                min_size=4, max_size=10),
       st.lists(st.tuples(st.integers(0, 2), st.integers(1, 64),
                          st.integers(1, 64)),
                min_size=0, max_size=3),
       st.booleans())
def test_specialized_memo_matches_fresh_unmemoized_graph(
        pairs, narrowings, with_equality):
    base_ranges = {"b": (1, 64), "s": (16, 4096), "k": (1, 4096)}
    parent = _fresh_graph(base_ranges, with_equality)
    # warm the parent memo with every query (and some repeats)
    for i, j in pairs:
        parent.compare(_EXPR_POOL[i], _EXPR_POOL[j])

    # a chain of randomized narrowings, inheriting memo entries each time
    ranges = dict(base_ranges)
    graph = parent
    for dim_i, a, b in narrowings:
        name = _DIMS[dim_i]
        lo0, hi0 = ranges[name]
        lo, hi = sorted((min(a, b), max(a, b)))
        lo = max(lo0, lo0 + lo - 1)
        hi = min(hi0, lo + hi)
        if lo > hi:
            lo = hi
        ranges[name] = (lo, hi)
        graph = graph.specialized({name: (lo, hi)})

    fresh = _fresh_graph(ranges, with_equality)
    for i, j in pairs:
        memoized = graph.compare(_EXPR_POOL[i], _EXPR_POOL[j])
        expected = fresh.compare(_EXPR_POOL[i], _EXPR_POOL[j])
        assert memoized is expected, (
            f"{_EXPR_POOL[i]} vs {_EXPR_POOL[j]}: memoized {memoized} "
            f"!= fresh {expected} under {ranges}")
        # repeat query (now certainly a memo hit) must agree too
        assert graph.compare(_EXPR_POOL[i], _EXPR_POOL[j]) is expected


class TestMemoizedCompareEquivalence:
    def test_declare_range_invalidates_only_dependents(self):
        g = ShapeGraph()
        g.declare_range("b", 1, 64)
        g.declare_range("s", 16, 4096)
        assert g.compare(V("b"), 100) is Cmp.LT
        assert g.compare(V("s"), 8) is Cmp.GT
        miss0 = g.cmp_stats["cache_miss"]
        g.declare_range("s", 16, 64)          # only s entries go stale
        assert g.compare(V("b"), 100) is Cmp.LT     # still a hit
        assert g.cmp_stats["cache_miss"] == miss0
        assert g.compare(V("s"), 8) is Cmp.GT       # recomputed
        assert g.cmp_stats["cache_miss"] == miss0 + 1

    def test_add_equality_invalidates_canonical_forms(self):
        g = ShapeGraph()
        g.declare_range("b", 1, 64)
        assert g.compare(V("k"), V("b") * 12) is Cmp.UNKNOWN
        g.add_equality("k", 12 * V("b"))
        assert g.compare(V("k"), V("b") * 12) is Cmp.EQ

    def test_interval_memo_matches_fresh(self):
        g = ShapeGraph()
        g.declare_range("b", 2, 8)
        e = V("b") * V("b") + 3
        assert (g.interval_of(e).lo, g.interval_of(e).hi) == (7, 67)
        g.declare_range("b", 2, 4)            # narrows: memo must refresh
        assert (g.interval_of(e).lo, g.interval_of(e).hi) == (7, 19)


# -- incremental pipeline equivalence -----------------------------------------


B, S = symbolic_dims("b, s")
NV, D, F = 300, 32, 64


def _loss(params, tokens, labels):
    emb = params["emb"][tokens]
    h = jax.nn.gelu(emb @ params["w1"])
    h2 = h @ params["w2"]
    logits = h2 @ params["emb"].T
    logp = jax.nn.log_softmax(logits)
    oh = jax.nn.one_hot(labels, logits.shape[-1])
    return -(oh * logp).sum() / (1.0 * tokens.shape[0] * tokens.shape[1])


def _train_step(params, tokens, labels):
    loss, grads = jax.value_and_grad(_loss)(params, tokens, labels)
    return loss, jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)


def _specs():
    p = {"emb": jax.ShapeDtypeStruct((NV, D), jnp.float32),
         "w1": jax.ShapeDtypeStruct((D, F), jnp.float32),
         "w2": jax.ShapeDtypeStruct((F, D), jnp.float32)}
    t = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return p, t, t


@pytest.fixture(scope="module")
def traced():
    graph, _ = trace_to_graph(_train_step, *_specs())
    return graph


class TestIncrementalPipeline:
    def test_incremental_equals_cold_compile(self, traced):
        """The incremental compile's outputs must be reproducible by fresh,
        un-memoized computation.  The remat candidates, bound data, and
        memory plan are checked against a cold reference *on the same
        schedule* (when reuse fires, the incremental path deliberately
        keeps the parent's guard/exchange post-pass, so the final order
        itself may differ from an end-to-end cold pipeline's — each is a
        valid guarded order)."""
        from repro.core.memplan import build_arena_plan
        from repro.core.remat.planner import ExecutionPlan
        from repro.core.remat.search import RecomputeSearcher
        from repro.core.scheduling.memsim import simulate_peak_bound

        sg = ShapeGraph()
        declare_dim_ranges(sg, {"b": (1, 16), "s": (8, 256)})
        _, _, art = _compile_pipeline(traced, sg, collect=True)
        for ranges in ({"s": (8, 32)}, {"s": (33, 64)}, {"s": (65, 256)}):
            sub = sg.specialized(ranges)
            inc_plan, inc_rep, _ = _compile_pipeline(traced, sub, parent=art)

            def fresh_sg():
                g = ShapeGraph()
                declare_dim_ranges(g, {"b": (1, 16), "s": ranges["s"]})
                return g

            # remat: a fresh searcher over the same order must reproduce
            # every candidate the (partially reused) incremental explore kept
            cold_cands = RecomputeSearcher(traced, fresh_sg()).explore(
                inc_plan.order)
            assert set(cold_cands) == set(inc_plan.candidates)
            for vid, c_cold in cold_cands.items():
                c_inc = inc_plan.candidates[vid]
                assert (c_cold.recompute is None) == (c_inc.recompute is None)
                if c_cold.recompute is not None:
                    assert c_cold.recompute.node_ids == c_inc.recompute.node_ids
                    assert c_cold.recompute.impact == c_inc.recompute.impact
                    assert c_cold.recompute.impact_interval == \
                        c_inc.recompute.impact_interval
                    assert c_cold.recompute.flops_interval == \
                        c_inc.recompute.flops_interval
                assert c_cold.bytes_interval == c_inc.bytes_interval
                assert c_cold.recompute_pruned_by_bounds == \
                    c_inc.recompute_pruned_by_bounds
            cold_ref = ExecutionPlan(graph=traced, order=list(inc_plan.order),
                                     shape_graph=fresh_sg(),
                                     candidates=cold_cands)
            assert inc_plan.static_methods == cold_ref.static_methods

            # bounds + memory plan: fresh graph, same order
            ap = build_arena_plan(traced, inc_plan.order, fresh_sg())
            assert ap.arena_bound_bytes == inc_rep.arena_bound_bytes
            lo, hi = simulate_peak_bound(traced, inc_plan.order, fresh_sg())
            assert (lo, hi) == (inc_rep.peak_bound_lo,
                                inc_rep.peak_bound_bytes)

            # without any reuse, the end-to-end cold pipeline must agree on
            # the final order too
            if not (inc_rep.reused_parent_schedule
                    or inc_rep.reused_parent_postpass):
                cold_plan, cold_rep, _ = _compile_pipeline(traced, fresh_sg())
                assert [n.id for n in inc_plan.order] == \
                    [n.id for n in cold_plan.order]
                assert inc_rep.arena_bound_bytes == cold_rep.arena_bound_bytes

    def test_full_reuse_when_nothing_narrows_effectively(self, traced):
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"b": (1, 16), "s": (8, 256)})
        _, _, art = _compile_pipeline(traced, sg, collect=True)
        # "narrowing" to the full declared range flips nothing: the parent
        # schedule + remat plan must be reused wholesale
        sub = sg.specialized({"s": (8, 256)})
        _, rep, _ = _compile_pipeline(traced, sub, parent=art)
        assert rep.reused_parent_schedule

    def test_scheduler_incremental_impact_is_invisible(self, traced):
        res = {}
        for mode in (True, False):
            sg = ShapeGraph()
            declare_dim_ranges(sg, {"b": (1, 16), "s": (8, 256)})
            res[mode] = OpScheduler(traced, sg,
                                    incremental_impact=mode).schedule()
        assert [n.id for n in res[True].order] == \
            [n.id for n in res[False].order]
        assert res[True].symbolic_decisions == res[False].symbolic_decisions
        assert res[True].tiebreak_decisions == res[False].tiebreak_decisions


# -- background specialization ------------------------------------------------


def _concrete_params(seed=0):
    rng = np.random.RandomState(seed)
    return {"emb": jnp.asarray(rng.randn(NV, D), jnp.float32),
            "w1": jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32),
            "w2": jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)}


def _tokens(b, s, seed=1):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, NV, (b, s)), jnp.int32)


class TestBackgroundSpecialization:
    def _pair(self):
        kw = dict(dynamic_dims={"b": (1, 16), "s": (8, 256)},
                  buckets={"s": [32, 64]})
        fn_sync = optimize(_train_step, *_specs(), **kw)
        fn_bg = optimize(_train_step, *_specs(),
                         background_specialize=True, **kw)
        return fn_sync, fn_bg

    def test_bitwise_identical_and_same_specialize_endpoint(self):
        fn_sync, fn_bg = self._pair()
        cp = _concrete_params()
        envs = [(2, 16), (2, 48), (1, 200), (2, 48), (4, 30)]
        for b, s in envs:
            tok = _tokens(b, s)
            loss_s, grads_s = fn_sync(cp, tok, tok)
            loss_b, grads_b = fn_bg(cp, tok, tok)
            assert np.asarray(loss_s).tobytes() == np.asarray(loss_b).tobytes()
            for a, bb in zip(jax.tree.leaves(grads_s),
                             jax.tree.leaves(grads_b)):
                assert np.asarray(a).tobytes() == np.asarray(bb).tobytes()
            assert fn_bg.last_bucket == fn_sync.last_bucket
        # deterministic join: after the drain, the background table has
        # specialized exactly the buckets the synchronous one compiled
        fn_bg.drain_specializations()
        ts, tb = fn_sync.specialization_table, fn_bg.specialization_table
        assert tb.specialize_count == ts.specialize_count
        assert sorted(tb.compiled_keys) == sorted(ts.compiled_keys)
        assert tb.n_pending == 0

    def test_miss_serves_fallback_then_swaps_in_plan(self):
        _, fn_bg = self._pair()
        cp = _concrete_params()
        tok = _tokens(2, 16)
        fn_bg(cp, tok, tok)                      # miss: fallback serve
        table = fn_bg.specialization_table
        assert table.fallback_serves == 1
        assert table.specialize_count in (0, 1)  # compile may still be going
        drained = fn_bg.drain_specializations()
        assert table.specialize_count == 1
        assert drained == [(0, 0)] or drained == []   # may land before drain
        assert table.peek((0, 0)) is not None
        fn_bg(cp, tok, tok)                      # now a hit
        assert table.hits == 1
        assert table.fallback_serves == 1

    def test_warmup_is_synchronous_join(self):
        _, fn_bg = self._pair()
        keys = fn_bg.warmup([{"b": 2, "s": 16}, {"b": 2, "s": 100}])
        table = fn_bg.specialization_table
        assert keys == [(0, 0), (0, 2)]
        assert table.specialize_count == 2
        assert table.n_pending == 0
        cp = _concrete_params()
        tok = _tokens(2, 16)
        fn_bg(cp, tok, tok)
        assert table.hits == 1 and table.fallback_serves == 0

    def test_background_arena_bound_answers_without_stall(self):
        _, fn_bg = self._pair()
        table = fn_bg.specialization_table
        mono_bound = fn_bg.report.arena_bound_bytes
        # unknown bucket: answers the conservative whole-range bound now...
        assert table.arena_bound_bytes((0, 0)) == mono_bound
        fn_bg.drain_specializations()
        # ...and the exact (tighter or equal) bucket bound once compiled
        exact = table.arena_bound_bytes((0, 0))
        assert exact is not None and exact <= mono_bound
        assert table.specialize_count == 1

    def test_background_requires_buckets(self):
        with pytest.raises(ValueError, match="buckets"):
            optimize(_train_step, *_specs(),
                     dynamic_dims={"b": (1, 16), "s": (8, 256)},
                     background_specialize=True)
